"""Failure-domain robustness: the deterministic fault injector, replica-
death redrive through the gateway, the stuck-lane watchdog, and the
bounded-retry actuator wrapper.

The centerpiece drives a real 2-replica paged cluster through a seeded
chaos schedule for 450 virtual-time steps with the gateway's verdict
ledger and the flight recorder's segment-conservation invariant checked
at EVERY step, then asserts the recovery contract: zero page leaks at
drain, exactly one terminal verdict per redriven request, an explicit
``handoff`` segment carrying each redriven timeline across engines, and
token parity with a fault-free run of the same workload.
"""
from collections import deque

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import get_config, reduced
from repro.core.faults import (ActuatorFault, Fault, FaultInjector,
                               RetryConfig, RetryingActuator,
                               StuckLaneWatchdog)
from repro.serving.directory import ResponseCache
from repro.serving.engine import ServingEngine
from repro.serving.gateway import DoorConfig, Gateway, Verdict
from repro.serving.request import Request
from repro.serving.trace import FlightRecorder

CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")

# crash replica 1 while it holds in-flight work, then hang a lane on
# replica 0 (the survivor carrying the redriven load)
CHAOS = (Fault(time=0.07, kind="replica_crash", tenant="T1", replica=1),
         Fault(time=0.10, kind="lane_stuck", tenant="T1", replica=0))


def mk_engine():
    # identical seed per replica: identical params, so greedy token
    # output is a pure function of the prompt regardless of which
    # replica (or how many restarts) served the request
    return ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4,
                         seed=0, backend="paged", pool_pages=24,
                         chunk_tokens=8, attn_impl="ref")


def drive_cluster(schedule, recover=True, steps=450, dt=0.01,
                  watchdog_timeout=0.05, n_req=20):
    """A miniature launch/serve loop: 2 paged replicas behind the
    gateway, fixed virtual step grid, the full recovery machinery —
    with ``gw.check()`` and ``rec.check()`` after every step."""
    rng = np.random.default_rng(3)
    engines = {"T1": [mk_engine(), mk_engine()]}
    rec = FlightRecorder()
    for e in engines["T1"]:
        e.tracer = rec
    gw = Gateway(engines,
                 door_cfgs={"T1": DoorConfig(max_queue=256,
                                             max_attempts=1000)},
                 tracer=rec)
    inj = FaultInjector(schedule)
    wd = StuckLaneWatchdog(timeout_s=watchdog_timeout)
    reqs = [Request(req_id=i, tenant="T1", prompt_len=12,
                    max_new_tokens=5, arrival=i * 0.004,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 12))
            for i in range(n_req)]
    pending = deque(reqs)
    redriven_ids, shed_ids = set(), set()
    t = 0.0
    for _ in range(steps):
        while pending and pending[0].arrival <= t:
            gw.offer(pending.popleft(), t)
        gw.dispatch(t)
        # faults after dispatch: a redriven entry waits at least one
        # step for redispatch, so its handoff segment has real width
        for f in inj.due(t):
            if f.kind == "replica_crash":
                live = gw.live_replicas("T1")
                if f.replica not in live or len(live) <= 1:
                    continue
                eng = engines["T1"][f.replica]
                gw.mark_dead("T1", f.replica)
                drained = eng.drain_requests()
                for r in drained:
                    wd.forget(("T1", f.replica, r.req_id))
                rec.on_fault(t, f.kind, tenant="T1", replica=f.replica)
                if recover:
                    gw.redrive("T1", drained, t, from_engine=f.replica)
                    redriven_ids.update(r.req_id for r in drained)
                else:
                    gw.abandon("T1", drained, t)
                    shed_ids.update(r.req_id for r in drained)
            elif f.kind == "lane_stuck":
                sched = engines["T1"][f.replica].runtime.sched
                lanes = [s.req.req_id for s in sched.active
                         if s.req.req_id not in sched.stuck]
                if lanes:
                    sched.mark_stuck(min(lanes))
                    rec.on_fault(t, f.kind, tenant="T1",
                                 replica=f.replica)
        for j in gw.live_replicas("T1"):
            eng = engines["T1"][j]
            if eng.has_work():
                gw.finalize("T1", eng, eng.step(), t + dt, start_time=t)
        live_keys = set()
        for j in gw.live_replicas("T1"):
            for s in engines["T1"][j].runtime.sched.active:
                key = ("T1", j, s.req.req_id)
                live_keys.add(key)
                wd.observe(key, s.req.generated, t + dt)
        wd.prune(live_keys)
        for _, j, rid in wd.stale(t + dt):
            sched = engines["T1"][j].runtime.sched
            seq = sched.find(rid)
            if seq is not None and seq not in sched.waiting:
                rec.on_preempt(seq.req, t + dt, engine=f"r{j}")
                sched.preempt(seq)
        gw.check()          # conservation holds at every step
        rec.check()         # segment tiling holds at every step
        t += dt
    assert not pending and gw.queued_total() == 0
    assert all(not e.has_work() for e in engines["T1"])
    return dict(gw=gw, rec=rec, engines=engines, reqs=reqs, inj=inj,
                wd=wd, redriven=redriven_ids, shed=shed_ids)


_RUNS = {}


def run_cached(key):
    if key not in _RUNS:
        if key == "baseline":
            _RUNS[key] = drive_cluster(())
        elif key == "no_recover":
            _RUNS[key] = drive_cluster(CHAOS[:1], recover=False)
        else:
            _RUNS[key] = drive_cluster(CHAOS)
    return _RUNS[key]


# ------------------------------------------------------- chaos property
# The five cluster-chaos properties below drive 450-step clusters and
# are tier-2 (the chaos CI job runs this whole file); the deterministic
# subset — injector purity/replay, watchdog, RetryingActuator — stays
# tier-1 so fault-path regressions block merges.
@pytest.mark.tier2
def test_chaos_recovery_conserves_everything():
    """450 checked steps of crash + stuck-lane chaos with recovery on:
    every offered request completes with exactly one terminal verdict,
    no replica (dead ones included) leaks a single KV page, and the
    fault schedule actually bit (work was redriven, the watchdog
    fired)."""
    run = run_cached("chaos")
    door = run["gw"].door("T1")
    assert door.offered == len(run["reqs"])
    assert door.completed == door.offered          # recovery saves all
    assert door.in_flight == 0
    assert door.shed == door.rejected == door.expired == 0
    assert door.redriven == len(run["redriven"]) >= 1
    assert run["wd"].fired >= 1
    # zero page leaks everywhere — the crashed replica included
    for eng in run["engines"]["T1"]:
        assert eng.kv.reserved_pages == 0
        assert not eng.runtime.sched.stuck
    # exactly one terminal verdict per redriven request
    for rid in run["redriven"]:
        assert door.verdict_of(rid) is Verdict.COMPLETED
    kinds = {k for _, k, _ in run["inj"].log}
    assert {"replica_crash", "lane_stuck"} <= kinds


@pytest.mark.tier2
def test_redriven_timeline_carries_handoff_segment():
    """A redriven request keeps ONE conserved timeline across engines:
    the crash opens an explicit ``handoff`` segment, the survivor's
    admit closes it, and the request is admitted twice but finished
    once."""
    run = run_cached("chaos")
    summaries = {s.req_id: s for s in run["rec"].summaries["T1"]}
    assert len(summaries) == len(run["reqs"])      # one timeline each
    for rid in run["redriven"]:
        s = summaries[rid]
        assert s.verdict == "completed"
        assert s.segs.get("handoff", 0.0) > 0.0
    # untouched requests never grew a handoff segment
    for rid in set(summaries) - run["redriven"]:
        assert "handoff" not in summaries[rid].segs


@pytest.mark.tier2
def test_chaos_tokens_match_fault_free_run():
    """Greedy decode + full-restart recovery: the chaos run's committed
    tokens are identical to the fault-free run's, for untouched AND
    redriven requests alike (regeneration replays the same argmax
    path)."""
    chaos = run_cached("chaos")
    base = run_cached("baseline")
    assert base["gw"].door("T1").completed == len(base["reqs"])
    base_toks = {r.req_id: list(r.output_tokens) for r in base["reqs"]}
    for r in chaos["reqs"]:
        assert len(r.output_tokens) == r.max_new_tokens
        assert list(r.output_tokens) == base_toks[r.req_id], \
            f"req {r.req_id} diverged (redriven={r.req_id in chaos['redriven']})"


@pytest.mark.tier2
def test_recovery_off_sheds_with_one_verdict_each():
    """Same crash, recovery disabled: the dead replica's in-flight
    requests are SHED — still exactly one terminal verdict each, the
    ledger still balances, pages still come back."""
    run = run_cached("no_recover")
    door = run["gw"].door("T1")
    assert len(run["shed"]) >= 1
    assert door.shed == len(run["shed"])
    assert door.redriven == 0
    assert door.completed == door.offered - door.shed
    assert door.in_flight == 0
    for rid in run["shed"]:
        assert door.verdict_of(rid) is Verdict.SHED
    for eng in run["engines"]["T1"]:
        assert eng.kv.reserved_pages == 0
    # recovery on vs off: the whole point, measured
    assert run_cached("chaos")["gw"].door("T1").completed > door.completed


@pytest.mark.tier2
def test_chaos_run_is_deterministic():
    """Same schedule, same seed, fixed virtual grid: a second run is
    bit-identical — fault log, gateway counters, committed tokens."""
    a = run_cached("chaos")
    b = drive_cluster(CHAOS)
    assert a["inj"].replay_key() == b["inj"].replay_key()
    assert a["gw"].door("T1").counters() == b["gw"].door("T1").counters()
    assert a["redriven"] == b["redriven"]
    toks = lambda run: {r.req_id: list(r.output_tokens)
                        for r in run["reqs"]}
    assert toks(a) == toks(b)


# ------------------------------------------------ injector determinism
@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10**6), st.data())
def test_fault_schedule_replays_bit_identically(seed, data):
    mk = lambda: FaultInjector.plan(
        seed, 20.0, tenants=["A", "B"], replicas=3, crashes=2,
        actuator_failures=2, stuck_lanes=2, fabric_windows=1,
        slow_replicas=1)
    a, b = mk(), mk()
    assert a.schedule == b.schedule
    assert any(f.kind == "replica_slow" for f in a.schedule)
    times = sorted(data.draw(st.lists(
        st.floats(min_value=0.0, max_value=25.0, allow_nan=False,
                  allow_infinity=False),
        min_size=1, max_size=12)))
    for t in times:
        assert a.due(t) == b.due(t)
        assert a.actuator_fault("reconfigure", t) == \
            b.actuator_fault("reconfigure", t)
        assert a.fabric_factor(t) == b.fabric_factor(t)
        for tenant in ("A", "B"):
            for rep in range(3):
                assert a.replica_factor(tenant, rep, t) == \
                    b.replica_factor(tenant, rep, t)
    assert a.replay_key() == b.replay_key()
    assert a.pending() == b.pending()


def test_plan_is_a_pure_function_of_seed():
    a = FaultInjector.plan(5, 10.0, tenants=["X"], replicas=2)
    b = FaultInjector.plan(5, 10.0, tenants=["X"], replicas=2)
    c = FaultInjector.plan(6, 10.0, tenants=["X"], replicas=2)
    assert a.schedule == b.schedule
    assert a.schedule != c.schedule
    assert all(0.0 <= f.time <= 10.0 for f in a.schedule)


# ---------------------------------------------------- watchdog mechanics
def test_watchdog_fires_only_on_true_stalls():
    wd = StuckLaneWatchdog(timeout_s=1.0)
    wd.observe("a", 0, 0.0)
    wd.observe("b", 0, 0.0)
    assert wd.stale(0.9) == []
    wd.observe("b", 1, 0.5)              # b made progress, a did not
    assert wd.stale(1.0) == ["a"]
    assert wd.fired == 1
    assert wd.stale(1.2) == []           # a was consumed, b still fresh
    assert wd.stale(1.5) == ["b"]
    # pruned lanes (completed/drained) can never be reported stale
    wd.observe("c", 0, 2.0)
    wd.prune([])
    assert wd.stale(10.0) == []


# ------------------------------------------- retrying actuator contract
class _ScriptedActuator:
    """Protocol-complete inner actuator that records every landed call
    and can be scripted to fail."""

    def __init__(self):
        self.calls = []
        self.quota = {}
        self.fail_next = 0

    def _maybe_fail(self):
        if self.fail_next > 0:
            self.fail_next -= 1
            raise ActuatorFault("scripted failure")

    def reconfigure(self, tenant, profile):
        self._maybe_fail()
        self.calls.append(("reconfigure", tenant, profile))
        return 1.0

    def move(self, tenant, slot):
        self._maybe_fail()
        self.calls.append(("move", tenant, slot))
        return 0.5

    def set_io_throttle(self, tenant, bytes_per_s):
        self._maybe_fail()
        self.calls.append(("set_io_throttle", tenant, bytes_per_s))

    def set_mps_quota(self, tenant, frac):
        self._maybe_fail()
        self.calls.append(("set_mps_quota", tenant, frac))
        self.quota[tenant] = frac

    def pin_cpu_away_from_irq(self, tenant):
        self._maybe_fail()
        self.calls.append(("pin_cpu_away_from_irq", tenant))

    def free_slots(self):
        self._maybe_fail()
        self.calls.append(("free_slots",))
        return ["slot"]

    def headroom_units(self, device):
        self._maybe_fail()
        self.calls.append(("headroom_units", device))
        return 3

    def migrate(self, tenant, replica_from, replica_to):
        self._maybe_fail()
        self.calls.append(("migrate", tenant, replica_from, replica_to))
        return 0.25


def _protocol_methods():
    from repro.core.controller import Actuator
    return sorted(n for n, v in vars(Actuator).items()
                  if not n.startswith("_") and callable(v))


def test_retrying_actuator_covers_every_protocol_method():
    """Lint over ``vars(Actuator)``: a method added to the protocol
    without RetryingActuator coverage (and a delegation check here)
    fails this test."""
    methods = _protocol_methods()
    for m in methods:
        assert callable(getattr(RetryingActuator, m, None)), \
            f"RetryingActuator does not implement protocol method {m!r}"
    inner = _ScriptedActuator()
    ra = RetryingActuator(inner, clock=lambda: 0.0)
    args = {"reconfigure": ("T1", "2g.20gb"), "move": ("T1", "slot"),
            "set_io_throttle": ("ETL", 3e8),
            "set_mps_quota": ("T1", 0.7),
            "pin_cpu_away_from_irq": ("T1",), "free_slots": (),
            "headroom_units": ("h0:g0",), "migrate": ("T1", 0, 1)}
    assert set(args) == set(methods)
    for m in methods:
        before = len(inner.calls)
        getattr(ra, m)(*args[m])
        assert len(inner.calls) == before + 1, \
            f"{m} did not delegate exactly once"
    assert ra.stats["calls"] == len(methods)
    assert ra.stats["faults"] == 0
    # value passthrough on the healthy path
    assert ra.reconfigure("T1", "2g.20gb") == 1.0
    assert ra.free_slots() == ["slot"]
    assert ra.headroom_units("h0:g0") == 3


def test_retrying_actuator_wraps_the_real_simulator():
    """The same wrapper heals a real ClusterSim whose actuator methods
    raise injected ActuatorFaults: two failures, success on the third
    attempt, one retried call on the books."""
    from repro.core.tenancy import TenantRegistry
    from repro.sim.cluster import ClusterSim
    from repro.sim.params import SimParams

    reg = TenantRegistry.slo_fleet(2, 2)
    p = SimParams(duration_s=60.0, schedule=(), tenants=tuple(reg))
    inj = FaultInjector([Fault(time=0.0, kind="actuator_fail",
                               method="pin_cpu_away_from_irq", count=2,
                               timeout_s=0.1)])
    inj.due(0.0)                     # arm
    sim = ClusterSim(p, faults=inj)
    ra = RetryingActuator(sim, clock=lambda: sim.now)
    first = next(iter(sim.lat))
    ra.pin_cpu_away_from_irq(first)
    assert sim.lat[first].pinned
    assert ra.stats["faults"] == 2
    assert ra.stats["retried_calls"] == 1
    assert ra.stats["exhausted"] == 0


def test_retry_backoff_is_charged_to_the_pause():
    """A retried reconfigure is downtime: the injected timeout plus the
    backoff delay land on the returned pause window."""
    inner = _ScriptedActuator()
    inj = FaultInjector([Fault(time=0.0, kind="actuator_fail",
                               method="reconfigure", count=1,
                               timeout_s=0.2)])
    inj.due(0.0)
    cfg = RetryConfig(max_attempts=3, base_backoff_s=0.05)
    ra = RetryingActuator(inner, clock=lambda: 0.0, faults=inj, cfg=cfg)
    pause = ra.reconfigure("T1", "2g.20gb")
    assert pause == pytest.approx(1.0 + 0.2 + 0.05)
    assert ra.time_lost_s == pytest.approx(0.25)


def test_exhaustion_rolls_back_to_last_good_and_gates():
    clock = [0.0]
    inner = _ScriptedActuator()
    inj = FaultInjector([])
    cfg = RetryConfig(max_attempts=3, base_backoff_s=0.01,
                      exhaustion_cooldown_s=10.0)
    ra = RetryingActuator(inner, clock=lambda: clock[0], faults=inj,
                          cfg=cfg)
    ra.set_mps_quota("T1", 0.9)              # last known-good
    assert inner.quota["T1"] == 0.9
    inner.fail_next = 3                      # every attempt fails...
    ra.set_mps_quota("T1", 0.5)              # ...rollback (4th) succeeds
    assert ra.stats["exhausted"] == 1
    assert ra.stats["rollbacks"] == 1
    assert inner.quota["T1"] == 0.9          # rolled back, not 0.5
    # gated during cooldown: no inner call at all
    before = len(inner.calls)
    assert ra.set_mps_quota("T1", 0.6) is None
    assert len(inner.calls) == before and ra.stats["gated"] == 1
    assert inner.quota["T1"] == 0.9
    # cooldown over: healthy calls flow again
    clock[0] = 11.0
    ra.set_mps_quota("T1", 0.6)
    assert inner.quota["T1"] == 0.6


def test_fsm_cooldown_stops_the_retry_cycle():
    """A cooling-down DecisionFSM ends the cycle after the FIRST failed
    attempt — retries never thrash a lane the control law is holding
    still."""
    class _FSM:
        def __init__(self, cooling):
            self.cooling = cooling

        def is_cooling_down(self):
            return self.cooling

    for cooling, want_faults in ((True, 1), (False, 3)):
        inner = _ScriptedActuator()
        inner.fail_next = 99
        ra = RetryingActuator(inner, clock=lambda: 0.0,
                              cfg=RetryConfig(max_attempts=3,
                                              base_backoff_s=0.01),
                              fsm_for=lambda t: _FSM(cooling))
        assert ra.set_mps_quota("T1", 0.5) is None
        assert ra.stats["faults"] == want_faults
        assert ra.stats["exhausted"] == 1


# ---------------------------------------- scheduler drain + stuck lanes
def test_scheduler_drain_and_stuck_lane_mechanics():
    """mark_stuck freezes a lane's progress without touching its pages;
    drain_for_redrive empties the whole scheduler, releases every page,
    and hands back restart-ready requests with their original
    ``prefill_done`` stamp (TTFT is never double-counted)."""
    rng = np.random.default_rng(9)
    eng = mk_engine()
    reqs = [Request(req_id=i, tenant="T1", prompt_len=12,
                    max_new_tokens=6, arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 12))
            for i in range(2)]
    for r in reqs:
        assert eng.submit(r)
    t = 0.0
    while not eng.runtime.sched.active:          # prefill both
        t += 0.01
        eng.finalize_step(eng.step(), t, t - 0.01)
    sched = eng.runtime.sched
    victim = min(s.req.req_id for s in sched.active)
    sched.mark_stuck(victim)
    frozen = next(s.req for s in sched.active if s.req.req_id == victim)
    gen_before = frozen.generated
    for _ in range(3):
        t += 0.01
        eng.finalize_step(eng.step(), t, t - 0.01)
    assert frozen.generated == gen_before        # stuck lane: no tokens
    others = [r for r in reqs if r.req_id != victim]
    assert all(r.generated > 1 or r.done for r in others)
    drained = eng.drain_requests()
    assert {r.req_id for r in drained} == \
        {r.req_id for r in reqs if not r.done}
    assert eng.kv.reserved_pages == 0
    assert not sched.active and not sched.prefilling and not sched.waiting
    assert not sched.stuck
    for r in drained:
        assert r.generated == 0 and not r.output_tokens
        assert r.prefill_done >= 0               # original TTFT stamp kept


# --------------------------------------------- response-cache guard
def test_response_cache_refuses_partials():
    """Only a COMPLETED generation may prime draft hints: a crash- or
    expiry-shaped partial (tokens present, generation short, no finish
    stamp) is refused and counted."""
    rc = ResponseCache()
    full = Request(req_id=0, tenant="T1", prompt_len=4, max_new_tokens=3,
                   arrival=0.0, prompt_tokens=np.array([1, 2, 3, 4]))
    full.output_tokens.extend([7, 8, 9])
    full.generated = 3
    rc.record(full)
    assert len(rc) == 1 and rc.partial_skips == 0
    partial = Request(req_id=1, tenant="T1", prompt_len=4,
                      max_new_tokens=8, arrival=0.0,
                      prompt_tokens=np.array([5, 6, 7, 8]))
    partial.output_tokens.extend([7, 8])
    partial.generated = 2                        # 2 of 8: a partial
    rc.record(partial)
    assert len(rc) == 1 and rc.partial_skips == 1
    probe = Request(req_id=2, tenant="T1", prompt_len=4, max_new_tokens=8,
                    arrival=0.0, prompt_tokens=np.array([5, 6, 7, 8]))
    assert not rc.prime(probe)                   # the partial never primed
    # a finished-but-short generation (early stop) IS recordable
    short = Request(req_id=3, tenant="T1", prompt_len=4, max_new_tokens=8,
                    arrival=0.0, prompt_tokens=np.array([9, 9, 9, 9]))
    short.output_tokens.extend([1, 2])
    short.generated = 2
    short.finished = 1.0
    rc.record(short)
    assert len(rc) == 2 and rc.partial_skips == 1
