"""Per-architecture smoke tests: a REDUCED variant of each family runs one
forward/train step on CPU; output shapes asserted, no NaNs (deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models.model import Model, decode_step, prefill, train_loss


def make_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.frontend.kind == "vision":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend.num_prefix,
                                 cfg.frontend.embed_dim)), jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.frontend.embed_dim)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss = jax.jit(lambda p, b: train_loss(p, cfg, b, remat=False))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, cache = jax.jit(
        lambda p, bb: prefill(p, cfg, bb, seq_cap=s + 8))(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    pos0 = batch["tokens"].shape[1]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg2, cache2 = jax.jit(
        lambda p, c, t, q: decode_step(p, cfg, c, t, q))(
        params, cache, tok, jnp.full((b,), pos0, jnp.int32))
    assert lg2.shape == (b, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(lg2.astype(jnp.float32)))
    # cache pytree structure is stable across steps
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["stablelm_3b", "mixtral_8x7b",
                                  "deepseek_v2_236b", "jamba_v0_1_52b",
                                  "rwkv6_1_6b", "gemma2_27b",
                                  "starcoder2_7b", "granite_3_8b"])
def test_decode_matches_prefill(arch):
    """serve_step(token N) must reproduce prefill(tokens 0..N) logits.

    MoE archs get a generous capacity factor: prefill's capacity-based
    token dropping is a *batch-level* semantic (decode never drops), so
    exact equivalence requires no drops."""
    from dataclasses import replace as _rp
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=_rp(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    _, cache = prefill(params, cfg, {"tokens": toks[:, :11]}, seq_cap=16)
    lg_inc, _ = decode_step(params, cfg, cache, toks[:, 11],
                            jnp.array([11], jnp.int32))
    lg_full, _ = prefill(params, cfg, {"tokens": toks}, seq_cap=16)
    np.testing.assert_allclose(
        np.asarray(lg_inc, np.float32), np.asarray(lg_full, np.float32),
        rtol=3e-2, atol=3e-2)


def test_sliding_window_cache_is_ring_buffer():
    """Windowed archs keep only `window` KV slots (long-context memory).
    Period caches are stacked [repeats, B, cap, ...]: cap is dim 2."""
    from dataclasses import replace
    cfg = reduced(get_config("mixtral_8x7b"))
    cfg = cfg.replace(period=(replace(cfg.period[0], window=8),))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.ones((1, 12), jnp.int32)
    _, cache = prefill(params, cfg, {"tokens": toks}, seq_cap=64)
    kv_leaves = [l for l in jax.tree.leaves(cache) if l.ndim == 5]
    assert kv_leaves and all(l.shape[2] == 8 for l in kv_leaves), \
        [l.shape for l in jax.tree.leaves(cache)]


def test_moe_aux_loss_contributes():
    cfg = reduced(get_config("mixtral_8x7b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss = train_loss(params, cfg, batch, remat=False)
    assert jnp.isfinite(loss)
