"""Beyond-paper proactive predictor (core/predictor.py)."""
import numpy as np
import pytest

from repro.core.predictor import PredictorConfig, TailTrendPredictor


def feed(pred, ts, ys):
    for t, y in zip(ts, ys):
        pred.update(float(t), float(y))


def test_rising_trend_predicts_breach():
    pred = TailTrendPredictor(PredictorConfig(horizon_s=15.0))
    ts = np.arange(12)
    ys = 0.010 + 0.0004 * ts          # +0.4 ms/s towards 15 ms
    feed(pred, ts, ys)
    p = pred.predict(now=11.0)
    assert p is not None and p > ys[-1]
    assert pred.should_preact(11.0, current_p99=float(ys[-1]), tau=0.015)


def test_flat_trend_does_not_preact():
    pred = TailTrendPredictor()
    ts = np.arange(12)
    feed(pred, ts, np.full(12, 0.012))
    assert pred.predict(11.0) is None
    assert not pred.should_preact(11.0, 0.012, tau=0.015)


def test_guard_frac_blocks_cold_start():
    """A rising trend far below the SLO must not trigger."""
    pred = TailTrendPredictor(PredictorConfig(guard_frac=0.6))
    ts = np.arange(12)
    feed(pred, ts, 0.001 + 0.0004 * ts)
    assert not pred.should_preact(11.0, current_p99=0.005, tau=0.015)


def test_rho_floor_vetoes_idle_system():
    pred = TailTrendPredictor(PredictorConfig(rho_floor=0.05))
    ts = np.arange(12)
    feed(pred, ts, 0.010 + 0.0006 * ts)
    # nearly idle: rho = 0.1 * 0.0001 << floor
    assert not pred.should_preact(11.0, 0.016, tau=0.015,
                                  rps=0.1, mean_service_s=1e-4)
    # loaded: prediction goes through
    assert pred.should_preact(11.0, 0.016, tau=0.015,
                              rps=30.0, mean_service_s=0.01)


def test_insufficient_history_returns_none():
    pred = TailTrendPredictor()
    pred.update(0.0, 0.010)
    pred.update(1.0, 0.012)
    assert pred.predict(2.0) is None


def test_proactive_controller_never_violates_structural_gates():
    """Proactive triggering must not produce more structural actions than
    the dwell allows (it only moves them earlier)."""
    from benchmarks.common import controller_factory
    from repro.core.policy import PolicyConfig
    from repro.sim.cluster import ClusterSim
    from repro.sim.params import SimParams, default_schedule
    p = SimParams(seed=2, duration_s=1200.0,
                  schedule=default_schedule(1200.0))
    sim = ClusterSim(p, controller_factory(proactive=True))
    sim.run()
    times = [d.time for d in sim.controller.audit.decisions
             if d.action in ("move", "reconfigure", "relax")]
    gaps = np.diff(times)
    dwell = PolicyConfig().dwell_obs * p.sample_period_s
    assert all(g >= dwell * 0.9 for g in gaps), gaps
