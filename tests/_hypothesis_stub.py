"""Minimal, dependency-free stand-in for the `hypothesis` API surface the
test-suite uses, installed by conftest.py only when the real package is
absent (the pinned CI/container image does not ship it and the repo may not
add dependencies).

Semantics: `@given` draws `max_examples` pseudo-random examples from the
declared strategies with a fixed seed, so the property tests still execute
(deterministically) instead of being skipped.  This is *not* Hypothesis —
no shrinking, no database, no adaptive search — but every strategy
combinator the suite uses (`floats`, `integers`, `lists`, `sampled_from`,
`one_of`, `none`, `booleans`, `just`, `tuples`, `data`) behaves
compatibly for generation purposes.
"""
from __future__ import annotations

import functools
import inspect
import math
import random
import sys
import types
from typing import Any, Callable, List, Optional, Sequence

_SEED = 0x5EED_CAFE
_DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    """A strategy is just a deterministic sampler: draw(rng) -> value."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str = "?"):
        self._draw = draw
        self.label = label

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self.draw(rng)),
                              f"{self.label}.map")

    def filter(self, pred: Callable[[Any], bool],
               max_tries: int = 100) -> "SearchStrategy":
        def drawer(rng: random.Random) -> Any:
            for _ in range(max_tries):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError(f"filter on {self.label} found no example "
                             f"in {max_tries} tries")
        return SearchStrategy(drawer, f"{self.label}.filter")

    def __repr__(self) -> str:
        return f"<stub strategy {self.label}>"


def floats(min_value: Optional[float] = None,
           max_value: Optional[float] = None,
           allow_nan: bool = True, allow_infinity: bool = True,
           **_ignored) -> SearchStrategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def drawer(rng: random.Random) -> float:
        # bias toward the boundaries like hypothesis does
        r = rng.random()
        if r < 0.1:
            return lo
        if r < 0.2:
            return hi
        if hi > 0 and lo >= 0 and hi / max(lo, 1e-300) > 1e3 and r < 0.5:
            # log-uniform for wide positive ranges
            return math.exp(rng.uniform(math.log(max(lo, 1e-12)),
                                        math.log(hi)))
        return rng.uniform(lo, hi)

    return SearchStrategy(drawer, f"floats({lo},{hi})")


def integers(min_value: Optional[int] = None,
             max_value: Optional[int] = None) -> SearchStrategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)
    return SearchStrategy(lambda rng: rng.randint(lo, hi),
                          f"integers({lo},{hi})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans")


def none() -> SearchStrategy:
    return SearchStrategy(lambda rng: None, "none")


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))],
                          f"sampled_from(n={len(elements)})")


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    flat: List[SearchStrategy] = []
    for s in strategies:
        flat.extend(s) if isinstance(s, (list, tuple)) else flat.append(s)
    return SearchStrategy(
        lambda rng: flat[rng.randrange(len(flat))].draw(rng),
        f"one_of(n={len(flat)})")


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: Optional[int] = None, unique: bool = False,
          **_ignored) -> SearchStrategy:
    hi = max_size if max_size is not None else min_size + 10

    def drawer(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, hi)
        if not unique:
            return [elements.draw(rng) for _ in range(n)]
        out: List[Any] = []
        tries = 0
        while len(out) < n and tries < 50 * (n + 1):
            v = elements.draw(rng)
            tries += 1
            if v not in out:
                out.append(v)
        return out

    return SearchStrategy(drawer, f"lists[{min_size},{hi}]")


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies),
                          f"tuples(n={len(strategies)})")


class DataObject:
    """Interactive draws: `data.draw(strategy)`."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: Optional[str] = None):
        return strategy.draw(self._rng)


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda rng: DataObject(rng), "data")


def data() -> SearchStrategy:
    return _DataStrategy()


# ------------------------------------------------------------- decorators
def given(*garg_strategies: SearchStrategy,
          **gkw_strategies: SearchStrategy) -> Callable:
    def decorate(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = [s.draw(rng) for s in garg_strategies]
                drawn_kw = {k: s.draw(rng)
                            for k, s in gkw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (real hypothesis does the same): drop the @wraps
        # __wrapped__ pointer pytest would unwrap, and expose only the
        # parameters @given does NOT provide (e.g. pytest.mark.parametrize
        # arguments or fixtures declared before the strategies).
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        provided = set(gkw_strategies)
        params = list(sig.parameters.values())
        if garg_strategies:
            # positional strategies fill the LAST len(garg_strategies)
            # parameters (hypothesis semantics)
            params = params[:-len(garg_strategies)]
        params = [p for p in params if p.name not in provided]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored) -> Callable:
    def decorate(fn: Callable) -> Callable:
        fn._stub_max_examples = max_examples
        return fn
    return decorate


def assume(condition: bool) -> bool:
    """Best-effort: a failed assumption skips the example via pytest.skip
    (no re-draw machinery here)."""
    if not condition:
        import pytest
        pytest.skip("stub-hypothesis assumption not satisfied")
    return True


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much, cls.data_too_large]


def install() -> None:
    """Register this stub as `hypothesis` + `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.__version__ = "0.0-stub"

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("floats", "integers", "booleans", "none", "just",
                 "sampled_from", "one_of", "lists", "tuples", "data",
                 "SearchStrategy"):
        setattr(st_mod, name, globals()[name])

    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
