"""Cluster-wide KV reuse: content-hash prefix directory (kvcache chain
keys <-> dispatcher-side prompt hashes), cache-aware routing (route-to-
longest-held-prefix, bounded fallbacks, strict total-order tie-breaks,
token parity under stale directories), the response cache that
self-primes speculation, and bucket-boundary-aware draft funding — all
host-side except the engine-level end-to-end checks."""
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.serving.directory import (CacheAwareRouter, PrefixDirectory,
                                     ResponseCache, RouterConfig,
                                     chain_key_hash, prefix_hashes,
                                     prompt_hash)
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request
from repro.serving.sched import (PagedScheduler, SchedConfig, SeqState,
                                 bucket_rows)

from test_paged_runtime import assert_no_leaks, drain

CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")


def make_req(req_id, prompt_tokens, max_new, hints=None, **kw):
    return Request(req_id=req_id, tenant="T1",
                   prompt_len=len(prompt_tokens), max_new_tokens=max_new,
                   arrival=0.0, prompt_tokens=np.asarray(prompt_tokens),
                   draft_hints=(np.asarray(hints) if hints is not None
                                else None), **kw)


def paged_engine(**kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("seq_cap", 96)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunk_tokens", 16)
    kw.setdefault("attn_impl", "ref")
    kw.setdefault("seed", 0)
    return ServingEngine(CFG, backend="paged", **kw)


# ------------------------------------------------------- content hashing
def test_chain_key_hash_matches_prompt_side_hashes():
    """The dispatcher (prefix_hashes over the prompt) and the kvcache
    listener (chain_key_hash over the recursive chain key) must derive
    identical hashes for identical content — that equality is the whole
    directory contract."""
    kv = PagedKVCache(num_pages=8, page_size=4)
    toks = list(range(100, 116))
    kv.allocate(1, prompt_len=16)
    kv.commit_prefix(1, toks, 16)
    assert set(chain_key_hash(k) for k in kv.prefix_index) == \
        set(prefix_hashes(toks, 4))
    # page-aligned full pages only: a partial page contributes nothing
    assert prefix_hashes(toks[:6], 4) == prefix_hashes(toks[:4], 4)
    assert len(prefix_hashes(toks, 4)) == 4
    # chained: same last page after a different first page = new hash
    other = [1] + toks[1:]
    assert prefix_hashes(other, 4)[-1] != prefix_hashes(toks, 4)[-1]
    kv.release(1)


def test_prompt_hash_content_addressed():
    a = prompt_hash([1, 2, 3])
    assert a == prompt_hash(np.asarray([1, 2, 3], np.int64))
    assert a != prompt_hash([1, 2, 4])


# ----------------------------------------------------- directory events
def test_directory_tracks_commit_and_eviction():
    """Listener wiring end to end on a real kvcache: commit publishes,
    cached-LRU eviction retracts, and lookup shrinks accordingly."""
    d = PrefixDirectory(page_size=4)
    kv = PagedKVCache(num_pages=4, page_size=4)
    d.attach("T1", 0, kv)
    toks = list(range(200, 216))
    kv.allocate(1, prompt_len=16)
    kv.commit_prefix(1, toks, 16)
    assert d.stats.published == 4
    assert d.lookup("T1", toks + [7]) == {0: 16}   # +1 token lifts the cap
    assert d.lookup("T1", toks) == {0: 12}         # final-token cap
    kv.release(1)                                  # park all 4 on the LRU
    # a new allocation must evict cached pages -> retractions flow back
    kv.allocate(2, prompt_len=8)
    assert d.stats.retracted >= 2
    held = d.lookup("T1", toks + [7])
    assert held.get(0, 0) < 16, "directory kept holdings past eviction"
    kv.release(2)


def test_defer_events_staleness_and_sync():
    d = PrefixDirectory(page_size=4, defer_events=True)
    d.publish("T1", 0, 123)
    d.publish("T1", 1, 123)
    assert d.staleness() == 2
    assert d.holders("T1", 123) == set()           # not yet applied
    assert d.sync() == 2
    assert d.staleness() == 0
    assert d.holders("T1", 123) == {0, 1}
    d.retract("T1", 0, 123)
    assert d.staleness() == 1
    d.sync()
    assert d.holders("T1", 123) == {1}


def test_lookup_longest_contiguous_prefix():
    """A replica only counts up to the first missing page in ITS chain
    (exactly what match_prefix would attach), and the final token is
    always left uncovered."""
    d = PrefixDirectory(page_size=4)
    toks = list(range(16))
    hs = prefix_hashes(toks, 4)
    for h in hs[:2]:
        d.publish("T1", 0, h)
    for h in hs[:3]:
        d.publish("T1", 1, h)
    d.publish("T1", 2, hs[2])                      # gap: page 3 only
    assert d.lookup("T1", toks) == {0: 8, 1: 12}
    assert d.lookup("T1", toks[:3]) == {}          # sub-page prompt
    d.stats.lookups = d.stats.hits = 0
    d.lookup("T1", toks)
    d.lookup("T1", list(range(900, 916)))          # unknown content
    assert (d.stats.lookups, d.stats.hits) == (2, 1)


# --------------------------------------------------------------- routing
def _route_req(toks):
    return make_req(0, toks, 4)


def test_router_routes_to_longest_holder():
    d = PrefixDirectory(page_size=4)
    toks = list(range(16))
    hs = prefix_hashes(toks, 4)
    d.publish("T1", 0, hs[0])
    for h in hs[:3]:
        d.publish("T1", 1, h)
    r = CacheAwareRouter(d, "T1")
    # replica 1 holds 12 tokens vs replica 0's 4 — even at a (bounded)
    # load disadvantage the longest holder wins
    assert r.route(_route_req(toks), [0, 2]) == 1
    assert r.stats.routed_cache == 1


def test_router_fallbacks_and_decision_invariant():
    d = PrefixDirectory(page_size=4, defer_events=True)
    toks = list(range(16))
    hs = prefix_hashes(toks, 4)
    for h in hs:
        d.publish("T1", 1, h)
    d.sync()
    cfg = RouterConfig(imbalance_bound=4, staleness_bound=0)
    r = CacheAwareRouter(d, "T1", cfg)
    # holder too far behind the least-loaded -> imbalance fallback
    assert r.route(_route_req(toks), [0, 6]) == 0
    assert r.stats.fallback_imbalance == 1
    # unknown content -> miss fallback
    assert r.route(_route_req(list(range(50, 66))), [3, 1]) == 1
    assert r.stats.fallback_miss == 1
    # pending backlog beyond the bound -> stale fallback (no lookup)
    d.publish("T1", 0, hs[0])
    looked = d.stats.lookups
    assert r.route(_route_req(toks), [3, 1]) == 1
    assert r.stats.fallback_stale == 1
    assert d.stats.lookups == looked, "stale router still hit the directory"
    d.sync()
    # blind baseline counts too
    blind = CacheAwareRouter(d, "T1", cfg, cache_aware=False)
    assert blind.route(_route_req(toks), [2, 1]) == 1
    assert blind.stats.routed_blind == 1
    # every decision is counted exactly once
    assert r.stats.total == 3
    assert r.stats.total == (r.stats.routed_cache + r.stats.routed_blind
                             + r.stats.fallback_miss
                             + r.stats.fallback_imbalance
                             + r.stats.fallback_stale)


def test_router_strict_total_order_tiebreaks():
    """Held tokens, then load, then replica index — and identical traces
    replay identically."""
    d = PrefixDirectory(page_size=4)
    toks = list(range(16))
    for h in prefix_hashes(toks, 4):
        for j in range(3):
            d.publish("T1", j, h)
    r = CacheAwareRouter(d, "T1")
    # equal holdings, equal loads -> lowest index
    assert r.route(_route_req(toks), [1, 1, 1]) == 0
    # equal holdings -> load breaks the tie
    assert r.route(_route_req(toks), [2, 1, 2]) == 1
    # least-loaded itself tie-breaks on index
    blind = CacheAwareRouter(d, "T1", cache_aware=False)
    assert blind.route(_route_req(toks), [2, 0, 0]) == 1

    def replay():
        rr = CacheAwareRouter(d, "T1")
        rng = np.random.default_rng(7)
        picks = []
        for _ in range(32):
            loads = [int(x) for x in rng.integers(0, 4, 3)]
            known = rng.random() < 0.5
            req = _route_req(toks if known else
                             [int(t) for t in rng.integers(100, 900, 16)])
            picks.append(rr.route(req, loads))
        return picks, rr.stats

    p1, s1 = replay()
    p2, s2 = replay()
    assert p1 == p2 and s1 == s2


def test_routing_token_parity_under_stale_directory():
    """A directory claiming holdings that do not exist routes requests to
    replicas that merely MISS their prefix cache: emitted tokens must be
    identical to a single reference engine's, request for request."""
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, CFG.vocab_size, 24) for _ in range(4)]

    ref = paged_engine()
    refs = [make_req(i, p, 6) for i, p in enumerate(prompts)]
    for r in refs:
        assert ref.submit(r)
    drain(ref)

    engines = [paged_engine(), paged_engine()]
    d = PrefixDirectory(page_size=8)
    for j, eng in enumerate(engines):
        d.attach("T1", j, eng.kv)
    # poison the directory: replica 0 "holds" every prompt's first page
    # (it holds nothing) — stale-but-safe means this only costs misses
    for p in prompts:
        d.publish("T1", 0, prefix_hashes(p, 8)[0])
    router = CacheAwareRouter(d, "T1")
    reqs = [make_req(i, p, 6) for i, p in enumerate(prompts)]
    for r in reqs:
        loads = [len(e.queue) + len(e.active()) for e in engines]
        assert engines[router.route(r, loads)].submit(r)
    for eng in engines:
        drain(eng)
    assert router.stats.routed_cache == len(reqs)   # every route was a lie
    for got, want in zip(reqs, refs):
        assert got.output_tokens == want.output_tokens
    for eng in engines:
        assert_no_leaks(eng)


# -------------------------------------------------------- response cache
def test_response_cache_key_includes_params():
    rc = ResponseCache()
    done = make_req(0, [1, 2, 3, 4], 8)
    done.output_tokens = [9, 8, 7]
    done.finished = 1.0            # early-stopped but COMPLETED
    rc.record(done)
    same = make_req(1, [1, 2, 3, 4], 8)
    assert rc.prime(same)
    assert list(same.draft_hints) == [9, 8, 7]
    # same prompt, different generation params -> different key
    other_params = make_req(2, [1, 2, 3, 4], 4)
    assert not rc.prime(other_params)
    assert other_params.draft_hints is None
    # different tenant -> different key
    other_tenant = make_req(3, [1, 2, 3, 4], 8)
    other_tenant.tenant = "T2"
    assert not rc.prime(other_tenant)
    assert rc.hit_rate() == pytest.approx(1 / 3)


def test_response_cache_never_overwrites_client_hints():
    rc = ResponseCache()
    done = make_req(0, [1, 2, 3, 4], 8)
    done.output_tokens = [9, 8, 7]
    done.finished = 1.0
    rc.record(done)
    client = make_req(1, [1, 2, 3, 4], 8, hints=[5, 5, 5])
    assert not rc.prime(client)
    assert list(client.draft_hints) == [5, 5, 5]
    assert rc.lookups == 0, "a hinted request still consulted the cache"


def test_response_cache_lru_eviction():
    rc = ResponseCache(capacity=2)
    for i in range(3):
        done = make_req(i, [i, i + 1, i + 2, i + 3], 8)
        done.output_tokens = [i]
        done.finished = 1.0
        rc.record(done)
    assert len(rc) == 2 and rc.evictions == 1
    assert not rc.prime(make_req(9, [0, 1, 2, 3], 8))     # oldest evicted
    assert rc.prime(make_req(9, [2, 3, 4, 5], 8))
    # empty outputs and token-less prompts are never recorded
    rc.record(make_req(5, [7, 7, 7], 8))
    nul = Request(req_id=6, tenant="T1", prompt_len=4, max_new_tokens=8,
                  arrival=0.0)
    nul.output_tokens = [1]
    rc.record(nul)
    assert len(rc) == 2


def test_response_cache_self_primes_speculation_end_to_end():
    """The headline loop: identical templated prompts, NO client hints —
    the first completion is recorded at complete, the second submit is
    primed at the scheduler, and the drafter replays it through the
    verify path (accept rate > 0) with token-identical output."""
    rng = np.random.default_rng(29)
    prompt = rng.integers(0, CFG.vocab_size, 24)
    eng = paged_engine(spec_k=4, response_cache=True)
    cold = make_req(0, prompt, 8)
    assert eng.submit(cold)
    drain(eng)
    assert cold.draft_hints is None                 # nothing to prime from
    drafted_cold = eng.metrics.drafted_tokens_total

    warm = make_req(1, prompt, 8)
    assert eng.submit(warm)
    assert warm.draft_hints is not None, "second submit was not primed"
    assert list(warm.draft_hints) == cold.output_tokens
    drain(eng)
    assert warm.output_tokens == cold.output_tokens
    m = eng.metrics
    assert m.drafted_tokens_total > drafted_cold
    assert m.accepted_tokens_total > 0
    assert m.response_hit_rate() == pytest.approx(0.5)   # 1 hit / 2 lookups
    assert_no_leaks(eng)


def test_response_cache_on_dense_backend_rejected():
    with pytest.raises(ValueError):
        ServingEngine(CFG, backend="dense", response_cache=True)


# --------------------------------------- bucket-boundary draft funding
def _decode_lane(kv, i):
    """An active decode lane with replay hints whose next draft the
    n-gram drafter will propose (hint boundary pattern, as in the replay
    workflow)."""
    req = make_req(i, [100 + i, 11, 12, 13], 8, hints=[50, 51, 52])
    req.output_tokens = [50]
    req.generated = 1
    kv.allocate(i, prompt_len=4)
    seq = SeqState(req)
    seq.prefilled = 4
    seq.last_token = 50
    return seq


def test_padded_rows_draft_for_free_where_old_planner_declined():
    """3 decode lanes under step_tokens=3: leftover budget is ZERO, so
    the pre-padding planner drafted nothing — but the runtime pads 3
    rows to the 4-row compile bucket anyway, so exactly one draft row
    rides that padding at zero budget cost."""
    assert bucket_rows(3) == 4 and bucket_rows(4) == 4

    def plan_with(free_padding):
        kv = PagedKVCache(num_pages=32, page_size=4)
        sched = PagedScheduler(kv, SchedConfig(
            spec_k=2, step_tokens=3, chunk_tokens=4, max_active=4,
            spec_free_padding=free_padding))
        for i in range(3):
            sched.active.append(_decode_lane(kv, i))
        return sched.plan()

    old = plan_with(False)
    assert (old.draft_tokens, old.free_draft_tokens) == (0, 0)
    new = plan_with(True)
    assert (new.draft_tokens, new.free_draft_tokens) == (1, 1)
    # the free row filled the padding exactly: same compile bucket
    assert bucket_rows(new.total_tokens) == bucket_rows(old.total_tokens)


def test_free_padding_never_grows_batch_past_budget_bucket():
    """With leftover budget AND padding available, draft rows (funded or
    free) fill the compile bucket the step budget already pays for — and
    stop exactly at its boundary, never opening the next bucket."""
    kv = PagedKVCache(num_pages=64, page_size=4)
    sched = PagedScheduler(kv, SchedConfig(
        spec_k=4, step_tokens=6, chunk_tokens=4, max_active=4))
    for i in range(3):
        sched.active.append(_decode_lane(kv, i))
    plan = sched.plan()
    # 3 decode lanes + 3 leftover budget -> the step pays for the 8-row
    # bucket; drafts fill it wall to wall (1 budgeted row to cross 4->8,
    # the rest ride padding) and go no further despite lanes having
    # draft material left
    assert plan.total_tokens == bucket_rows(6) == 8
    assert plan.draft_tokens == 5 and plan.free_draft_tokens == 4
    assert bucket_rows(plan.total_tokens) == bucket_rows(6), \
        "draft rows grew the device batch past the budget's bucket"


def test_spec_free_padding_token_parity():
    """Padding-funded drafts change WHEN tokens commit, never WHICH:
    a saturated-budget spec run must emit exactly the non-spec tokens."""
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, CFG.vocab_size, 8) for _ in range(3)]

    base = paged_engine(spec_k=0, step_tokens=3)
    rb = [make_req(i, p, 8) for i, p in enumerate(prompts)]
    for r in rb:
        assert base.submit(r)
    drain(base)

    spec = paged_engine(spec_k=2, step_tokens=3, response_cache=True)
    # prime the response cache so the spec arm drafts with no client
    # hints, then replay the same prompts
    r1 = [make_req(i, p, 8) for i, p in enumerate(prompts)]
    for r in r1:
        assert spec.submit(r)
    drain(spec)
    r2 = [make_req(10 + i, p, 8) for i, p in enumerate(prompts)]
    for r in r2:
        assert spec.submit(r)
    drain(spec)
    for got, want in zip(r2, rb):
        assert got.output_tokens == want.output_tokens
    assert spec.metrics.drafted_tokens_total > 0
    assert_no_leaks(spec)
    assert_no_leaks(base)
