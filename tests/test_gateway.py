"""Gateway front door: verdict conservation under churn, the 429/503
backpressure split, token-stream <-> TenantMetrics ITL parity, the
Kingman-derived per-request rate limit, and warmup hygiene."""
from collections import deque

import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.admission import AdmissionConfig, RateLimiter
from repro.core.kingman import GG1
from repro.core.tenancy import TenantSpec
from repro.serving.engine import ServingEngine, StepReport
from repro.serving.gateway import (DoorConfig, Gateway, TokenStream,
                                   Verdict)
from repro.serving.metrics import DEFAULT_BUCKETS, TenantMetrics
from repro.serving.request import ADMITTED, POOL_EXHAUSTED, Request

CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")


def make_req(i, tenant="T1", arrival=0.0, prompt_len=8, max_new=3):
    return Request(req_id=i, tenant=tenant, prompt_len=prompt_len,
                   max_new_tokens=max_new, arrival=arrival)


class StubEngine:
    """Dense-engine-shaped mini engine: a bounded pool, one prefill or
    one batched decode per fabricated step.  ``finalize_step`` is the
    REAL ServingEngine implementation (unbound), so timestamps and
    metrics follow production bookkeeping exactly."""

    tracer = None

    def __init__(self, cap=4):
        self.cap = cap
        self.max_slots = cap
        self.queue = deque()
        self.running = []
        self.metrics = TenantMetrics()

    def active(self):
        return self.running

    def has_work(self):
        return bool(self.queue or self.running)

    def submit(self, req):
        if len(self.queue) + len(self.running) >= self.cap:
            return POOL_EXHAUSTED
        self.queue.append(req)
        return ADMITTED

    finalize_step = ServingEngine.finalize_step

    def fabricate_step(self, rng):
        if self.queue:
            r = self.queue.popleft()
            self.running.append(r)
            r.output_tokens.append(int(rng.integers(1000)))
            rep = StepReport(kind="prefill", tokens=r.prompt_len,
                             prefill_tokens=r.prompt_len, prefilled=[r])
            if len(r.output_tokens) >= r.max_new_tokens:
                self.running.remove(r)
                rep.completed.append(r)
            return rep
        rep = StepReport(kind="decode")
        for r in list(self.running):
            r.output_tokens.append(int(rng.integers(1000)))
            rep.decoded.append(r)
            rep.tokens += 1
            rep.decode_tokens += 1
            if len(r.output_tokens) >= r.max_new_tokens:
                self.running.remove(r)
                rep.completed.append(r)
        return rep


# ---------------------------------------------------------- conservation
def test_verdict_conservation_under_churn():
    """Random traffic, pauses, stepping, and a mid-run tenant add: the
    per-tenant ledger must balance at EVERY virtual-time step, and every
    offered request must end in exactly one terminal verdict."""
    rng = np.random.default_rng(7)
    pauses = {}
    engines = {"T1": [StubEngine(3), StubEngine(2)], "T2": [StubEngine(2)]}
    gw = Gateway(engines,
                 default_cfg=DoorConfig(max_queue=4, deadline_s=2.0,
                                        max_attempts=2),
                 paused_until=lambda n: pauses.get(n, 0.0))
    now, i = 0.0, 0
    for _ in range(400):
        now += float(rng.exponential(0.05))
        op = int(rng.integers(5))
        if op == 0:
            for _ in range(int(rng.integers(1, 4))):
                name = str(rng.choice(sorted(engines)))
                gw.offer(make_req(i, name, arrival=now,
                                  max_new=int(rng.integers(1, 5))), now)
                i += 1
        elif op == 1:
            gw.dispatch(now)
        elif op == 2:
            name = str(rng.choice(sorted(engines)))
            for eng in engines[name]:
                if eng.has_work():
                    gw.finalize(name, eng, eng.fabricate_step(rng), now)
        elif op == 3:
            name = str(rng.choice(sorted(engines)))
            pauses[name] = now + float(rng.exponential(0.2))
        elif op == 4 and "T9" not in engines:
            engines["T9"] = [StubEngine(2)]      # tenant admitted mid-run
        gw.check()       # the invariant holds at every step, not just at end
    # drain: everything accepted must resolve to COMPLETED or EXPIRED
    for _ in range(400):
        now += 0.1
        gw.dispatch(now)
        for name, engs in engines.items():
            for eng in engs:
                while eng.has_work():
                    gw.finalize(name, eng, eng.fabricate_step(rng), now)
        gw.check()
        if gw.queued_total() == 0 and \
                all(not e.has_work() for es in engines.values() for e in es):
            break
    assert i > 100                       # the trace actually offered load
    for door in gw.doors.values():
        assert door.in_flight == 0
        assert door.offered == door.completed + door.rejected + \
            door.shed + door.expired
        assert all(v in (Verdict.REJECTED, Verdict.SHED, Verdict.EXPIRED,
                         Verdict.COMPLETED) for v in door._state.values())
    # the run exercised more than the happy path
    total = {k: sum(d.counters()[k] for d in gw.doors.values())
             for k in ("completed", "rejected", "shed", "expired")}
    assert total["completed"] > 0
    assert total["rejected"] + total["shed"] + total["expired"] > 0


def test_double_terminal_verdict_raises():
    gw = Gateway({"T1": [StubEngine(2)]})
    r = make_req(0)
    gw.offer(r, 0.0)
    door = gw.door("T1")
    door._terminal(r, Verdict.COMPLETED)
    with pytest.raises(AssertionError, match="second terminal"):
        door._terminal(r, Verdict.EXPIRED)


# ------------------------------------------------------ 429 vs 503 split
def test_queue_full_rejects_fast():
    """A full bounded door queue is a structural condition: the arrival
    is REJECTED immediately (429), never queued."""
    gw = Gateway({"T1": [StubEngine(0)]},      # engine pool never admits
                 door_cfgs={"T1": DoorConfig(max_queue=2,
                                             max_attempts=1000)})
    assert gw.offer(make_req(0), 0.0) is Verdict.ACCEPTED
    assert gw.offer(make_req(1), 0.0) is Verdict.ACCEPTED
    assert gw.offer(make_req(2), 0.0) is Verdict.REJECTED
    door = gw.door("T1")
    assert door.reject_reasons == {"queue_full": 1}
    assert len(door.queue) == 2
    gw.check()


def test_deadline_expiry_boundary():
    """A transient shortage queues with a deadline (503 path): still
    queued strictly before the deadline, EXPIRED exactly at it."""
    gw = Gateway({"T1": [StubEngine(0)]},
                 door_cfgs={"T1": DoorConfig(max_queue=8, deadline_s=1.0,
                                             max_attempts=1000)})
    gw.offer(make_req(0, arrival=0.0), 0.0)
    door = gw.door("T1")
    gw.dispatch(0.5)                 # pool exhausted: retried, not dropped
    assert door.expired == 0 and len(door.queue) == 1
    gw.dispatch(1.0 - 1e-9)          # just under the deadline: still queued
    assert door.expired == 0
    gw.dispatch(1.0)                 # exactly at the deadline: expired
    assert door.expired == 1 and door.in_flight == 0
    assert door.verdict_of(0) is Verdict.EXPIRED
    gw.check()


def test_structural_rejection_skips_the_queue_wait():
    """A non-transient engine rejection (request could NEVER fit) must
    not burn the full retry/deadline budget."""
    eng = ServingEngine(CFG, max_slots=2, seq_cap=32, backend="paged")
    gw = Gateway({"T1": [eng]},
                 door_cfgs={"T1": DoorConfig(max_queue=8, deadline_s=10.0,
                                             max_attempts=1000)})
    gw.offer(make_req(0, prompt_len=500, max_new=100), 0.0)
    gw.dispatch(0.0)
    door = gw.door("T1")
    assert door.rejected == 1 and len(door.queue) == 0
    assert "exceeds_seq_cap" in door.reject_reasons
    gw.check()


def test_transient_rejection_requeues_once_then_gives_up():
    gw = Gateway({"T1": [StubEngine(0)]},
                 door_cfgs={"T1": DoorConfig(max_queue=8,
                                             max_attempts=2)})
    gw.offer(make_req(0), 0.0)
    door = gw.door("T1")
    gw.dispatch(0.0)                         # attempt 1: requeued
    assert door.rejected == 0 and len(door.queue) == 1
    gw.dispatch(0.1)                         # attempt 2: gives up
    assert door.rejected == 1 and len(door.queue) == 0
    assert door.reject_reasons == {"pool_exhausted": 1}
    gw.check()


def test_redriven_request_gets_fresh_requeue_credit():
    """Replica death is not the request's fault: a redriven request's
    pool-exhaustion budget resets (attempts=0) and its redrives are
    counted separately, so prior transient rejections on the dead
    replica can never push it over ``max_attempts``."""
    rng = np.random.default_rng(0)
    a, b = StubEngine(1), StubEngine(1)
    gw = Gateway({"T1": [a, b]},
                 door_cfgs={"T1": DoorConfig(max_queue=8,
                                             max_attempts=2)})
    door = gw.door("T1")
    gw.offer(make_req(0, max_new=1), 0.0)    # filler -> A, finishes fast
    gw.offer(make_req(1, max_new=5), 0.0)    # filler -> B, stays busy
    gw.offer(make_req(2, max_new=1), 0.0)    # X: the redriven request
    gw.dispatch(0.0)
    assert len(door.queue) == 1              # X burned attempt 1 of 2
    gw.finalize("T1", a, a.fabricate_step(rng), 0.01, start_time=0.0)
    assert door.completed == 1               # filler 0 done, A is free
    gw.dispatch(0.02)                        # X lands on A
    assert len(door.queue) == 0 and door.in_flight == 2
    # A dies with X resident: drain it and redrive through the door
    gw.mark_dead("T1", 0)
    drained = list(a.queue) + list(a.running)
    a.queue.clear()
    a.running.clear()
    assert [r.req_id for r in drained] == [2]
    gw.redrive("T1", drained, 0.03, from_engine=0)
    assert door.redriven == 1 and door.rejected == 0
    # B is still full: X pool-exhausts AGAIN — but with fresh credit
    # it is requeued, not rejected (old bookkeeping would reject here)
    gw.dispatch(0.04)
    assert door.rejected == 0 and len(door.queue) == 1
    for _ in range(5):                       # drain filler 1 off B
        gw.finalize("T1", b, b.fabricate_step(rng), 0.05, start_time=0.04)
    gw.dispatch(0.06)                        # X finally lands on B
    gw.finalize("T1", b, b.fabricate_step(rng), 0.07, start_time=0.06)
    assert door.verdict_of(2) is Verdict.COMPLETED
    assert door.counters()["completed"] == 3
    assert door.counters()["redriven"] == 1
    gw.check()


# ------------------------------------------------------------ rate limit
def test_rate_limit_rejects_429():
    gw = Gateway({"T1": [StubEngine(4)]},
                 door_cfgs={"T1": DoorConfig(
                     max_queue=8,
                     rate_limiter=RateLimiter(rate=1.0, burst=1.0))})
    assert gw.offer(make_req(0), 0.0) is Verdict.ACCEPTED
    assert gw.offer(make_req(1), 0.0) is Verdict.REJECTED
    assert gw.door("T1").reject_reasons == {"rate_limit": 1}
    # one token/s sustained: refilled a second later
    assert gw.offer(make_req(2, arrival=1.5), 1.5) is Verdict.ACCEPTED
    gw.check()


def test_kingman_rate_limiter_matches_gg1_bound():
    """The per-request limiter and the tenant-plane admission check must
    agree: the limiter's sustained rate is exactly the arrival rate that
    puts the G/G/1 utilisation at the admission bound."""
    spec = TenantSpec(name="X", rate=5.0, slo_s=0.2)
    cfg = AdmissionConfig()
    lim = RateLimiter.kingman(spec, cfg)
    es = spec.c0_s + spec.mean_size / cfg.fabric_capacity
    assert lim.rate == pytest.approx(cfg.rho_bound / es)
    assert GG1(lim.rate, es).rho == pytest.approx(cfg.rho_bound)
    assert GG1(lim.rate * 1.1, es).rho > cfg.rho_bound
    # a fair share split n ways shrinks the safe rate
    assert RateLimiter.kingman(spec, cfg, n_flows=4).rate < lim.rate
    # enforcement: a same-instant burst is clipped at the bucket depth
    lim2 = RateLimiter(rate=2.0, burst=3.0)
    assert sum(lim2.allow(0.0) for _ in range(10)) == 3


# -------------------------------------------------------- stream parity
@pytest.mark.parametrize("spec_k", [0, 3])
def test_stream_itl_matches_metrics(spec_k):
    """The client-visible token stream must measure exactly the ITLs the
    engine records: same emission timestamps, same gaps — including
    speculative bursts, where same-step tokens land with zero gap."""
    eng = ServingEngine(CFG, max_slots=4, seq_cap=64, backend="paged",
                        spec_k=spec_k)
    gw = Gateway({"T1": [eng]},
                 door_cfgs={"T1": DoorConfig(max_queue=64)})
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i, tenant="T1", prompt_len=pl,
                    max_new_tokens=mn, arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, pl))
            for i, (pl, mn) in enumerate([(40, 4), (7, 8), (21, 2), (3, 6)])]
    for r in reqs:
        assert gw.offer(r, 0.0) is Verdict.ACCEPTED
    gw.dispatch(0.0)
    t = 0.0
    while eng.has_work():
        t += 0.01
        gw.finalize("T1", eng, eng.step(), t)
    gw.check()
    door = gw.door("T1")
    assert door.completed == len(reqs)
    all_gaps = []
    for r in reqs:
        st = door.streams[r.req_id]
        assert st.first_time == r.prefill_done
        assert [ts for _, ts in st.events[1:]] == r.decode_times
        assert [tok for tok, _ in st.events] == r.output_tokens
        assert st.gaps == pytest.approx(r.itls)
        all_gaps.extend(st.gaps)
    itl_samples = [v for _, v in eng.metrics.itl.samples]
    assert sorted(all_gaps) == pytest.approx(sorted(itl_samples))


def test_stream_rollback_preserves_pre_preemption_gaps():
    """Preemption rolls the stream back to the first token; already-
    observed gaps stay recorded (the metrics window keeps its samples
    too), and the first regenerated gap is measured from the ORIGINAL
    first emission — mirroring finalize_step's cleared-decode_times
    fallback to the retained prefill_done."""
    st = TokenStream(make_req(0))
    st.first(5, 1.0)
    st.emit(6, 1.5)
    st.emit(7, 2.0)
    assert st.gaps == [0.5, 0.5]
    st.rollback()
    assert st.sent == 1
    st.emit(6, 3.0)                  # first regenerated token
    assert st.gaps == [0.5, 0.5, 2.0]
    # a request preempted before its first token has nothing to roll back
    st2 = TokenStream(make_req(1))
    st2.rollback()
    assert st2.sent == 0 and st2.first_time is None


# ------------------------------------------------------- warmup hygiene
def test_warm_engine_leaves_no_trace():
    """The req_id=-1 warm request must not leave a zero-latency metrics
    sample, a shared response-cache entry, or published directory pages
    behind — and the wiring must be restored afterwards."""
    from repro.launch.serve import warm_engine
    from repro.serving.directory import PrefixDirectory, ResponseCache

    rc = ResponseCache()
    eng = ServingEngine(CFG, max_slots=2, seq_cap=64, backend="paged",
                        response_cache=rc)
    directory = PrefixDirectory(page_size=16)
    directory.attach("T1", 0, eng.kv)
    warm_engine(eng, "T1", prompt_len=48)
    m = eng.metrics
    assert m.latency.total == 0 and m.itl.total == 0
    assert m.engine_ttft.total == 0
    assert m.prefill_tokens_total == 0 and m.drafted_tokens_total == 0
    assert m.response_cache_lookups == 0
    assert eng.runtime.sched.rc_lookups == 0
    assert eng.runtime.sched.rc_hits == 0
    assert len(rc) == 0                          # nothing recorded
    assert directory.stats.published == 0        # nothing published
    assert eng.kv.listener is not None           # wiring restored
    assert eng.runtime.sched.response_cache is rc


# ------------------------------------------- serve() end-to-end ledger
def test_serve_counts_rejections_at_pool_exhaustion():
    """Regression for the silent-drop bug: burst traffic into a 1-slot
    dense engine exhausts the prompt+max_new page reservation; every
    failed submit must surface as a REJECTED verdict (after one
    requeue), and the ledger must balance."""
    from repro.launch.serve import serve

    out = serve(requests=10, qps=500.0, slots=1, max_new=16,
                with_controller=False, verbose=False)
    t = out["T1"]
    assert t["offered"] == 10
    assert t["offered"] == t["completed"] + t["shed"] + t["rejected"] \
        + t["expired"]
    assert t["rejected"] > 0
    assert t["reject_reasons"].get("pool_exhausted") == t["rejected"]
    # the Prometheus export exposes the full ledger per tenant
    assert 'gateway_offered_total{tenant="T1"} 10' in out["prometheus"]
    for v in ("completed", "rejected", "shed", "expired"):
        assert f'gateway_verdict_total{{tenant="T1",verdict="{v}"}}' \
            in out["prometheus"]
    for g in ("gateway_queue_depth", "gateway_in_flight",
              "gateway_active_lanes", "gateway_saturation",
              "gateway_door_ttft_p99_seconds",
              "gateway_engine_ttft_p99_seconds"):
        assert f'{g}{{tenant="T1"}}' in out["prometheus"]
    # cumulative le-bucket histograms ride along the windowed gauges
    for m in ("gateway_door_ttft_seconds", "gateway_engine_ttft_seconds",
              "gateway_itl_seconds"):
        assert f'# TYPE {m} histogram' in out["prometheus"]
        assert f'{m}_bucket{{tenant="T1",le="+Inf"}}' in out["prometheus"]
        assert f'{m}_sum{{tenant="T1"}}' in out["prometheus"]
        assert f'{m}_count{{tenant="T1"}}' in out["prometheus"]


def test_prometheus_histograms_aggregate_across_replicas():
    """Unlike the windowed p99 gauges, the ``le`` buckets are cumulative
    counters: per-tenant export sums them element-wise across replica
    engines, stays monotone in ``le``, and ``_count`` equals the
    all-time total — the property that makes them aggregable across
    scrapes where a windowed quantile is not."""
    import re

    e1, e2 = StubEngine(2), StubEngine(2)
    gw = Gateway({"T1": [e1, e2]})
    e1.metrics.latency.observe(0.0, 0.003)   # -> le 0.005
    e1.metrics.latency.observe(1.0, 0.05)    # -> le 0.05 (edge-inclusive)
    e2.metrics.latency.observe(0.5, 0.3)     # -> le 0.4, other replica
    text = gw.prometheus()
    rows = dict(re.findall(
        r'gateway_door_ttft_seconds_bucket\{tenant="T1",le="([^"]+)"\}'
        r' (\S+)', text))
    assert rows["0.0025"] == "0"
    assert rows["0.005"] == "1"
    assert rows["0.05"] == "2"
    assert rows["0.4"] == "3"
    assert rows["+Inf"] == "3"
    vals = [float(rows[f"{le:g}"]) for le in DEFAULT_BUCKETS]
    assert vals == sorted(vals)
    assert 'gateway_door_ttft_seconds_count{tenant="T1"} 3' in text
    assert 'gateway_door_ttft_seconds_sum{tenant="T1"} 0.353' in text
