"""The multi-tenant layer: registry-driven sim, the shared MIG arbiter's
budget invariant, seeded determinism of the e5 sweep, and a two-SLO-tenant
scenario where the controller helps both lanes."""
import numpy as np
import pytest

from repro.core.controller import Controller, ControllerConfig
from repro.core.profiles import A100_MIG
from repro.core.tenancy import (ComputeArbiter, TenantRegistry, TenantSpec,
                                UpgradeRequest, parse_slot_key)
from repro.core.topology import make_p4d_cluster
from repro.sim.cluster import ClusterSim
from repro.sim.params import InterferenceWindow, SimParams, default_schedule


# ------------------------------------------------------------- registry
def test_paper_scenario_is_a_registry_instance():
    p = SimParams(duration_s=60.0, schedule=())
    sim = ClusterSim(p)
    assert set(sim.registry.names()) == {"T1", "T2", "T3"}
    assert [s.name for s in sim.registry.latency()] == ["T1"]
    assert sim.registry["T2"].pcie_demand == p.t2_pcie_demand
    assert sim.registry["T3"].units == p.t3_units


def test_cluster_sim_has_no_tenant_prefixed_attributes():
    """Tenant identity is data, not code: no t1_/t2_/t3_ attrs remain."""
    sim = ClusterSim(SimParams(duration_s=60.0, schedule=()))
    leaked = [a for a in vars(sim)
              if a.startswith(("t1_", "t2_", "t3_"))]
    assert leaked == []


def test_registry_auto_placement_unique_and_spread():
    topo = make_p4d_cluster(2)
    reg = TenantRegistry.slo_fleet(8, 2)
    placements = reg.resolve_placements(topo)
    keys = [s.key for slots in placements.values() for s in slots]
    assert len(keys) == len(set(keys))            # no slot double-booked
    # latency replicas land on more than one PCIe root
    roots = {topo.root_of(s.device)
             for name in [t.name for t in reg.latency()]
             for s in placements[name]}
    assert len(roots) >= 4


def test_parse_slot_key_roundtrip():
    topo = make_p4d_cluster(2)
    for slot in topo.slots()[:8]:
        assert parse_slot_key(topo, slot.key) == slot


# -------------------------------------------------------------- arbiter
def test_arbiter_occupy_rejects_oversubscription():
    arb = ComputeArbiter(A100_MIG, budget_per_gpu=7)
    arb.occupy("A", "h0:g0", 4)
    with pytest.raises(ValueError):
        arb.occupy("B", "h0:g0", 4, replica=0)


def test_arbiter_grants_respect_budget_and_log_never_exceeds():
    arb = ComputeArbiter(A100_MIG, budget_per_gpu=7)
    arb.occupy("A", "h0:g0", 2)
    arb.occupy("B", "h0:g0", 2)
    two, four = A100_MIG["2g.20gb"], A100_MIG["4g.40gb"]
    ok_a = arb.grant(UpgradeRequest("A", 1.0, 0.5, ("h0:g0",), two, four))
    ok_b = arb.grant(UpgradeRequest("B", 1.0, 0.4, ("h0:g0",), two, four))
    assert ok_a and not ok_b            # 4 + 4 would blow the 7-unit budget
    assert arb.used("h0:g0") == 6       # A upgraded, B denied
    assert arb.audit_ok()
    assert any(e.action == "deny" and e.tenant == "B" for e in arb.log)


def test_arbiter_rank_priority_weighted_highest_miss_first():
    two, four = A100_MIG["2g.20gb"], A100_MIG["4g.40gb"]
    reqs = [
        UpgradeRequest("low_pri_high_miss", 1.0, 0.9, ("d",), two, four),
        UpgradeRequest("high_pri_low_miss", 2.0, 0.1, ("d",), two, four),
        UpgradeRequest("high_pri_high_miss", 2.0, 0.5, ("d",), two, four),
    ]
    ranked = [r.tenant for r in ComputeArbiter.rank(reqs)]
    assert ranked == ["high_pri_high_miss", "high_pri_low_miss",
                      "low_pri_high_miss"]


def test_multi_replica_grant_counts_per_device_replicas():
    """Two replicas of one tenant on a device double the upgrade cost."""
    arb = ComputeArbiter(A100_MIG, budget_per_gpu=7)
    arb.occupy("A", "h0:g0", 2, replica=0)
    arb.occupy("A", "h0:g0", 2, replica=1)
    two = A100_MIG["2g.20gb"]
    # +1 unit x 2 replicas = 2 <= headroom 3: fits
    assert arb.grant(UpgradeRequest("A", 1.0, 0.5, ("h0:g0",), two,
                                    A100_MIG["3g.40gb"]))
    assert arb.used("h0:g0") == 6
    # +4 units x 2 replicas from 3g: way past the budget
    assert not arb.grant(UpgradeRequest("A", 1.0, 0.5, ("h0:g0",),
                                        A100_MIG["3g.40gb"],
                                        A100_MIG["7g.80gb"]))
    assert arb.audit_ok()


# --------------------------------------------------- e5 / determinism
def _fleet_params(n, r, duration, seed):
    from benchmarks.e5_multitenant import make_params
    return make_params(n, r, duration, seed)


def test_e5_results_deterministic_per_seed():
    from benchmarks.e5_multitenant import run_cell
    a = run_cell(2, 2, duration=240.0, seed=3)
    b = run_cell(2, 2, duration=240.0, seed=3)
    # the controller block reports MEASURED wall-clock per decision tick
    # (host-dependent by design); everything simulated must be identical
    ca, cb = a.pop("controller"), b.pop("controller")
    assert a == b
    assert (ca["ticks"], ca["hosts"], ca["devices"]) == \
        (cb["ticks"], cb["hosts"], cb["devices"])


def test_e5_arbiter_budget_never_exceeded():
    from benchmarks.e5_multitenant import run_cell
    cell = run_cell(4, 2, duration=240.0, seed=0)
    assert cell["arbiter"]["ok"]
    assert cell["arbiter"]["max_units_per_gpu"] <= 7
    for name, row in cell["controlled"]["per_tenant"].items():
        assert row["p99_ms"] >= 0.0 and 0.0 <= row["miss_rate"] <= 1.0


def test_multi_replica_dispatch_uses_all_replicas():
    reg = TenantRegistry.slo_fleet(1, 3, base_rate=30.0,
                                   with_interferers=False)
    p = SimParams(duration_s=120.0, schedule=(), tenants=tuple(reg))
    sim = ClusterSim(p)
    res = sim.run()
    t = res.tenants["L0"]
    assert t.replicas == 3
    assert t.completed > 0
    # service load must actually spread: with 30 rps and ~8 ms service, a
    # single replica would saturate; 3 replicas keep the tail sane
    assert t.p99 < 0.05


# ------------------------------------------- two competing SLO tenants
def _two_tenant_params(seed):
    sizes = ((0.75, 12e6), (0.20, 24e6), (0.05, 32e6))
    reg = TenantRegistry([
        TenantSpec(name="A", rate=10.0, slo_s=0.015, sizes=sizes,
                   priority=1.5, placement=("h0:g0:s0",)),
        TenantSpec(name="B", rate=10.0, slo_s=0.015, sizes=sizes,
                   priority=1.0, placement=("h0:g1:s1",)),
        TenantSpec(name="ETL", role="background", profile="7g.80gb",
                   pcie_demand=20e9, ps_weight=4.0, io_demand=2.5e9,
                   units=0, placement=("h0:g1:s0",)),
        TenantSpec(name="TRAIN", role="background", profile="2g.20gb",
                   sm_util=0.95, units=2, placement=("h0:g0:s1",)),
    ])
    sched = []
    t = 60.0
    while t + 230 < 900.0:
        sched.append(InterferenceWindow("ETL", t, t + 150))
        sched.append(InterferenceWindow("TRAIN", t + 75, t + 225))
        t += 300.0
    return SimParams(seed=seed, duration_s=900.0, schedule=tuple(sched),
                     tenants=tuple(reg),
                     home_devices=("h0:g0", "h0:g1"))


def test_two_tenant_controller_improves_both_vs_static():
    p = _two_tenant_params(seed=5)
    static = ClusterSim(p).run()

    def fac(sim):
        c = Controller(sim.topo, sim.lattice, sim, ControllerConfig())
        sim.register_tenants(c)
        return c

    controlled = ClusterSim(p, fac).run()
    for name in ("A", "B"):
        s, c = static.tenants[name], controlled.tenants[name]
        assert c.miss_rate < s.miss_rate, \
            f"{name}: controlled {c.miss_rate} !< static {s.miss_rate}"
        assert c.p99 < s.p99
    # the controller paid for it with structural/guardrail actions
    assert sum(controlled.actions.values()) > 0
    assert controlled.arbiter_max_units <= 7
