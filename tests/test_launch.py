"""Launch-layer units that don't need 512 placeholder devices: batch plans,
analytic roofline terms, collective parsing."""
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch.shardings import make_policy
from repro.launch.specs import batch_plan, decode_arg_plans
from repro.models.params import P


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_plan_shapes_dense_train():
    cfg = get_config("granite_3_8b")
    plan = batch_plan(cfg, INPUT_SHAPES["train_4k"], MESH)
    assert plan["tokens"].shape == (256, 4096)
    assert plan["labels"].shape == (256, 4096)
    assert plan["tokens"].pspec[0] == ("data",) or \
        plan["tokens"].pspec[0] == "data"


def test_batch_plan_vlm_subtracts_patches():
    cfg = get_config("phi_3_vision_4_2b")
    plan = batch_plan(cfg, INPUT_SHAPES["train_4k"], MESH)
    assert plan["embeds"].shape == (256, 576, 1024)
    assert plan["tokens"].shape == (256, 4096 - 576)   # total positions 4096


def test_batch_plan_encdec_frames():
    cfg = get_config("seamless_m4t_large_v2")
    plan = batch_plan(cfg, INPUT_SHAPES["prefill_32k"], MESH)
    assert plan["frames"].shape == (32, 32768, 1024)
    assert plan["tokens"].shape[1] <= 128               # decoder prompt


def test_decode_arg_plans_cache_capacity():
    cfg = get_config("mixtral_8x7b")                    # SWA 4096
    cplan, tok, pos = decode_arg_plans(cfg, INPUT_SHAPES["long_500k"], MESH)
    kv_leaves = [p for p in _leaves(cplan) if len(p.shape) == 5]
    # window cache capacity == 4096, not 524288
    assert all(p.shape[2] == 4096 for p in kv_leaves)
    assert tok.shape == (1,)


def _leaves(plan):
    import jax
    return jax.tree.leaves(plan, is_leaf=lambda x: isinstance(x, P))


def test_multipod_policy_batch_axes():
    cfg = get_config("granite_3_8b")
    pol = make_policy(cfg, INPUT_SHAPES["train_4k"], MESH_MP)
    assert pol.act[0] == ("pod", "data")


def test_analytic_terms_scale_with_chips():
    from repro.launch.dryrun import analytic_terms
    cfg = get_config("stablelm_3b")
    t256 = analytic_terms(cfg, INPUT_SHAPES["train_4k"], 256)
    t512 = analytic_terms(cfg, INPUT_SHAPES["train_4k"], 512)
    assert t256["flops_analytic"] == t512["flops_analytic"]
    assert t256["t_compute_analytic"] == pytest.approx(
        2 * t512["t_compute_analytic"])


def test_analytic_train_flops_close_to_6nd():
    """Dense archs: analytic flops within ~2x of 6*N*D (attention extra)."""
    from benchmarks.roofline import model_flops
    from repro.launch.dryrun import analytic_terms
    cfg = get_config("granite_3_8b")
    t = analytic_terms(cfg, INPUT_SHAPES["train_4k"], 256)
    mf = model_flops("granite_3_8b", "train_4k")
    assert 0.5 < t["flops_analytic"] / mf < 2.0


def test_window_clipping_reduces_analytic_compute():
    from repro.launch.dryrun import analytic_terms
    cfg = get_config("mixtral_8x7b")
    clipped = analytic_terms(cfg, INPUT_SHAPES["prefill_32k"], 256,
                             q_chunk=512)
    unclipped = analytic_terms(cfg, INPUT_SHAPES["prefill_32k"], 256,
                               q_chunk=32768)
    assert clipped["flops_analytic"] < 0.9 * unclipped["flops_analytic"]


def test_collective_parser_sums_sizes():
    from repro.launch.dryrun import collective_bytes_from_hlo
    hlo = """
ENTRY %main {
  %ag = bf16[128,256] all-gather(%x), replica_groups={}
  %ar = f32[64] all-reduce(%y), to_apply=%sum
}
%body.1 (p: f32[8]) {
  %ar2 = f32[8,4] all-reduce(%p), to_apply=%sum
}
"""
    out = collective_bytes_from_hlo(hlo, {"layers": 10})
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 64 * 4 + 8 * 4 * 4 * 10   # body x10
