"""Per-request flight recorder: segment conservation under churn (the
gateway-ledger discipline applied to time), preemption/reconfig overlap
retention, real paged-engine preemption tracing, Chrome export schema,
tracing-off token/timing parity, and the one-trace-event-per-actuator-
method lint over both Actuator implementations."""
import json
from collections import deque

import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.obs import Tracer
from repro.serving.engine import ServingEngine, StepReport
from repro.serving.gateway import DoorConfig, Gateway, Verdict
from repro.serving.metrics import TenantMetrics
from repro.serving.request import ADMITTED, POOL_EXHAUSTED, Request
from repro.serving.trace import FlightRecorder

CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")


def make_req(i, tenant="T1", arrival=0.0, prompt_len=16, max_new=3):
    return Request(req_id=i, tenant=tenant, prompt_len=prompt_len,
                   max_new_tokens=max_new, arrival=arrival)


class ChurnEngine:
    """test_gateway's StubEngine extended with the paged runtime's churn
    behaviours — chunked prefill, restart-style preemption, speculative
    verify/rollback — all fabricated, all on virtual stamps.
    ``finalize_step`` is the REAL ServingEngine implementation, so the
    recorder sees production StepReports through the production hook."""

    backend = "stub"
    tracer = None

    def __init__(self, cap=4, chunk=8):
        self.cap = cap
        self.max_slots = cap
        self.chunk = chunk
        self.queue = deque()
        self.prefilling = []          # [req, tokens_done, next_chunk_idx]
        self.running = []
        self.metrics = TenantMetrics()

    def active(self):
        return self.running + [p[0] for p in self.prefilling]

    def has_work(self):
        return bool(self.queue or self.prefilling or self.running)

    def submit(self, req):
        if len(self.queue) + len(self.active()) >= self.cap:
            return POOL_EXHAUSTED
        self.queue.append(req)
        return ADMITTED

    finalize_step = ServingEngine.finalize_step

    def fabricate_step(self, rng):
        rep = StepReport(kind="mixed")
        # restart-style preemption: victim loses its tokens, requeues
        if self.running and rng.random() < 0.15:
            victim = self.running.pop(int(rng.integers(len(self.running))))
            victim.output_tokens.clear()
            victim.decode_times.clear()
            self.queue.appendleft(victim)
            rep.preempted.append(victim)
            rep.preempt_pairs.append((victim.req_id, -1))
        # advance one chunked prefill
        if self.prefilling:
            slot = self.prefilling.pop(0)
            req, done, idx = slot
            clen = min(self.chunk, req.prompt_len - done)
            rep.chunks.append((req, done, clen, idx))
            rep.tokens += clen
            rep.prefill_tokens += clen
            done += clen
            if done >= req.prompt_len:
                req.output_tokens.append(int(rng.integers(1000)))
                rep.prefilled.append(req)
                rep.tokens += 1
                rep.decode_tokens += 1
                if len(req.output_tokens) >= req.max_new_tokens:
                    rep.completed.append(req)
                else:
                    self.running.append(req)
            else:
                self.prefilling.append([req, done, idx + 1])
        elif self.queue:
            self.prefilling.append([self.queue.popleft(), 0, 0])
        # batched decode, sometimes with a speculative burst
        for r in list(self.running):
            n = 1
            if rng.random() < 0.3:
                drafted = int(rng.integers(1, 4))
                accepted = int(rng.integers(0, drafted + 1))
                rep.spec.append((r, drafted, accepted))
                n = min(1 + accepted,
                        r.max_new_tokens - len(r.output_tokens))
            for _ in range(n):
                r.output_tokens.append(int(rng.integers(1000)))
                rep.decoded.append(r)
            rep.tokens += n
            rep.decode_tokens += n
            if len(r.output_tokens) >= r.max_new_tokens:
                self.running.remove(r)
                rep.completed.append(r)
        return rep


# ---------------------------------------------------------- conservation
def test_segment_conservation_under_churn():
    """300+ virtual-time steps of random traffic, chunked prefill,
    preemption, speculation, pauses and controller actions: every
    offered request must end with a timeline whose segments tile
    [arrival, terminal] and sum to the measured latency — checked at
    EVERY step, not just at the end (mirrors the gateway ledger test).
    Every preemption and every controller action overlapping a request
    must be visible in the trace."""
    rng = np.random.default_rng(7)
    rec = FlightRecorder(keep_slowest=4, window_s=5.0)
    pauses = {}
    engines = {"T1": [ChurnEngine(3), ChurnEngine(2)],
               "T2": [ChurnEngine(2)]}
    for engs in engines.values():
        for e in engs:
            e.tracer = rec
    gw = Gateway(engines,
                 default_cfg=DoorConfig(max_queue=4, deadline_s=2.0,
                                        max_attempts=2),
                 paused_until=lambda n: pauses.get(n, 0.0),
                 tracer=rec)
    now, i = 0.0, 0
    preempted_ids = set()
    spec_steps = 0
    for _ in range(400):
        prev = now
        now += float(rng.exponential(0.05))
        op = int(rng.integers(6))
        if op == 0:
            for _ in range(int(rng.integers(1, 4))):
                name = str(rng.choice(sorted(engines)))
                gw.offer(make_req(i, name, arrival=now,
                                  max_new=int(rng.integers(1, 5))), now)
                i += 1
        elif op == 1:
            gw.dispatch(now)
        elif op == 2:
            name = str(rng.choice(sorted(engines)))
            for eng in engines[name]:
                if eng.has_work():
                    rep = eng.fabricate_step(rng)
                    preempted_ids.update(
                        (name, r.req_id) for r in rep.preempted)
                    if rep.spec:
                        spec_steps += 1
                    gw.finalize(name, eng, rep, now, start_time=prev)
        elif op == 3:
            name = str(rng.choice(sorted(engines)))
            pauses[name] = now + float(rng.exponential(0.2))
        elif op == 4:
            rec.action("reconfigure", now,
                       str(rng.choice(sorted(engines))),
                       dur=float(rng.exponential(0.5)))
        else:
            rec.action("set_mps_quota", now,
                       str(rng.choice(sorted(engines))), frac=0.7)
        gw.check()
        rec.check()        # conservation holds at every step
    # drain: every accepted request resolves, every timeline conserves
    for _ in range(400):
        now += 0.1
        gw.dispatch(now)
        for name, engs in engines.items():
            for eng in engs:
                while eng.has_work():
                    gw.finalize(name, eng, eng.fabricate_step(rng), now,
                                start_time=now - 0.1)
        gw.check()
        rec.check()
        if gw.queued_total() == 0 and \
                all(not e.has_work() for es in engines.values()
                    for e in es):
            break
    assert i > 100
    # one conserved timeline per offered request, rejected ones included
    assert rec.finished == i
    summaries = {(t, s.req_id): s for t, dq in rec.summaries.items()
                 for s in dq}
    assert len(summaries) == i
    verdicts = {v for s in summaries.values() for v in [s.verdict]}
    assert "completed" in verdicts and len(verdicts) > 1
    # the churn actually exercised preemption + speculation
    assert preempted_ids and spec_steps > 0
    for key in preempted_ids:
        assert summaries[key].preemptions >= 1
    assert any("preempted" in summaries[key].segs for key in preempted_ids)
    # every request overlapping a controller action keeps its full trace
    exemplar_ids = {(tl.tenant, tl.req_id) for tl in rec.action_exemplars}
    overlapping = {key for key, s in summaries.items()
                   if rec.actions_overlapping(s.arrival, s.end)}
    assert overlapping and overlapping <= exemplar_ids
    # retained tail exemplars are the slowest of their (tenant, window)
    for (tenant, _), bucket in rec._tail.items():
        assert len(bucket) <= rec.keep_slowest


def test_known_timeline_segments_and_events():
    """A hand-stamped request: door wait, two prefill chunks, decode
    with a speculative burst — exact segment durations, the TTFT view,
    and the instant events, all from production StepReport shapes."""
    rec = FlightRecorder()
    r = make_req(0, prompt_len=16, max_new=3)
    rec.on_offer(r, 0.0, Verdict.ACCEPTED)
    rec.on_admit(r, 0.5, engine=1)
    rec.on_step(StepReport(kind="prefill", chunks=[(r, 0, 8, 0)]),
                1.0, 1.5)
    rec.on_step(StepReport(kind="mixed", chunks=[(r, 8, 8, 1)],
                           prefilled=[r]), 1.5, 2.0)
    rec.on_step(StepReport(kind="decode", decoded=[r, r],
                           spec=[(r, 2, 1)], completed=[r]), 2.5, 3.0)
    (tl,) = rec.retained()
    tl.check()
    assert [s.name for s in tl.segments] == [
        "door_queued", "sched_queued", "prefill_chunk", "prefill_chunk",
        "decode"]
    sums = tl.seg_sums()
    assert sums["door_queued"] == pytest.approx(0.5)
    assert sums["sched_queued"] == pytest.approx(0.5)
    assert sums["prefill_chunk"] == pytest.approx(1.0)
    assert sums["decode"] == pytest.approx(1.0)
    assert sum(sums.values()) == pytest.approx(tl.e2e) == pytest.approx(3.0)
    # TTFT view clips at the first-token stamp
    assert tl.first_token_t == 2.0
    assert "decode" not in tl.seg_sums(until=tl.first_token_t)
    names = [ev.name for ev in tl.instants]
    for n in ("offered", "admitted", "first_token", "spec_verify",
              "spec_rollback", "verdict"):
        assert n in names
    (summ,) = rec.summaries["T1"]
    assert summ.ttft == pytest.approx(2.0)
    assert summ.verdict == "completed"


def test_rejected_requests_conserve_too():
    """Terminal verdicts away from the engine (door shed, dispatch-time
    rejection, queue expiry) still produce conserved timelines."""
    rec = FlightRecorder()
    shed = make_req(0, arrival=1.0)
    rec.on_offer(shed, 1.0, Verdict.SHED)
    exp = make_req(1, arrival=2.0)
    rec.on_offer(exp, 2.0, Verdict.ACCEPTED)
    rec.on_terminal(exp, 4.5, "expired")
    rej = make_req(2, arrival=3.0)
    rec.on_offer(rej, 3.0, Verdict.ACCEPTED)
    rec.on_admit(rej, 3.5)
    rec.on_terminal(rej, 3.5, "rejected", reason="pool_exhausted")
    rec.check()
    assert rec.finished == 3
    by_id = {s.req_id: s for s in rec.summaries["T1"]}
    assert by_id[0].e2e == 0.0 and by_id[0].verdict == "shed"
    assert by_id[1].segs == {"door_queued": pytest.approx(2.5)}
    assert by_id[1].verdict == "expired"
    assert by_id[2].segs == {"door_queued": pytest.approx(0.5)}
    # a second terminal for the same request is a ledger violation
    with pytest.raises(AssertionError, match="already finished"):
        rec.on_terminal(shed, 5.0, "expired")


def test_out_of_order_stamp_is_rejected():
    rec = FlightRecorder()
    r = make_req(0)
    rec.on_offer(r, 1.0, Verdict.ACCEPTED)
    rec.on_admit(r, 2.0)
    with pytest.raises(AssertionError, match="out of order"):
        rec.on_admit(r, 1.5)


# ----------------------------------------------------- real paged engine
def _overcommitted_engine(**kw):
    # pool of 6 pages x 4 tokens; two 16-token sequences need 8 pages
    return ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4,
                         seed=0, backend="paged", pool_pages=6,
                         chunk_tokens=8, attn_impl="ref", **kw)


def _drive(eng, reqs, tracer=None, dt=0.01, max_steps=800):
    eng.tracer = tracer
    for r in reqs:
        assert eng.submit(r)
    t, steps = 0.0, 0
    while eng.has_work():
        rep = eng.step()
        eng.finalize_step(rep, t + dt, t)
        t += dt
        steps += 1
        assert steps < max_steps
    return t


def test_real_paged_preemption_is_traced():
    """SLO-aware preemption on an overcommitted page pool: the victim's
    eviction lands in its timeline (preempted event + restart chunks)
    and the timeline still conserves through the recompute."""
    rng = np.random.default_rng(11)
    rec = FlightRecorder()
    hi = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, slo_ms=50.0, priority=2.0,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    lo = Request(req_id=1, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=0.5,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    _drive(_overcommitted_engine(), [hi, lo], tracer=rec)
    rec.check()
    assert rec.finished == 2
    by_id = {s.req_id: s for s in rec.summaries["T1"]}
    assert by_id[lo.req_id].preemptions >= 1
    assert by_id[hi.req_id].preemptions == 0
    tls = {tl.req_id: tl for tl in rec.retained()}
    ev_names = [ev.name for ev in tls[lo.req_id].instants]
    assert "preempted" in ev_names
    # the restart's prefill chunks are flagged
    restarts = [s for s in tls[lo.req_id].segments
                if s.name == "prefill_chunk" and s.args.get("restart")]
    assert restarts
    # engine-only harness: the timeline lazily begins at arrival (a
    # wait before the first chunk, if any, is labelled sched_queued)
    for tl in tls.values():
        assert tl.segments[0].t0 == tl.arrival
        assert {s.name for s in tl.segments} <= {
            "sched_queued", "prefill_chunk", "preempted", "decode"}


def test_tracing_off_is_token_and_timing_identical():
    """Attaching a recorder must not perturb anything: same tokens,
    same per-token virtual timestamps, same finish stamps."""
    def go(tracer):
        rng = np.random.default_rng(13)
        reqs = [Request(req_id=j, tenant="T1", prompt_len=8,
                        max_new_tokens=6, arrival=0.0,
                        priority=float(1 + (j % 2)),
                        prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
                for j in range(3)]
        _drive(_overcommitted_engine(), reqs, tracer=tracer)
        return [(list(r.output_tokens), list(r.decode_times),
                 r.prefill_done, r.finished) for r in reqs]

    assert go(None) == go(FlightRecorder())


# --------------------------------------------------- retention discipline
def test_retention_keeps_slowest_k_and_action_overlaps():
    rec = FlightRecorder(keep_slowest=2, window_s=100.0)
    # 6 requests in one window with e2e = 1..6 virtual seconds
    for j in range(6):
        r = make_req(j, arrival=0.0)
        rec.on_offer(r, 0.0, Verdict.ACCEPTED)
        rec.on_terminal(r, float(j + 1), "expired")
    kept = {tl.req_id for tl in rec.retained()}
    assert kept == {4, 5}                     # slowest two only
    assert len(rec.summaries["T1"]) == 6      # summaries keep everything
    # a FAST request overlapping a controller action is retained anyway
    rec.action("reconfigure", 10.0, "T1", dur=5.0)
    r = make_req(9, arrival=12.0)
    rec.on_offer(r, 12.0, Verdict.ACCEPTED)
    rec.on_terminal(r, 12.1, "expired")
    assert 9 in {tl.req_id for tl in rec.retained()}
    assert 9 in {tl.req_id for tl in rec.action_exemplars}


# ------------------------------------------------------------- chrome json
def test_chrome_export_schema():
    rec = FlightRecorder()
    r = make_req(0, prompt_len=16, max_new=2)
    rec.on_offer(r, 0.0, Verdict.ACCEPTED)
    rec.on_admit(r, 0.5)
    rec.on_step(StepReport(kind="prefill", chunks=[(r, 0, 16, 0)],
                           prefilled=[r]), 1.0, 2.0)
    rec.on_step(StepReport(kind="decode", decoded=[r], completed=[r]),
                2.0, 3.0)
    rec.action("reconfigure", 1.2, "T1", dur=0.4, profile="2g.20gb")
    data = rec.chrome_trace()
    json.dumps(data)                           # serialisable as-is
    assert data["displayTimeUnit"] == "ms"
    evs = data["traceEvents"]
    assert {e["ph"] for e in evs} <= {"X", "i", "M"}
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0
        elif e["ph"] == "i":
            assert e["s"] == "t"
    # seconds -> microseconds
    first = next(e for e in evs if e["name"] == "first_token")
    assert first["ts"] == pytest.approx(2.0e6)
    # tracks are processes (named via metadata), lanes are threads
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"T1", "controller"} <= procs
    spans = [e for e in evs if e["ph"] == "X"]
    reconf = next(e for e in spans if e["name"] == "reconfigure")
    assert reconf["dur"] == pytest.approx(0.4e6)
    assert reconf["args"]["tenant"] == "T1"


# ------------------------------------------------------------ actuator lint
def _protocol_methods():
    from repro.core.controller import Actuator
    return sorted(n for n, v in vars(Actuator).items()
                  if not n.startswith("_") and callable(v))


class _QuotaEngine:
    runtime = None                  # no paged runtime -> imports skipped

    def __init__(self):
        self.quota = 1.0

    def set_quota(self, q):
        self.quota = q

    def drain_requests(self, ship_state=False):
        return []


def _lint_actuator(act, tracer, first, second):
    """Call every Actuator protocol method once; each must emit exactly
    one trace event, and action events must carry their pause window."""
    from repro.core.profiles import A100_MIG

    # scout a move target through the ledger directly (no trace events)
    cur = act.ledger.slots_of(second)[0]
    target = next(s for s in act.ledger.free_slots()
                  if s.device != cur.device
                  and act.ledger.headroom_units(s.device) >= 2)
    calls = {
        "reconfigure": lambda: act.reconfigure(first, A100_MIG["3g.40gb"]),
        "move": lambda: act.move(second, target),
        "set_io_throttle": lambda: act.set_io_throttle("ETL", 3e8),
        "set_mps_quota": lambda: act.set_mps_quota(first, 0.7),
        "pin_cpu_away_from_irq":
            lambda: act.pin_cpu_away_from_irq(first),
        "free_slots": lambda: act.free_slots(),
        "headroom_units": lambda: act.headroom_units(cur.device),
        "migrate": lambda: act.migrate(first, 0, 1),
    }
    methods = _protocol_methods()
    # lint: a protocol method added without trace coverage fails here
    assert set(calls) == set(methods)
    for name in methods:
        before = len(tracer.events)
        calls[name]()
        assert len(tracer.events) == before + 1, \
            f"{type(act).__name__}.{name} emitted " \
            f"{len(tracer.events) - before} trace events, expected 1"
        ev = tracer.events[-1]
        assert tracer.actions and tracer.actions[-1] is ev
        if name in ("reconfigure", "move", "migrate"):
            assert ev.ph == "X" and ev.dur > 0    # pause window recorded
        else:
            assert ev.ph == "i"


def test_every_actuator_method_emits_exactly_one_event():
    from repro.core.ledger import DeviceLedger
    from repro.core.profiles import A100_MIG
    from repro.core.tenancy import TenantRegistry
    from repro.core.topology import make_p4d_cluster
    from repro.serving.actuator import FabricState, ServingActuator
    from repro.sim.cluster import ClusterSim
    from repro.sim.params import SimParams

    reg = TenantRegistry.slo_fleet(2, 2)
    specs = tuple(reg)
    p = SimParams(duration_s=60.0, schedule=(), tenants=specs)

    sim_tracer = Tracer()
    sim = ClusterSim(p, tracer=sim_tracer)
    first, second = list(sim.lat)[:2]
    _lint_actuator(sim, sim_tracer, first, second)

    act_tracer = Tracer()
    topo = make_p4d_cluster(2)
    reg2 = TenantRegistry(specs)
    ledger = DeviceLedger.from_registry(
        topo, reg2, A100_MIG, home_devices=p.home_devices,
        ambient_units=p.ambient_units)
    engines = {s.name: [_QuotaEngine(), _QuotaEngine()]
               for s in reg2.latency()}
    act = ServingActuator(engines, FabricState(), topo, lambda: 5.0,
                          ledger=ledger, rng=np.random.default_rng(0),
                          tracer=act_tracer)
    _lint_actuator(act, act_tracer, first, second)
