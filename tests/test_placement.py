"""Topology queries and the PCIe-aware placement scorer (paper §2.2.1)."""
import pytest

from repro.core.placement import (PlacementWeights, best_candidate,
                                  intra_device_first, placement_score,
                                  rank_candidates)
from repro.core.signals import Snapshot, SystemSignals, TenantSignals
from repro.core.topology import (BUILTIN_TOPOLOGIES, Slot, builtin_topology,
                                 make_p4d_cluster, make_p4d_fleet)


@pytest.fixture
def topo():
    return make_p4d_cluster(2)


def snap_with(pcie=None, io=None, irq=None):
    s = SystemSignals(pcie_bytes=pcie or {}, host_io=io or {},
                      irq_rate=irq or {})
    return Snapshot(0.0, {"T1": TenantSignals()}, s)


def test_p4d_topology_shape(topo):
    assert len(topo.devices()) == 16
    assert len(topo.roots()) == 8
    assert topo.same_root("h0:g0", "h0:g1")
    assert not topo.same_root("h0:g0", "h0:g2")
    assert topo.host_of("h1:g3") == 1
    assert "h0:g1" in topo.siblings("h0:g0")


def test_p4d_fleet_and_builtin_topologies():
    """The scaled-fleet variant (e5 --hosts 4) and the name-based
    registry: every builtin instantiates, the 4-host fleet doubles the
    2-host testbed, and unknown names fail loudly."""
    fleet = make_p4d_fleet(4)
    assert len(fleet.devices()) == 32
    assert len(fleet.roots()) == 16
    assert fleet.host_of("h3:g7") == 3
    for name in BUILTIN_TOPOLOGIES:
        t = builtin_topology(name)
        assert t.devices(), name
    assert len(builtin_topology("p4d-4host").devices()) == \
        2 * len(builtin_topology("p4d-2host").devices())
    with pytest.raises(ValueError):
        builtin_topology("nonexistent")
    with pytest.raises(ValueError):
        make_p4d_cluster(0)


def test_score_penalises_busy_root(topo):
    snap = snap_with(pcie={"h0:r0": 20e9})
    hot = placement_score(topo, Slot(0, "h0:g0", 0), snap)
    cold = placement_score(topo, Slot(0, "h0:g2", 0), snap)
    assert hot > cold


def test_score_penalises_numa_io_and_irq(topo):
    w = PlacementWeights()
    snap = snap_with(io={topo.numa_of("h0:g0"): 3e9})
    assert placement_score(topo, Slot(0, "h0:g0", 0), snap, w) > \
        placement_score(topo, Slot(1, "h1:g0", 0), snap, w) - w.cross_host


def test_cross_host_penalty(topo):
    snap = snap_with()
    local = placement_score(topo, Slot(0, "h0:g2", 0), snap, current_host=0)
    remote = placement_score(topo, Slot(1, "h1:g2", 0), snap, current_host=0)
    assert remote == pytest.approx(local + PlacementWeights().cross_host)


def test_rank_is_deterministic_and_sorted(topo):
    snap = snap_with(pcie={"h0:r0": 20e9, "h0:r1": 5e9})
    cands = topo.slots()
    ranked = rank_candidates(topo, cands, snap)
    scores = [s for _, s in ranked]
    assert scores == sorted(scores)
    assert ranked == rank_candidates(topo, cands, snap)


def test_intra_device_first_ordering(topo):
    """Paper: intra-GPU moves are tried before cross-GPU/cross-host."""
    snap = snap_with()
    current = Slot(0, "h0:g0", 0)
    free = [Slot(1, "h1:g0", 0), Slot(0, "h0:g3", 1), Slot(0, "h0:g0", 1)]
    ranked = intra_device_first(topo, current, free, snap)
    assert ranked[0][0].device == "h0:g0"            # same device first
    assert topo.host_of(ranked[1][0].device) == 0    # same host next
    assert topo.host_of(ranked[2][0].device) == 1    # remote last


def test_best_candidate_avoids_hot_path(topo):
    snap = snap_with(pcie={"h0:r0": 22e9, "h0:r1": 1e9, "h0:r2": 18e9})
    cands = [Slot(0, "h0:g0", 1), Slot(0, "h0:g2", 0), Slot(0, "h0:g4", 0)]
    best, score = best_candidate(topo, cands, snap)
    assert best.device == "h0:g2"                    # on the 1 GB/s root
