"""DeviceLedger invariants, property-tested (hypothesis, via the repo's
deterministic stub when the real package is absent): per-GPU budget never
exceeded, no slot double-occupied, move is occupancy-conserving, release
is idempotent."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ledger import DeviceLedger, LedgerError
from repro.core.profiles import A100_MIG
from repro.core.tenancy import TenantRegistry
from repro.core.topology import Slot, make_p4d_cluster

pytestmark = pytest.mark.tier2

TOPO = make_p4d_cluster(1)
SLOTS = TOPO.slots()
TENANTS = [f"P{i}" for i in range(6)]

# one random operation: (kind, tenant, replica, slot index, units)
ops = st.tuples(st.sampled_from(["occupy", "release", "move", "resize"]),
                st.sampled_from(TENANTS),
                st.integers(min_value=0, max_value=1),
                st.integers(min_value=0, max_value=len(SLOTS) - 1),
                st.integers(min_value=1, max_value=7))


def apply_op(ledger, op):
    """Apply one op; invalid ops must raise LedgerError and leave the
    ledger untouched (their effect is exactly 'nothing happened')."""
    kind, tenant, replica, sidx, units = op
    try:
        if kind == "occupy":
            ledger.occupy(tenant, SLOTS[sidx], units, replica=replica,
                          demand=float(units) * 1e9)
        elif kind == "release":
            ledger.release(tenant, replica)
        elif kind == "move":
            ledger.move(tenant, replica, SLOTS[sidx])
        elif kind == "resize":
            ledger.set_units(tenant, units)
    except LedgerError:
        pass


@given(st.lists(ops, min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_budget_never_exceeded_and_no_double_occupancy(op_list):
    ledger = DeviceLedger(TOPO, budget_per_gpu=7)
    for op in op_list:
        apply_op(ledger, op)
        ledger.check()                       # all invariants, every step
        for dev in TOPO.devices():
            assert ledger.used_units(dev) <= 7
        occupied = [e.slot.key for e in ledger.entries()]
        assert len(occupied) == len(set(occupied))
        # occupancy and free set partition the slot space
        assert len(occupied) + len(ledger.free_slots()) == len(SLOTS)


@given(st.lists(ops, min_size=1, max_size=30),
       st.integers(min_value=0, max_value=len(SLOTS) - 1))
@settings(max_examples=60, deadline=None)
def test_move_is_occupancy_conserving(op_list, target_idx):
    ledger = DeviceLedger(TOPO, budget_per_gpu=7)
    for op in op_list:
        apply_op(ledger, op)
    entries = ledger.entries()
    if not entries:
        return
    entry = entries[0]
    n_before = len(ledger.entries())
    units_before = sum(e.units for e in ledger.entries())
    src = entry.slot
    target = SLOTS[target_idx]
    try:
        ledger.move(entry.tenant, entry.replica, target)
    except LedgerError:
        # refused: nothing changed
        assert ledger.owner_of(src.key) == entry.owner
    else:
        if target.key != src.key:
            assert ledger.owner_of(src.key) is None
        assert ledger.owner_of(target.key) == entry.owner
    # conserved either way: same entry count, same total units
    assert len(ledger.entries()) == n_before
    assert sum(e.units for e in ledger.entries()) == units_before
    ledger.check()


@given(st.lists(ops, min_size=1, max_size=30),
       st.sampled_from(TENANTS))
@settings(max_examples=60, deadline=None)
def test_release_is_idempotent(op_list, tenant):
    ledger = DeviceLedger(TOPO, budget_per_gpu=7)
    for op in op_list:
        apply_op(ledger, op)
    ledger.release(tenant)
    view_once = ledger.view()
    assert ledger.release(tenant) == 0       # second release: no-op
    assert ledger.view() == view_once
    assert ledger.slots_of(tenant) == []
    ledger.check()


@given(st.lists(ops, min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_view_is_consistent_with_queries(op_list):
    ledger = DeviceLedger(TOPO, budget_per_gpu=7, home_devices=("h0:g0",),
                          ambient_units=3)
    for op in op_list:
        apply_op(ledger, op)
    view = ledger.view()
    for dev in TOPO.devices():
        assert view["units"][dev] == ledger.used_units(dev)
        assert view["headroom"][dev] == ledger.headroom_units(dev)
        ambient = 0 if dev == "h0:g0" else 3
        assert view["headroom"][dev] == max(
            0, 7 - view["units"][dev] - ambient)
    for key, owner in view["occupancy"].items():
        assert ledger.owner_of(key) == owner


# ------------------------------------------------- registry construction
def test_from_registry_matches_resolved_placements():
    topo = make_p4d_cluster(2)
    reg = TenantRegistry.slo_fleet(4, 2)
    placements = reg.resolve_placements(topo)
    ledger = DeviceLedger.from_registry(topo, reg, A100_MIG, placements)
    for spec in reg:
        keys = [s.key for s in ledger.slots_of(spec.name)]
        want = [s.key for s in placements[spec.name]]
        if spec.is_latency:
            assert keys == want
        else:
            assert keys == want[:1] or keys == want
    ledger.check()
    # ETL's fabric demand lands on its root
    etl_root = topo.root_of(ledger.slots_of("ETL")[0].device)
    assert ledger.root_demand(etl_root) >= reg["ETL"].pcie_demand


def test_occupy_rejects_oversubscription_and_taken_slot():
    ledger = DeviceLedger(TOPO, budget_per_gpu=7)
    ledger.occupy("A", Slot(0, "h0:g0", 0), 4)
    with pytest.raises(LedgerError):
        ledger.occupy("B", Slot(0, "h0:g0", 1), 4)       # 8 > 7 units
    with pytest.raises(LedgerError):
        ledger.occupy("C", Slot(0, "h0:g0", 0), 1)       # slot taken
    ledger.occupy("B", Slot(0, "h0:g0", 1), 3)           # exactly 7: fits
    assert ledger.used_units("h0:g0") == 7
    with pytest.raises(LedgerError):
        ledger.set_units("B", 4)                          # resize past 7
    assert ledger.used_units("h0:g0") == 7
