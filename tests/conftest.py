import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py uses 512 placeholders.

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
