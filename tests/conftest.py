import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py uses 512 placeholders.

jax.config.update("jax_platform_name", "cpu")

# The pinned container image does not ship `hypothesis`; fall back to the
# deterministic sampling stub so property tests still run (see
# tests/_hypothesis_stub.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import os
    import sys
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import install as _install_hypothesis_stub
    _install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier2: sim<->serving parity / property suites, run as a separate "
        "non-blocking CI job (select with -m tier2)")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
