"""Paged decode runtime: dense-vs-paged token parity, chunked prefill,
SLO-aware preemption, and page-accounting invariants — all on CPU, with
the Pallas paged-attention kernel exercised in interpret mode."""
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.serving.engine import ServingEngine
from repro.serving.request import Request

# float32 keeps the two backends bit-identical (the bf16 KV cache is
# value-identical too, but fp32 removes any tie-breaking ambiguity from
# the token-parity assertions)
CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")

# mixed long/short trace: (prompt_len, max_new_tokens)
TRACE = [(40, 4), (7, 8), (21, 2), (3, 6), (60, 3)]


def make_trace(seed=0, trace=TRACE, **kw):
    rng = np.random.default_rng(seed)
    return [Request(req_id=i, tenant="T1", prompt_len=pl, max_new_tokens=mn,
                    arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, pl), **kw)
            for i, (pl, mn) in enumerate(trace)]


def drain(eng, max_steps=800):
    reports = []
    while eng.has_work():
        rep = eng.step()
        eng.finalize_step(rep, float(len(reports)))
        reports.append(rep)
        assert len(reports) < max_steps, "engine did not converge"
    return reports


def assert_no_leaks(eng):
    assert eng.kv.used_pages == 0
    assert eng.kv.reserved_pages == 0
    assert len(eng.kv.free) == eng.kv.num_pages
    assert not eng.kv.tables


# ----------------------------------------------------------------- parity
@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_paged_dense_token_parity(impl):
    """Same mixed long/short trace through both backends -> identical
    output tokens; 'kernel' runs the Pallas kernel in interpret mode."""
    dense = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0)
    paged = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                          backend="paged", chunk_tokens=16, attn_impl=impl)
    reqs_d, reqs_p = make_trace(), make_trace()
    for r in reqs_d:
        assert dense.submit(r)
    for r in reqs_p:
        assert paged.submit(r)
    drain(dense)
    drain(paged)
    for rd, rp in zip(reqs_d, reqs_p):
        assert rd.done and rp.done
        assert len(rd.output_tokens) == rd.max_new_tokens
        assert rd.output_tokens == rp.output_tokens, \
            f"req {rd.req_id}: {rd.output_tokens} != {rp.output_tokens}"
    assert_no_leaks(paged)
    assert_no_leaks(dense)


def test_paged_accounting_during_run():
    """Reserved/used stay within the pool at every step and reserved >=
    used (grow-on-demand never marks unreserved pages live)."""
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=16, attn_impl="ref")
    for r in make_trace(seed=3):
        assert eng.submit(r)
    while eng.has_work():
        rep = eng.step()
        assert 0 <= eng.kv.used_pages <= eng.kv.reserved_pages \
            <= eng.kv.num_pages
        owned = [p for e in eng.kv.tables.values() for p in e.pages]
        assert len(owned) == len(set(owned)), "page owned twice"
        assert len(owned) + len(eng.kv.free) == eng.kv.num_pages
        eng.finalize_step(rep, 0.0)
    assert_no_leaks(eng)


# -------------------------------------------------------- chunked prefill
def test_chunked_prefill_bounds_per_step_tokens():
    chunk = 16
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=chunk, attn_impl="ref")
    rng = np.random.default_rng(5)
    req = Request(req_id=0, tenant="T1", prompt_len=60, max_new_tokens=2,
                  arrival=0.0,
                  prompt_tokens=rng.integers(0, CFG.vocab_size, 60))
    assert eng.submit(req)
    reports = drain(eng)
    prefills = [r for r in reports if r.kind == "prefill"]
    assert all(r.tokens <= chunk for r in prefills)
    assert sum(r.tokens for r in prefills) == 60
    assert len(prefills) == 4          # ceil(60/16)
    assert req.done and len(req.output_tokens) == 2


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt must not head-of-line-block a running decode: between
    its chunks the scheduler keeps emitting decode steps."""
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=16, attn_impl="ref")
    rng = np.random.default_rng(7)
    short = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=12,
                    arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    long_ = Request(req_id=1, tenant="T1", prompt_len=64, max_new_tokens=2,
                    arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 64))
    assert eng.submit(short) and eng.submit(long_)
    kinds = [r.kind for r in drain(eng)]
    # the short request's prefill is step 0; the long prompt then needs 4
    # chunks, and every consecutive pair of them must be separated by a
    # decode step that advances the short request
    first_decode = kinds.index("decode")
    chunk_steps = [i for i, k in enumerate(kinds) if k == "prefill"][1:]
    assert len(chunk_steps) == 4
    for a, b in zip(chunk_steps, chunk_steps[1:]):
        assert "decode" in kinds[a + 1:b], \
            f"prefill chunks at {a},{b} not interleaved with decode: {kinds}"
    assert first_decode < chunk_steps[-1]
    assert short.done and long_.done
    assert_no_leaks(eng)


# ------------------------------------------------------------- preemption
def _overcommitted_engine(**kw):
    # pool of 6 pages x 4 tokens; two 16-token sequences need 8 pages
    return ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4, seed=0,
                         backend="paged", pool_pages=6, chunk_tokens=8,
                         attn_impl="ref", **kw)


def test_preemption_evicts_by_slo_priority_and_requeues():
    eng = _overcommitted_engine()
    rng = np.random.default_rng(11)
    hi = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, slo_ms=50.0, priority=2.0,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    lo = Request(req_id=1, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=0.5,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    assert eng.submit(hi) and eng.submit(lo)
    reports = drain(eng)
    preempted_ids = [r.req_id for rep in reports for r in rep.preempted]
    log = eng.runtime.sched.preempt_log
    assert preempted_ids or log, "overcommitted pool never preempted"
    # only the low-priority request is ever evicted
    assert set(r for r, _ in log) == {lo.req_id}
    # both (including the requeued victim) run to completion
    assert hi.done and len(hi.output_tokens) == hi.max_new_tokens
    assert lo.done and len(lo.output_tokens) == lo.max_new_tokens
    assert_no_leaks(eng)


def test_preempted_sequence_regenerates_identical_tokens():
    """Recompute-style preemption + greedy decode: the victim's restart
    must reproduce the tokens an uncontended run produces."""
    rng = np.random.default_rng(13)
    toks = rng.integers(0, CFG.vocab_size, 8)

    solo = ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4, seed=0,
                         backend="paged", chunk_tokens=8, attn_impl="ref")
    ref_req = Request(req_id=9, tenant="T1", prompt_len=8, max_new_tokens=8,
                      arrival=0.0, prompt_tokens=toks.copy())
    assert solo.submit(ref_req)
    drain(solo)

    eng = _overcommitted_engine()
    hi = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=2.0,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    lo = Request(req_id=1, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=0.5, prompt_tokens=toks.copy())
    assert eng.submit(hi) and eng.submit(lo)
    drain(eng)
    assert any(r == lo.req_id for r, _ in eng.runtime.sched.preempt_log)
    assert lo.output_tokens == ref_req.output_tokens
    assert_no_leaks(eng)


def test_paged_submit_rejects_only_never_fitting():
    eng = _overcommitted_engine()
    # 6 pages x 4 tokens = 24-token pool; 32-token footprint can never fit
    assert not eng.submit(Request(req_id=0, tenant="T1", prompt_len=16,
                                  max_new_tokens=16, arrival=0.0))
    # an overcommitting-but-feasible request is accepted (dense would
    # reject the second one at submit)
    assert eng.submit(Request(req_id=1, tenant="T1", prompt_len=12,
                              max_new_tokens=8, arrival=0.0))
    assert eng.submit(Request(req_id=2, tenant="T1", prompt_len=12,
                              max_new_tokens=8, arrival=0.0))
    drain(eng)
    assert_no_leaks(eng)


# ------------------------------------------------- kv-cache satellite fixes
def test_block_table_overflow_raises():
    from repro.serving.kvcache import PagedKVCache
    kv = PagedKVCache(num_pages=8, page_size=4)
    kv.allocate(1, prompt_len=12)           # 3 pages
    with pytest.raises(ValueError):
        kv.block_table(1, pages_per_seq=2)  # too narrow: must not truncate
    bt = kv.block_table(1, pages_per_seq=4)
    assert list(bt[:3]) == kv.tables[1].pages


def test_reserved_vs_used_pages_diverge_under_dense_reservation():
    from repro.serving.kvcache import PagedKVCache
    kv = PagedKVCache(num_pages=16, page_size=4)
    kv.allocate(1, prompt_len=4, reserve_total=16)   # 4 pages reserved
    assert kv.reserved_pages == 4
    assert kv.used_pages == 1                        # only the prompt live
    for _ in range(4):
        kv.append_token(1)
    assert kv.used_pages == 2 and kv.reserved_pages == 4
    kv.release(1)
    assert kv.reserved_pages == 0 and kv.used_pages == 0


def test_engine_metrics_report_both_kv_gauges():
    eng = ServingEngine(CFG, max_slots=2, seq_cap=32, page_size=8, seed=0)
    assert eng.submit(Request(req_id=0, tenant="T1", prompt_len=8,
                              max_new_tokens=16, arrival=0.0))
    eng.finalize_step(eng.step(), 0.0)      # prefill
    m = eng.metrics
    assert m.kv_total_pages == eng.kv.num_pages
    # dense reservation: prompt+max_new reserved, only prompt-ish live
    assert m.kv_reserved_pages == 3 and m.kv_used_pages == 1
    assert m.kv_utilisation() > m.kv_live_utilisation() > 0
    drain(eng)
