"""Paged serving runtime: dense-vs-paged token parity through the fused
mixed prefill+decode step, per-step token budgets, prefix-cache sharing,
SLO-aware preemption, and refcount/page-accounting invariants — all on
CPU, with the Pallas paged-attention kernel exercised in interpret mode."""
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request

# float32 keeps the two backends bit-identical (the bf16 KV cache is
# value-identical too, but fp32 removes any tie-breaking ambiguity from
# the token-parity assertions)
CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")

# mixed long/short trace: (prompt_len, max_new_tokens)
TRACE = [(40, 4), (7, 8), (21, 2), (3, 6), (60, 3)]


def make_trace(seed=0, trace=TRACE, **kw):
    rng = np.random.default_rng(seed)
    return [Request(req_id=i, tenant="T1", prompt_len=pl, max_new_tokens=mn,
                    arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, pl), **kw)
            for i, (pl, mn) in enumerate(trace)]


def drain(eng, max_steps=800):
    reports = []
    while eng.has_work():
        rep = eng.step()
        eng.finalize_step(rep, float(len(reports)))
        reports.append(rep)
        assert len(reports) < max_steps, "engine did not converge"
    return reports


def assert_no_leaks(eng):
    """After a drain no sequence holds pages; only refcount-zero prefix
    pages may remain parked on the cached LRU (reclaimable capacity)."""
    kv = eng.kv
    assert kv.used_pages == 0
    assert kv.reserved_pages == 0
    assert not kv.tables
    assert len(kv.free) + kv.cached_pages == kv.num_pages
    assert all(kv.ref.get(p, 0) == 0 for p in kv.cached)


def assert_refcount_invariants(kv: PagedKVCache):
    """Every page is exactly one of {free, cached, owned}; refcounts equal
    the number of tables referencing the page; no page is freed while it
    has live sharers."""
    owned = {}
    for e in kv.tables.values():
        seen = set()
        for p in e.pages:
            assert p not in seen, "page mapped twice in one sequence"
            seen.add(p)
            owned[p] = owned.get(p, 0) + 1
    for p, n in owned.items():
        assert kv.ref.get(p) == n, f"page {p}: ref {kv.ref.get(p)} != {n}"
        assert p not in kv.free and p not in kv.cached, \
            f"owned page {p} also free/cached"
    for p in kv.cached:
        assert p not in kv.free and p not in owned
        assert kv.ref.get(p, 0) == 0
    assert len(owned) + len(set(kv.free)) + len(kv.cached) == kv.num_pages
    assert len(kv.free) == len(set(kv.free)), "free list duplicate"
    assert 0 <= kv.used_pages <= kv.reserved_pages <= kv.num_pages


# ----------------------------------------------------------------- parity
@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_paged_dense_token_parity(impl):
    """Same mixed long/short trace through both backends -> identical
    output tokens; 'kernel' runs the ragged Pallas kernel in interpret
    mode.  The paged side now serves everything through the fused mixed
    step (decode lanes + prefill chunks in one jitted call)."""
    dense = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0)
    paged = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                          backend="paged", chunk_tokens=16, attn_impl=impl)
    reqs_d, reqs_p = make_trace(), make_trace()
    for r in reqs_d:
        assert dense.submit(r)
    for r in reqs_p:
        assert paged.submit(r)
    drain(dense)
    reports = drain(paged)
    for rd, rp in zip(reqs_d, reqs_p):
        assert rd.done and rp.done
        assert len(rd.output_tokens) == rd.max_new_tokens
        assert rd.output_tokens == rp.output_tokens, \
            f"req {rd.req_id}: {rd.output_tokens} != {rp.output_tokens}"
    # the fused step actually fused: some steps carried prefill AND decode
    assert any(r.kind == "mixed" for r in reports)
    assert_no_leaks(paged)
    assert_no_leaks(dense)


def test_paged_accounting_during_run():
    """Refcount/occupancy invariants hold at every step (shared pages
    counted once, refcounts consistent, free/cached/owned partition the
    pool)."""
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=16, attn_impl="ref")
    for r in make_trace(seed=3):
        assert eng.submit(r)
    while eng.has_work():
        rep = eng.step()
        assert_refcount_invariants(eng.kv)
        eng.finalize_step(rep, 0.0)
    assert_no_leaks(eng)


# --------------------------------------------------- fused mixed stepping
def test_step_token_budget_bounds_every_step():
    """Per-step work never exceeds the fused token budget, and a single
    prompt's chunks are bounded by chunk_tokens."""
    chunk = 16
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=chunk, attn_impl="ref")
    budget = eng.runtime.sched.step_token_budget()
    rng = np.random.default_rng(5)
    req = Request(req_id=0, tenant="T1", prompt_len=60, max_new_tokens=2,
                  arrival=0.0,
                  prompt_tokens=rng.integers(0, CFG.vocab_size, 60))
    assert eng.submit(req)
    reports = drain(eng)
    prefills = [r for r in reports if r.prefill_tokens]
    assert all(r.tokens <= budget for r in reports)
    assert all(r.prefill_tokens <= chunk for r in prefills)
    assert sum(r.prefill_tokens for r in prefills) == 60
    assert len(prefills) == 4          # ceil(60/16)
    assert req.done and len(req.output_tokens) == 2


def test_mixed_step_decode_never_stalls_on_admission():
    """The head-of-line fix: while a long prompt chunk-prefills, every one
    of its chunk steps ALSO decodes the already-running sequence in the
    same fused call — admissions consume prefill budget, never decode
    steps (under PR 3's interleave each chunk stalled all decode lanes)."""
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=16, attn_impl="ref")
    rng = np.random.default_rng(7)
    short = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=12,
                    arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    assert eng.submit(short)
    # get the short request decoding before the long prompt arrives
    while not short.generated:
        eng.finalize_step(eng.step(), 0.0)
    long_ = Request(req_id=1, tenant="T1", prompt_len=64, max_new_tokens=2,
                    arrival=0.0,
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 64))
    assert eng.submit(long_)
    stalled = []
    while eng.has_work():
        rep = eng.step()
        if rep.prefill_tokens and not short.done:
            # the long prompt's chunk rode WITH the short seq's decode
            stalled.append(rep.decode_tokens == 0)
            assert rep.kind == "mixed"
        eng.finalize_step(rep, 0.0)
    assert stalled and not any(stalled), \
        f"decode stalled during {sum(stalled)}/{len(stalled)} chunk steps"
    assert short.done and long_.done
    assert_no_leaks(eng)


# ---------------------------------------------------- prefix-cache sharing
def _shared_engine(**kw):
    return ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                         backend="paged", chunk_tokens=16, attn_impl="ref",
                         **kw)


def test_prefix_hit_parity_and_compute_skip():
    """A request sharing a warm prompt prefix produces IDENTICAL tokens to
    a cold run while prefilling only the tail (page-aligned prefix served
    from shared pages)."""
    rng = np.random.default_rng(21)
    toks = rng.integers(0, CFG.vocab_size, 40)     # 5 pages, 4 shareable

    cold = _shared_engine(prefix_cache=False)
    r_cold = Request(req_id=0, tenant="T1", prompt_len=40, max_new_tokens=6,
                     arrival=0.0, prompt_tokens=toks.copy())
    assert cold.submit(r_cold)
    drain(cold)

    eng = _shared_engine()
    r1 = Request(req_id=1, tenant="T1", prompt_len=40, max_new_tokens=6,
                 arrival=0.0, prompt_tokens=toks.copy())
    assert eng.submit(r1)
    drain(eng)
    assert eng.metrics.prefill_tokens_total == 40      # cold: full prompt
    assert r1.output_tokens == r_cold.output_tokens

    r2 = Request(req_id=2, tenant="T1", prompt_len=40, max_new_tokens=6,
                 arrival=1.0, prompt_tokens=toks.copy())
    assert eng.submit(r2)
    drain(eng)
    # (40-1)//8 = 4 full pages = 32 tokens came from the cache; only the
    # 8-token tail was prefilled
    assert eng.metrics.prefix_hit_tokens_total == 32
    assert eng.metrics.prefill_tokens_total == 48
    assert eng.metrics.prefix_hit_rate() == pytest.approx(32 / 80)
    assert r2.output_tokens == r_cold.output_tokens
    assert_no_leaks(eng)


def test_prefix_pages_shared_live_with_refcounts():
    """Two live requests with the same prompt share physical pages
    (refcount 2) and the pages are never freed while shared."""
    rng = np.random.default_rng(23)
    toks = rng.integers(0, CFG.vocab_size, 40)
    eng = _shared_engine()
    r1 = Request(req_id=0, tenant="T1", prompt_len=40, max_new_tokens=20,
                 arrival=0.0, prompt_tokens=toks.copy())
    assert eng.submit(r1)
    while not r1.generated:                 # r1 decoding, pages committed
        eng.finalize_step(eng.step(), 0.0)
    r2 = Request(req_id=1, tenant="T1", prompt_len=40, max_new_tokens=4,
                 arrival=0.0, prompt_tokens=toks.copy())
    assert eng.submit(r2)
    saw_shared = False
    while eng.has_work():
        assert_refcount_invariants(eng.kv)
        if any(n == 2 for n in eng.kv.ref.values()):
            saw_shared = True
        eng.finalize_step(eng.step(), 0.0)
    assert saw_shared, "prompts never shared a physical page"
    assert r1.output_tokens[:4] == r2.output_tokens[:4]
    assert_no_leaks(eng)


def test_prefix_cache_eviction_reclaims_capacity():
    """Cached refcount-zero prefix pages are transparently reclaimed when
    fresh allocations need them (no MemoryError, no stale index)."""
    rng = np.random.default_rng(25)
    eng = ServingEngine(CFG, max_slots=2, seq_cap=64, page_size=8, seed=0,
                        backend="paged", pool_pages=8, chunk_tokens=16,
                        attn_impl="ref")
    for i in range(4):                    # distinct prompts, 4 pages each
        r = Request(req_id=i, tenant="T1", prompt_len=32, max_new_tokens=2,
                    arrival=float(i),
                    prompt_tokens=rng.integers(0, CFG.vocab_size, 32))
        assert eng.submit(r)
        drain(eng)
        assert r.done
        assert_refcount_invariants(eng.kv)
    assert_no_leaks(eng)


# ------------------------------------------------------------- preemption
def _overcommitted_engine(**kw):
    # pool of 6 pages x 4 tokens; two 16-token sequences need 8 pages
    return ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4, seed=0,
                         backend="paged", pool_pages=6, chunk_tokens=8,
                         attn_impl="ref", **kw)


def test_preemption_evicts_by_slo_priority_and_requeues():
    eng = _overcommitted_engine()
    rng = np.random.default_rng(11)
    hi = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, slo_ms=50.0, priority=2.0,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    lo = Request(req_id=1, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=0.5,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    assert eng.submit(hi) and eng.submit(lo)
    reports = drain(eng)
    preempted_ids = [r.req_id for rep in reports for r in rep.preempted]
    log = eng.runtime.sched.preempt_log
    assert preempted_ids or log, "overcommitted pool never preempted"
    # only the low-priority request is ever evicted
    assert set(r for r, _ in log) == {lo.req_id}
    # both (including the requeued victim) run to completion
    assert hi.done and len(hi.output_tokens) == hi.max_new_tokens
    assert lo.done and len(lo.output_tokens) == lo.max_new_tokens
    assert_no_leaks(eng)


def test_preempted_sequence_regenerates_identical_tokens():
    """Recompute-style preemption + greedy decode: the victim's restart
    must reproduce the tokens an uncontended run produces (the restart
    may legally ride a prefix hit on its own surviving cached pages)."""
    rng = np.random.default_rng(13)
    toks = rng.integers(0, CFG.vocab_size, 8)

    solo = ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4, seed=0,
                         backend="paged", chunk_tokens=8, attn_impl="ref")
    ref_req = Request(req_id=9, tenant="T1", prompt_len=8, max_new_tokens=8,
                      arrival=0.0, prompt_tokens=toks.copy())
    assert solo.submit(ref_req)
    drain(solo)

    eng = _overcommitted_engine()
    hi = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=2.0,
                 prompt_tokens=rng.integers(0, CFG.vocab_size, 8))
    lo = Request(req_id=1, tenant="T1", prompt_len=8, max_new_tokens=8,
                 arrival=0.0, priority=0.5, prompt_tokens=toks.copy())
    assert eng.submit(hi) and eng.submit(lo)
    drain(eng)
    assert any(r == lo.req_id for r, _ in eng.runtime.sched.preempt_log)
    assert lo.output_tokens == ref_req.output_tokens
    assert_no_leaks(eng)


def test_refcount_invariants_under_churn_and_preemption():
    """Shared-prefix traffic on an overcommitted pool: preemption,
    prefix reuse, and cached-page eviction interleave, and the refcount
    invariants must hold at every step (no page freed while shared, zero
    leaks after the churn)."""
    rng = np.random.default_rng(31)
    common = rng.integers(0, CFG.vocab_size, 8)     # 2 shared pages
    eng = ServingEngine(CFG, max_slots=3, seq_cap=32, page_size=4, seed=0,
                        backend="paged", pool_pages=10, chunk_tokens=8,
                        attn_impl="ref")
    reqs = []
    for i in range(6):
        tail = rng.integers(0, CFG.vocab_size, 4)
        reqs.append(Request(
            req_id=i, tenant="T1", prompt_len=12, max_new_tokens=6,
            arrival=float(i), priority=float(rng.integers(0, 3)),
            prompt_tokens=np.concatenate([common, tail])))
    for r in reqs[:3]:
        assert eng.submit(r)
    steps = 0
    while eng.has_work():
        if steps == 4:
            for r in reqs[3:]:
                assert eng.submit(r)
        rep = eng.step()
        assert_refcount_invariants(eng.kv)
        eng.finalize_step(rep, float(steps))
        steps += 1
        assert steps < 800
    assert all(r.done for r in reqs)
    assert eng.metrics.prefix_hit_tokens_total > 0, "churn never hit prefix"
    assert_no_leaks(eng)


def test_paged_submit_rejects_only_never_fitting():
    eng = _overcommitted_engine()
    # 6 pages x 4 tokens = 24-token pool; 32-token footprint can never fit
    assert not eng.submit(Request(req_id=0, tenant="T1", prompt_len=16,
                                  max_new_tokens=16, arrival=0.0))
    # an overcommitting-but-feasible request is accepted (dense would
    # reject the second one at submit)
    assert eng.submit(Request(req_id=1, tenant="T1", prompt_len=12,
                              max_new_tokens=8, arrival=0.0))
    assert eng.submit(Request(req_id=2, tenant="T1", prompt_len=12,
                              max_new_tokens=8, arrival=0.0))
    drain(eng)
    assert_no_leaks(eng)


# ------------------------------------------------- kv-cache satellite fixes
def test_release_unknown_or_double_raises():
    """Regression: a silent release of an unknown/already-released seq_id
    would push its pages onto the free list twice and hand the same page
    to two sequences."""
    kv = PagedKVCache(num_pages=8, page_size=4)
    with pytest.raises(KeyError):
        kv.release(7)
    kv.allocate(1, prompt_len=8)
    kv.release(1)
    with pytest.raises(KeyError):
        kv.release(1)
    assert len(kv.free) == 8            # no double-free corruption


def test_preemption_path_guards_double_release():
    """The scheduler's preempt/complete paths must tolerate a sequence
    whose pages were already released (e.g. evicted while planned) without
    tripping the strict release() or corrupting the free list."""
    from repro.serving.sched import PagedScheduler, SchedConfig, SeqState
    kv = PagedKVCache(num_pages=8, page_size=4, enable_prefix_cache=False)
    sched = PagedScheduler(kv, SchedConfig(chunk_tokens=8, max_active=2))
    req = Request(req_id=0, tenant="T1", prompt_len=8, max_new_tokens=2,
                  arrival=0.0,
                  prompt_tokens=np.zeros(8, np.int64))
    assert sched.submit(req)
    plan = sched.plan()
    assert plan.prefills
    seq = plan.prefills[0][0]
    sched.preempt(seq)                  # releases pages, requeues
    sched.preempt(seq)                  # double-preempt: must be safe
    sched.complete(seq)                 # and complete-after-release too
    assert len(kv.free) == 8
    assert not kv.tables


def test_block_table_overflow_raises():
    kv = PagedKVCache(num_pages=8, page_size=4)
    kv.allocate(1, prompt_len=12)           # 3 pages
    with pytest.raises(ValueError):
        kv.block_table(1, pages_per_seq=2)  # too narrow: must not truncate
    bt = kv.block_table(1, pages_per_seq=4)
    assert list(bt[:3]) == kv.tables[1].pages


def test_reserved_vs_used_pages_diverge_under_dense_reservation():
    kv = PagedKVCache(num_pages=16, page_size=4)
    kv.allocate(1, prompt_len=4, reserve_total=16)   # 4 pages reserved
    assert kv.reserved_pages == 4
    assert kv.used_pages == 1                        # only the prompt live
    for _ in range(4):
        kv.append_token(1)
    assert kv.used_pages == 2 and kv.reserved_pages == 4
    kv.release(1)
    assert kv.reserved_pages == 0 and kv.used_pages == 0


def test_engine_metrics_report_both_kv_gauges():
    eng = ServingEngine(CFG, max_slots=2, seq_cap=32, page_size=8, seed=0)
    assert eng.submit(Request(req_id=0, tenant="T1", prompt_len=8,
                              max_new_tokens=16, arrival=0.0))
    eng.finalize_step(eng.step(), 0.0)      # prefill
    m = eng.metrics
    assert m.kv_total_pages == eng.kv.num_pages
    # dense reservation: prompt+max_new reserved, only prompt-ish live
    assert m.kv_reserved_pages == 3 and m.kv_used_pages == 1
    assert m.kv_utilisation() > m.kv_live_utilisation() > 0
    drain(eng)


# ---------------------------------------------------------- int8 page pools
def _first_step_logits(eng, req):
    """Capture the fused step's logits for the lane serving ``req``."""
    rt = eng.runtime
    captured = {}
    orig = rt._run_mixed

    def wrap(*args):
        logits, dt = orig(*args)
        captured["logits"] = logits
        return logits, dt

    rt._run_mixed = wrap
    try:
        assert eng.submit(req)
        eng.finalize_step(eng.step(), 0.0)
    finally:
        rt._run_mixed = orig
    return np.asarray(captured["logits"], np.float32)


def test_int8_pages_logits_close_and_pool_halved():
    """kv_dtype='int8' quantizes the page pools (int8 K/V + per-page-row
    scales) and the first-step logits stay within the same tolerance the
    dense REPRO_KV_INT8 harness (tests/test_kv_quant.py) enforces."""
    import jax
    import jax.numpy as jnp
    from repro.models.model import Model
    params = Model(CFG).init(jax.random.key(1))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, CFG.vocab_size, 12)

    def make(kv_dtype):
        return ServingEngine(CFG, params=params, max_slots=2, seq_cap=32,
                             page_size=8, seed=0, backend="paged",
                             chunk_tokens=16, attn_impl="ref",
                             kv_dtype=kv_dtype)

    def req():
        return Request(req_id=0, tenant="T1", prompt_len=12,
                       max_new_tokens=2, arrival=0.0,
                       prompt_tokens=toks.copy())

    eng_f = make("auto")
    eng_q = make("int8")
    pool = eng_q.runtime.pools["period"]["sub0"]
    assert pool["k"].dtype == jnp.int8 and "k_scale" in pool
    # int8 halves the page bytes (+ small f32 scale overhead)
    kv_bytes = pool["k"].nbytes + pool["k_scale"].nbytes
    assert kv_bytes < 0.55 * (2 * pool["k"].size *
                              jnp.dtype(CFG.dtype).itemsize)
    lg_f = _first_step_logits(eng_f, req())[0]
    lg_q = _first_step_logits(eng_q, req())[0]
    err = np.max(np.abs(lg_q - lg_f))
    ref = np.max(np.abs(lg_f)) + 1e-6
    assert err / ref < 0.08, f"relative logits error {err/ref:.3f}"


def test_int8_pages_full_run_no_leaks():
    eng = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0,
                        backend="paged", chunk_tokens=16, attn_impl="ref",
                        kv_dtype="int8")
    reqs = make_trace(seed=9)
    for r in reqs:
        assert eng.submit(r)
    drain(eng)
    assert all(r.done and len(r.output_tokens) == r.max_new_tokens
               for r in reqs)
    assert_no_leaks(eng)


def test_int8_on_dense_backend_rejected():
    with pytest.raises(ValueError):
        ServingEngine(CFG, backend="dense", kv_dtype="int8")
