"""Registry-driven admission (paper §2.3): the admit/queue/reject matrix
over fabric-saturated vs. rho-bound-violating vs. safe tenants, verdicts
committing to the shared DeviceLedger, and a QUEUE'd tenant admitting once
a slot frees."""
import pytest

from repro.core.admission import (AdmissionConfig, AdmissionController,
                                  AdmissionVerdict)
from repro.core.ledger import DeviceLedger
from repro.core.profiles import A100_MIG
from repro.core.tenancy import BACKGROUND, TenantRegistry, TenantSpec
from repro.core.topology import ClusterTopology, make_p4d_cluster


def make_stack(topo=None, specs=(), cfg=AdmissionConfig(), **ledger_kw):
    topo = topo or make_p4d_cluster(1)
    reg = TenantRegistry(specs)
    ledger = DeviceLedger.from_registry(topo, reg, A100_MIG, **ledger_kw)
    return topo, reg, ledger, AdmissionController(topo, reg, ledger, cfg)


SIZES = ((1.0, 12e6),)


def safe_spec(name="NEW", **kw):
    kw.setdefault("rate", 6.0)
    kw.setdefault("sizes", SIZES)
    return TenantSpec(name=name, **kw)


# ------------------------------------------------------------ the matrix
def test_safe_tenant_admitted_and_ledger_updated():
    topo, reg, ledger, adm = make_stack(
        specs=[TenantSpec(name="T1", sizes=SIZES,
                          placement=("h0:g0:s0",))])
    free_before = len(ledger.free_slots())
    verdict, slots = adm.decide(safe_spec())
    assert verdict == AdmissionVerdict.ADMIT
    assert len(slots) == 1
    assert ledger.owner_of(slots[0].key) == "NEW/r0"
    assert len(ledger.free_slots()) == free_before - 1
    assert "NEW" in reg                       # registry expanded
    assert reg["NEW"].placement == (slots[0].key,)
    # the pinned placement keeps resolve_placements stable
    resolved = reg.resolve_placements(topo)
    assert [s.key for s in resolved["NEW"]] == [slots[0].key]
    ledger.check()


def test_fabric_saturated_tenant_queued_then_rejected():
    """Claim-1: a demand that saturates every root finds no safe slot."""
    topo, reg, ledger, adm = make_stack(cfg=AdmissionConfig(max_queue=1))
    heavy = TenantSpec(name="ETL9", role=BACKGROUND, pcie_demand=30e9)
    verdict, slots = adm.decide(heavy)
    assert verdict == AdmissionVerdict.QUEUE and slots is None
    heavy2 = TenantSpec(name="ETL10", role=BACKGROUND, pcie_demand=30e9)
    verdict, _ = adm.decide(heavy2)
    assert verdict == AdmissionVerdict.REJECT
    assert adm.counts() == {"admit": 0, "queue": 1, "reject": 1}
    assert "ETL9" not in reg and "ETL10" not in reg
    ledger.check()


def test_rho_bound_violating_tenant_not_admitted():
    """Kingman guidance: a newcomer whose own rho = lambda E[S] exceeds
    the bound is unsafe on every root."""
    topo, reg, ledger, adm = make_stack()
    hot = safe_spec("HOT", rate=200.0)       # rho >> 0.85 at any share
    verdict, slots = adm.decide(hot)
    assert verdict == AdmissionVerdict.QUEUE and slots is None


def test_rho_bound_protects_existing_tenant():
    """A newcomer that would push a *resident* latency tenant over the
    rho bound is kept off that root."""
    topo = ClusterTopology(num_hosts=1, devices_per_host=2,
                           devices_per_root=2, numa_per_host=1,
                           slots_per_device=2)          # one root complex
    # resident rho ~ 0.82 at full fabric share; halving its share (one
    # more PS flow on the root) pushes it to ~ 0.88 > 0.85
    resident = TenantSpec(name="R", rate=110.0, sizes=SIZES,
                          placement=("h0:g0:s0",))
    topo, reg, ledger, adm = make_stack(topo, [resident])
    verdict, slots = adm.decide(safe_spec())
    assert verdict == AdmissionVerdict.QUEUE and slots is None
    assert "NEW" not in reg


def test_unit_feasibility_respects_gpu_budget():
    """A 7g slice only fits a device with 7 free units."""
    specs = [TenantSpec(name=f"L{i}", sizes=SIZES, rate=1.0,
                        placement=(f"h0:g{i}:s0",)) for i in range(8)]
    topo, reg, ledger, adm = make_stack(specs=specs)
    big = safe_spec("BIG", rate=1.0, profile="7g.80gb")
    verdict, slots = adm.decide(big)
    assert verdict == AdmissionVerdict.QUEUE      # every device has 2u used
    ledger.release("L3")
    reg.remove("L3")
    admitted = adm.retry_queued()
    assert [s.name for s, _ in admitted] == ["BIG"]
    assert ledger.slots_of("BIG")[0].device == "h0:g3"


def test_queued_tenant_admits_once_slot_frees():
    """The paper's QUEUE verdict is a promise: departures re-trigger
    placement and the queued tenant lands."""
    topo = ClusterTopology(num_hosts=1, devices_per_host=2,
                           devices_per_root=2, numa_per_host=1,
                           slots_per_device=1)           # 2 slots total
    specs = [TenantSpec(name="A", rate=2.0, sizes=SIZES,
                        placement=("h0:g0:s0",)),
             TenantSpec(name="B", rate=2.0, sizes=SIZES,
                        placement=("h0:g1:s0",))]
    topo, reg, ledger, adm = make_stack(topo, specs)
    assert ledger.free_slots() == []
    verdict, _ = adm.decide(safe_spec(rate=2.0), now=1.0)
    assert verdict == AdmissionVerdict.QUEUE
    assert adm.retry_queued(now=2.0) == []       # still full
    adm.release("A", now=3.0)                    # departure frees a slot
    admitted = adm.retry_queued(now=3.0)
    assert [s.name for s, _ in admitted] == ["NEW"]
    assert adm.queue == []
    assert ledger.owner_of("h0:g0:s0") == "NEW/r0"
    assert "NEW" in reg and "A" not in reg
    ledger.check()


def test_multi_replica_admission_spreads_and_accounts_demand():
    topo, reg, ledger, adm = make_stack(topo=make_p4d_cluster(2))
    spec = safe_spec("MR", replicas=4, rate=8.0)
    verdict, slots = adm.decide(spec)
    assert verdict == AdmissionVerdict.ADMIT and len(slots) == 4
    keys = [s.key for s in slots]
    assert len(set(keys)) == 4                   # distinct slots
    per_rep = spec.rate * spec.mean_size / 4
    roots = {topo.root_of(s.device) for s in slots}
    for r in roots:
        assert ledger.root_demand(r) > 0
    total = sum(ledger.root_demand(r) for r in topo.roots())
    assert total == pytest.approx(per_rep * 4)


def test_duplicate_admission_refused():
    topo, reg, ledger, adm = make_stack(
        specs=[TenantSpec(name="T1", sizes=SIZES,
                          placement=("h0:g0:s0",))])
    with pytest.raises(ValueError):
        adm.decide(TenantSpec(name="T1", sizes=SIZES))


def test_duplicate_queued_name_refused_and_release_purges_queue():
    """A name can be queued at most once, and a departing tenant's
    queued copy is dropped (retry_queued stays crash-free)."""
    topo, reg, ledger, adm = make_stack(cfg=AdmissionConfig(max_queue=4))
    hot = safe_spec("HOT", rate=200.0)        # never placeable
    verdict, _ = adm.decide(hot)
    assert verdict == AdmissionVerdict.QUEUE
    with pytest.raises(ValueError):
        adm.decide(safe_spec("HOT", rate=200.0))
    assert [q.name for q in adm.queue] == ["HOT"]
    adm.release("HOT")                        # caller gives up on it
    assert adm.queue == []
    assert adm.retry_queued() == []           # nothing stale left behind
