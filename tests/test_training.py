"""Training substrate: optimizer, data pipeline (with the io.max-analogue
throttle), checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.training import checkpoint
from repro.training.data import SyntheticTokenPipeline
from repro.training.optimizer import AdamWConfig, lr_at
from repro.training.trainer import train


def test_loss_decreases_dense():
    cfg = reduced(get_config("stablelm_3b"))
    pipe = SyntheticTokenPipeline(cfg.vocab_size, batch=4, seq_len=32, seed=0)
    res = train(cfg, iter(pipe), steps=12)
    assert res.losses[-1] < res.losses[0]


def test_loss_decreases_moe():
    """Memorise one fixed batch: loss must drop through the MoE router."""
    import itertools
    cfg = reduced(get_config("mixtral_8x7b"))
    pipe = SyntheticTokenPipeline(cfg.vocab_size, batch=4, seq_len=32, seed=0)
    batch = next(iter(pipe))
    res = train(cfg, itertools.repeat(batch), steps=15,
                ocfg=AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=15))
    assert res.losses[-1] < res.losses[0] - 0.2, res.losses


def test_lr_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    end = float(lr_at(cfg, jnp.asarray(100)))
    assert end == pytest.approx(1e-4, rel=1e-2)


def test_pipeline_shapes_multimodal():
    cfg = reduced(get_config("phi_3_vision_4_2b"))
    pipe = SyntheticTokenPipeline(
        cfg.vocab_size, batch=2, seq_len=32, seed=0,
        frontend={"kind": "vision", "num_prefix": cfg.frontend.num_prefix,
                  "embed_dim": cfg.frontend.embed_dim})
    b = next(iter(pipe))
    p = cfg.frontend.num_prefix
    assert b["embeds"].shape == (2, p, cfg.frontend.embed_dim)
    assert b["tokens"].shape == (2, 32 - p)


def test_pipeline_throttle_accounts_sleeps():
    pipe = SyntheticTokenPipeline(1024, batch=8, seq_len=512, seed=0,
                                  bytes_per_s_cap=1e6)
    it = iter(pipe)
    for _ in range(3):
        next(it)
    assert pipe.stats.throttle_sleeps > 0      # cap is binding
    pipe.set_throttle(None)                    # controller releases it
    assert pipe.bytes_per_s_cap is None


def test_checkpoint_roundtrip_preserves_dtypes():
    cfg = reduced(get_config("rwkv6_1_6b"))
    from repro.models.model import Model
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.zst")
        checkpoint.save(path, params, {"step": 5})
        restored, meta = checkpoint.load(path, like=params)
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
