"""Verified KV-page shipping: export→import round-trips bit-exactly
(tokens, refcounts, chain hashes, int8 scales), a corrupted transfer is
ALWAYS detected and degrades to the recompute redrive (never a wrong
token), shared prompt prefixes attach through the destination's
chain-hash index instead of copying, migration cost is priced against
the ledger's per-root fabric demand, and the gateway's OpenMetrics
exemplars parse back to the slowest retained request per bucket.
"""
import copy
import re

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import get_config, reduced
from repro.serving.engine import ServingEngine
from repro.serving.gateway import Gateway
from repro.serving.metrics import LatencyWindow
from repro.serving.migrate import (LaneManifest, MigrationPlanner,
                                   PageImporter, _page_digest)
from repro.serving.request import Request

CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")


def mk_engine():
    # float32 + ref attention: greedy output is a pure function of the
    # prompt (batch- and restart-invariant), so cross-engine token
    # parity is assertable bit-exactly.  int8 pages so the property
    # covers the quantized pool's scale leaves too.
    return ServingEngine(CFG, max_slots=4, seq_cap=64, page_size=4,
                         seed=0, backend="paged", pool_pages=48,
                         chunk_tokens=8, attn_impl="ref", kv_dtype="int8")


def mk_req(rid, prompt_tokens, max_new=6):
    return Request(req_id=rid, tenant="T1", prompt_len=len(prompt_tokens),
                   max_new_tokens=max_new, arrival=0.0,
                   prompt_tokens=np.asarray(prompt_tokens, np.int64))


def run_out(eng, t=0.0):
    while eng.has_work():
        t += 0.01
        eng.finalize_step(eng.step(), t, t - 0.01)
    return t


def reference_tokens(prompts, max_new=6):
    """Fault-free greedy outputs, one fresh engine."""
    eng = mk_engine()
    reqs = [mk_req(i, p, max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        assert eng.submit(r)
    run_out(eng)
    return {r.req_id: list(r.output_tokens) for r in reqs}


def dst_pool_payload(rt, pages):
    """Read back one page's leaves from a runtime's pools, in the same
    leaf-key layout the exporter serializes."""
    from repro.serving.migrate import _pool_leaves
    out = []
    for page in pages:
        payload = {}
        for key, group, name, fld in _pool_leaves(rt.pools):
            pool = rt.pools[group][name][fld]
            payload[key] = np.asarray(pool[:, page] if group == "period"
                                      else pool[page])
        out.append(payload)
    return out


# ------------------------------------------------- round-trip property
@settings(max_examples=6, deadline=None)
@given(st.data())
def test_export_import_round_trip(data):
    """Randomized lanes (mid-prefill, decoding, queued) drained with
    ``ship_state=True`` and imported into a fresh replica: every warm
    lane lands with its tokens, page bytes (int8 payloads AND scales),
    chain hashes and refcounts intact, every cold lane degrades to a
    plain resubmit, and the merged cluster finishes token-identical to
    a fault-free run."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    n_req = data.draw(st.integers(min_value=2, max_value=4))
    prompts = [rng.integers(0, CFG.vocab_size,
                            int(data.draw(st.integers(5, 20))))
               for _ in range(n_req)]
    steps = data.draw(st.integers(min_value=1, max_value=10))
    base = reference_tokens(prompts)

    src = mk_engine()
    reqs = [mk_req(i, p) for i, p in enumerate(prompts)]
    for r in reqs:
        assert src.submit(r)
    t = 0.0
    for _ in range(steps):
        if not src.has_work():
            break
        t += 0.01
        src.finalize_step(src.step(), t, t - 0.01)

    mans = src.drain_requests(ship_state=True)
    assert src.kv.reserved_pages == 0            # drain leaks nothing
    dst = mk_engine()
    imp = PageImporter(dst.runtime)
    for man in mans:
        ok = imp.import_lane(man)
        assert ok == man.warm                    # fresh dst: warm lands
        if not ok:
            assert dst.submit(man.req)
        if not man.warm:
            continue
        rid = man.req.req_id
        entry = dst.kv.tables[rid]
        assert entry.length == man.cache_tokens
        # page-for-page byte equality, chain recomputed on the DST pool
        landed = dst_pool_payload(dst.runtime, entry.pages)
        prev = b""
        for rec, payload in zip(man.pages, landed):
            assert any("scale" in k for k in payload), \
                "int8 pool must ship its scale leaves"
            for key in rec.payload:
                assert np.array_equal(np.asarray(rec.payload[key]),
                                      payload[key]), key
            prev = _page_digest(prev, rec.tokens, payload)
            assert prev == rec.digest
        for page in entry.pages:
            assert dst.kv.ref.get(page, 0) >= 1
        # cursor stamps restored: the lane resumes, it does not restart
        assert man.req.generated == man.generated
        assert list(man.req.output_tokens) == list(man.output_tokens)
    assert imp.verify_failures == 0

    run_out(dst, t)
    assert dst.kv.reserved_pages == 0
    done = {r.req_id: list(r.output_tokens) for r in reqs}
    assert done == base                          # token-identical merge


# -------------------------------------------- corruption -> recompute
def _warm_manifest():
    """One decoding lane's manifest plus its (reset) request and the
    fault-free reference output."""
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, CFG.vocab_size, 13)
    base = reference_tokens([prompt])
    src = mk_engine()
    req = mk_req(0, prompt)
    assert src.submit(req)
    t = 0.0
    for _ in range(4):                           # prefill + some decode
        t += 0.01
        src.finalize_step(src.step(), t, t - 0.01)
    (man,) = src.drain_requests(ship_state=True)
    assert man.warm and len(man.pages) >= 2
    return man, req, base[0]


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_corrupted_byte_is_always_detected(data):
    """Flip ANY single byte of ANY shipped page leaf (or a token, or
    the digest itself): the importer must reject the whole lane before
    a byte lands — destination bit-identical to before the call."""
    man, _, _ = _warm_manifest()
    man = copy.deepcopy(man)
    p = data.draw(st.integers(0, len(man.pages) - 1))
    rec = man.pages[p]
    kind = data.draw(st.sampled_from(["payload", "token", "digest"]))
    if kind == "payload":
        key = data.draw(st.sampled_from(sorted(rec.payload)))
        arr = np.array(rec.payload[key], copy=True)
        flat = arr.view(np.uint8).reshape(-1)
        flat[data.draw(st.integers(0, flat.size - 1))] ^= 0xFF
        rec.payload[key] = arr
    elif kind == "token" and rec.tokens:
        i = data.draw(st.integers(0, len(rec.tokens) - 1))
        toks = list(rec.tokens)
        toks[i] ^= 1
        rec.tokens = tuple(toks)
    else:
        rec.digest = bytes(b ^ 0xFF for b in rec.digest)
    dst = mk_engine()
    imp = PageImporter(dst.runtime)
    assert imp.import_lane(man) is False
    assert imp.verify_failures == 1
    assert not dst.kv.tables                     # nothing landed
    assert dst.kv.reserved_pages == 0
    assert not dst.runtime.sched.active and not dst.runtime.sched.prefilling


def test_rejected_lane_recomputes_token_identically():
    """The fallback path end-to-end: a corrupted transfer degrades to
    the cold resubmit and regenerates EXACTLY the fault-free tokens —
    the worst a bad transfer can cost is latency, never a wrong
    token."""
    man, req, base_out = _warm_manifest()
    bad = copy.deepcopy(man)
    key = sorted(bad.pages[0].payload)[0]
    arr = np.array(bad.pages[0].payload[key], copy=True)
    arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
    bad.pages[0].payload[key] = arr
    dst = mk_engine()
    imp = PageImporter(dst.runtime)
    assert imp.import_lane(bad) is False
    assert dst.submit(req)                       # recompute redrive
    run_out(dst)
    assert list(req.output_tokens) == base_out
    assert dst.kv.reserved_pages == 0


# ------------------------------------------------ prefix-page attach
def test_import_attaches_shared_prefix_pages():
    """A destination that already holds the lane's prompt prefix (its
    chain-hash index) adopts those pages by ref bump — zero copies for
    the shared run — and the resumed lane still finishes
    token-identical."""
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, CFG.vocab_size, 12)  # 3 full pages of 4
    dst = mk_engine()
    primer = mk_req(100, prompt)
    assert dst.submit(primer)
    run_out(dst)                                  # publishes the prefix
    assert dst.kv.prefix_index

    src = mk_engine()
    req = mk_req(0, prompt)
    assert src.submit(req)
    t = 0.0
    for _ in range(4):
        t += 0.01
        src.finalize_step(src.step(), t, t - 0.01)
    (man,) = src.drain_requests(ship_state=True)
    assert man.warm
    imp = PageImporter(dst.runtime)
    assert imp.import_lane(man)
    assert imp.attached_pages >= 2                # shared run adopted
    assert imp.copied_pages == len(man.pages) - imp.attached_pages
    entry = dst.kv.tables[0]
    assert entry.shared_tokens == imp.attached_pages * dst.kv.page_size
    run_out(dst)
    assert list(req.output_tokens) == list(primer.output_tokens)
    assert dst.kv.reserved_pages == 0


# -------------------------------------------------- fabric-aware price
def test_planner_prices_against_root_demand():
    """Transfer bandwidth is what the more contended root complex has
    left (per the ledger's demand bookkeeping), floored at ``min_frac``
    of capacity; without ledger/topology it falls back to raw
    capacity."""
    class _Fabric:
        pcie_capacity = 10e9

    class _Topo:
        def root_of(self, device):
            return device.split(":")[0]

    class _Ledger:
        def __init__(self, demand):
            self.demand = demand

        def root_demand(self, root):
            return self.demand.get(root, 0.0)

    man = LaneManifest(req=mk_req(0, np.arange(8)),
                       prompt_tokens=np.arange(8))
    man.cache_tokens = 8
    from repro.serving.migrate import PageRecord
    man.pages.append(PageRecord(src_page=0, tokens=(1, 2, 3, 4),
                                payload={"k": np.zeros(250_000, np.int8)},
                                digest=b"x"))
    ledger = _Ledger({"h0": 8e9, "h1": 0.0})
    pl = MigrationPlanner(fabric=_Fabric(), topo=_Topo(), ledger=ledger,
                          min_frac=0.1, setup_s=0.005)
    plan = pl.price([man], src_device="h0:g0", dst_device="h1:g0")
    assert plan.bandwidth == pytest.approx(2e9)   # h0 is the bottleneck
    assert plan.transfer_s == pytest.approx(0.005 + plan.bytes / 2e9)
    # saturated root: floored at min_frac, never starved
    pl2 = MigrationPlanner(fabric=_Fabric(), topo=_Topo(),
                           ledger=_Ledger({"h0": 50e9}), min_frac=0.1)
    assert pl2.price([man], "h0:g0", "h1:g0").bandwidth \
        == pytest.approx(1e9)
    # no ledger: raw capacity (single-host tests)
    assert MigrationPlanner(fabric=_Fabric()).price([man]).bandwidth \
        == pytest.approx(10e9)


# ------------------------------------------- exemplar parse-back
EX_RE = re.compile(
    r'gateway_door_ttft_seconds_bucket\{tenant="T1",le="([^"]+)"\}'
    r' (\S+)(?: # \{req_id="(\d+)"\} (\S+) (\S+))?')


def test_prometheus_exemplars_parse_back():
    """Histogram bucket lines carry the slowest retained req_id per
    bucket in OpenMetrics exemplar syntax — parse the exposition back
    and recover exactly the requests we observed."""
    eng = mk_engine()
    w = eng.metrics.latency
    assert isinstance(w, LatencyWindow)
    # three samples in one bucket (slowest must win), one far tail, and
    # one sample WITHOUT a req_id (must never become an exemplar)
    w.observe(1.0, 0.011, req_id=1)
    w.observe(2.0, 0.013, req_id=2)
    w.observe(3.0, 0.012, req_id=3)
    w.observe(4.0, 0.900, req_id=9)
    w.observe(5.0, 3.000)
    gw = Gateway({"T1": [eng]})
    text = gw.prometheus()
    seen = {}
    for le, count, rid, val, ts in EX_RE.findall(text):
        if rid:
            seen[le] = (int(rid), float(val), float(ts))
    # the 0.011/0.012/0.013 bucket: slowest (req 2) is the exemplar,
    # and it sits inside its own bucket's bounds
    le2 = next(le for le, (rid, _, _) in seen.items() if rid == 2)
    assert seen[le2] == (2, 0.013, 2.0)
    assert 0.013 <= float(le2)
    le9 = next(le for le, (rid, _, _) in seen.items() if rid == 9)
    assert seen[le9] == (9, 0.9, 4.0)
    # the req-id-less 3.0s sample landed in SOME bucket but no bucket
    # claims it as an exemplar
    assert not any(v == 3.0 for _, v, _ in seen.values())
    assert all(float(v) <= float(le) for le, (_, v, _) in seen.items()
               if le != "+Inf")
