"""Speculative multi-token decode lanes: dense<->paged<->speculative
token parity (deterministic drafter), accept/rollback correctness (page
leaks, refcounts, shared pages) under churn + preemption, step-budget
bounds with speculation on, adaptive-k self-disable, the n-gram drafter
itself, and the ``PagedKVCache.truncate`` rollback primitive — all on
CPU, with the Pallas ragged kernel exercised in interpret mode."""
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request
from repro.serving.sched import NgramDrafter, bucket_rows

from test_paged_runtime import (assert_no_leaks,
                                assert_refcount_invariants, drain)

CFG = reduced(get_config("stablelm_3b")).replace(dtype="float32")


def make_req(req_id, prompt_tokens, max_new, hints=None, **kw):
    return Request(req_id=req_id, tenant="T1",
                   prompt_len=len(prompt_tokens), max_new_tokens=max_new,
                   arrival=0.0, prompt_tokens=np.asarray(prompt_tokens),
                   draft_hints=(np.asarray(hints) if hints is not None
                                else None), **kw)


def spec_engine(spec_k=4, attn_impl="ref", **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("seq_cap", 96)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunk_tokens", 16)
    return ServingEngine(CFG, seed=0, backend="paged", attn_impl=attn_impl,
                         spec_k=spec_k, **kw)


# ------------------------------------------------------------- the drafter
def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(ngram=2)
    corpus = [1, 2, 3, 9, 9, 1, 2]
    # pattern [1, 2] occurred at position 0; the following tokens are
    # proposed, capped at k
    assert d.draft(corpus, [1, 2], 3) == [3, 9, 9]
    assert d.draft(corpus, [1, 2], 1) == [3]
    # unseen pattern -> no draft (a miss costs nothing)
    assert d.draft(corpus, [7, 7], 3) == []
    # k=0 and tiny corpora are no-ops
    assert d.draft(corpus, [1, 2], 0) == []
    assert d.draft([1, 2], [1, 2], 3) == []


def test_ngram_drafter_prefers_most_recent_occurrence():
    d = NgramDrafter(ngram=2)
    #        [5,6]->7 ....... [5,6]->8 (more recent)
    corpus = [5, 6, 7, 1, 2, 5, 6, 8, 3, 5, 6]
    assert d.draft(corpus, [5, 6], 2) == [8, 3]


def test_ngram_drafter_replay_hint_boundary():
    """The replay workflow: hints (the previously observed completion)
    sit right after the prompt in the corpus, so the very first decode
    step's pattern [prompt[-1], first_output] matches at the boundary and
    proposes the rest of the completion."""
    d = NgramDrafter(ngram=2)
    prompt = [10, 11, 12]
    hints = [50, 51, 52, 53]       # previously observed completion
    output = [50]                  # first generated token matched o1
    corpus = prompt + hints + output
    pattern = (prompt + output)[-2:]          # [12, 50]
    assert d.draft(corpus, pattern, 3) == [51, 52, 53]


# ------------------------------------------------------------ token parity
@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_spec_token_parity_with_replay_hints(impl):
    """Accepted speculative output must be token-identical to
    non-speculative decode — run a trace cold, replay it with exact
    hints (forcing multi-token accepted bursts), and compare against the
    dense engine too.  'kernel' drives the ragged Pallas kernel in
    interpret mode with q_len>1 verify rows."""
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, CFG.vocab_size, pl) for pl in (40, 7, 21)]
    max_new = [6, 8, 5]

    dense = ServingEngine(CFG, max_slots=4, seq_cap=96, page_size=8, seed=0)
    reqs_d = [make_req(i, p, mn) for i, (p, mn)
              in enumerate(zip(prompts, max_new))]
    for r in reqs_d:
        assert dense.submit(r)
    drain(dense)

    cold = spec_engine(spec_k=4, attn_impl=impl)
    reqs_c = [make_req(i, p, mn) for i, (p, mn)
              in enumerate(zip(prompts, max_new))]
    for r in reqs_c:
        assert cold.submit(r)
    drain(cold)

    warm = spec_engine(spec_k=4, attn_impl=impl)
    reqs_w = [make_req(i, p, mn, hints=r.output_tokens) for i, (p, mn, r)
              in enumerate(zip(prompts, max_new, reqs_c))]
    for r in reqs_w:
        assert warm.submit(r)
    drain(warm)

    for rd, rc, rw in zip(reqs_d, reqs_c, reqs_w):
        assert rd.output_tokens == rc.output_tokens == rw.output_tokens
    # the replay run actually speculated (bursts were committed)
    m = warm.metrics
    assert m.drafted_tokens_total > 0
    assert m.accepted_tokens_total > 0
    assert m.accept_rate() > 0.5
    assert_no_leaks(warm)
    assert_no_leaks(cold)


class _AdversarialDrafter:
    """Proposes deterministic WRONG tokens: every draft must be rejected
    and rolled back, and the output must still be exact."""

    ngram = 2

    def __init__(self, vocab):
        self.vocab = vocab

    def draft(self, corpus, pattern, k):
        # off-by-one from whatever greedy decode would produce; the model
        # can never agree with all-offset tokens AND their own chain
        return [(int(corpus[-1]) + 7 + j) % self.vocab for j in range(k)]


def test_adversarial_drafter_all_rejected_still_exact():
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, CFG.vocab_size, 24)

    base = spec_engine(spec_k=0)
    rb = make_req(0, prompt, 8)
    assert base.submit(rb)
    drain(base)

    eng = spec_engine(spec_k=3)
    eng.runtime.sched.drafter = _AdversarialDrafter(CFG.vocab_size)
    r = make_req(0, prompt, 8)
    assert eng.submit(r)
    steps = 0
    while eng.has_work():
        rep = eng.step()
        assert_refcount_invariants(eng.kv)
        eng.finalize_step(rep, float(steps))
        steps += 1
        assert steps < 200
    assert r.output_tokens == rb.output_tokens
    m = eng.metrics
    assert m.drafted_tokens_total > 0
    # a rejected draft still commits its bonus token; nothing is accepted
    assert m.accepted_tokens_total == 0
    assert_no_leaks(eng)


def test_wrong_hints_never_corrupt_output():
    """Stale/garbage replay hints cost rejected rows, never wrong
    tokens."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, CFG.vocab_size, 24)
    base = spec_engine(spec_k=0)
    rb = make_req(0, prompt, 8)
    assert base.submit(rb)
    drain(base)

    eng = spec_engine(spec_k=4)
    # hints = reversed true completion: the boundary bigram never matches
    # the model chain beyond luck, and any draft must be verified away
    r = make_req(0, prompt, 8, hints=list(reversed(rb.output_tokens)))
    assert eng.submit(r)
    drain(eng)
    assert r.output_tokens == rb.output_tokens
    assert_no_leaks(eng)


# ------------------------------------------------- budget + starvation
def test_step_budget_bounds_hold_with_speculation():
    """Every fused step's NON-DRAFT rows (decode bases + prefill chunks)
    fit the step token budget, and drafts only ever consume LEFTOVER
    budget or ride row-bucket padding for free — so total planned rows
    stay within the budget's row bucket (the device batch the
    non-speculative plan would have padded to anyway), and prefill
    progress per step matches the non-speculative run exactly
    (speculation never starves prefill)."""
    rng = np.random.default_rng(9)
    long_prompt = rng.integers(0, CFG.vocab_size, 60)
    short = rng.integers(0, CFG.vocab_size, 8)

    solo = spec_engine(spec_k=0)
    ref = make_req(0, short, 24)
    assert solo.submit(ref)
    drain(solo)

    def run(spec_k):
        # r1 carries exact replay hints, so with spec on it WANTS k draft
        # rows every step while r2's long prompt chunks compete for the
        # same step budget
        eng = spec_engine(spec_k=spec_k, step_tokens=20, chunk_tokens=16)
        r1 = make_req(0, short, 24,
                      hints=ref.output_tokens if spec_k else None)
        assert eng.submit(r1)
        while not r1.generated:             # r1 decoding before admission
            eng.finalize_step(eng.step(), 0.0)
        r2 = make_req(1, long_prompt, 2)
        assert eng.submit(r2)
        budget = eng.runtime.sched.step_token_budget()
        prefill_per_step = []
        while eng.has_work():
            rep = eng.step()
            # planned rows = decode lanes (committed minus accepted) +
            # draft rows + prefill chunk rows — the true device batch.
            # Non-draft rows must fit the budget; padding-funded draft
            # rows may exceed it but never grow the row bucket the
            # non-draft rows already paid for
            lanes = rep.decode_tokens - rep.accepted_tokens
            assert lanes + rep.prefill_tokens <= budget, \
                "non-draft rows exceeded the step budget"
            assert lanes + rep.drafted_tokens + rep.prefill_tokens \
                <= bucket_rows(budget), \
                "draft rows grew the device batch beyond the budget bucket"
            assert rep.tokens <= bucket_rows(budget)
            if not r2.done:
                prefill_per_step.append(rep.prefill_tokens)
            eng.finalize_step(rep, 0.0)
        assert_no_leaks(eng)
        return prefill_per_step

    base = run(0)
    spec = run(4)
    assert spec == base, \
        "speculation changed prefill chunking (starved a prefill chunk)"


def test_drafts_clamped_to_remaining_tokens():
    """A lane one token from completion never drafts (the base commit
    finishes it), and committed bursts never overshoot max_new_tokens."""
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, CFG.vocab_size, 16)
    cold = spec_engine(spec_k=0)
    rc = make_req(0, prompt, 5)
    assert cold.submit(rc)
    drain(cold)

    eng = spec_engine(spec_k=4)
    r = make_req(0, prompt, 5, hints=rc.output_tokens)
    assert eng.submit(r)
    drain(eng)
    assert r.output_tokens == rc.output_tokens
    assert len(r.output_tokens) == 5          # never overshot
    assert_no_leaks(eng)


# ---------------------------------------------------------- adaptive k
def test_adaptive_k_disables_on_random_traffic():
    """On unpredictable traffic the drafter almost never matches and the
    acceptance EMA keeps lanes at q_len=1: drafted rows stay a tiny
    fraction of decoded tokens (ITL can never be structurally worse)."""
    rng = np.random.default_rng(13)
    eng = spec_engine(spec_k=4)
    reqs = [make_req(i, rng.integers(0, CFG.vocab_size, 16), 16)
            for i in range(4)]
    for r in reqs:
        assert eng.submit(r)
    drain(eng)
    m = eng.metrics
    decoded = sum(len(r.output_tokens) for r in reqs)
    assert decoded == 64
    # random 1024-vocab bigrams essentially never repeat inside these
    # tiny corpora; a handful of accidental matches is fine, a draft
    # per decoded token is not
    assert m.drafted_tokens_total <= decoded * 0.2
    assert_no_leaks(eng)


def test_adaptive_k_ema_drives_depth_down_and_probes():
    from repro.serving.sched import PagedScheduler, SchedConfig, SeqState
    kv = PagedKVCache(num_pages=8, page_size=4)
    sched = PagedScheduler(kv, SchedConfig(spec_k=4, spec_probe_every=3))
    seq = SeqState(make_req(0, [1, 2, 3, 4], 8))
    seq.req.generated = 1
    kv.reserve(0, 5)
    assert sched._adaptive_k(seq) == 4        # optimistic start
    for _ in range(12):                       # sustained total rejection
        sched.commit_verified(seq, 1, drafted=4, accepted=0)
    assert int(round(seq.accept_ema * 4)) == 0
    ks = [sched._adaptive_k(seq) for _ in range(7)]
    assert ks.count(1) == 2 and ks.count(0) == 5, \
        f"probe cadence broken: {ks}"
    # one accepted burst lifts the EMA (and so k) straight back up
    sched.commit_verified(seq, 5, drafted=4, accepted=4)
    assert sched._adaptive_k(seq) >= 1


def test_drafts_never_evict_cached_prefix_pages():
    """Speculation is opportunistic all the way down: a draft page
    reservation must only draw on truly-free pages — never reclaim
    refcount-zero cached prefix pages (a draft is worth at most k
    tokens; a cached prefix page saves a whole prefill)."""
    from repro.serving.sched import PagedScheduler, SchedConfig, SeqState
    kv = PagedKVCache(num_pages=4, page_size=4)
    toks = list(range(300, 316))              # exactly the whole pool
    kv.allocate(1, prompt_len=16)
    kv.commit_prefix(1, toks, 16)
    kv.release(1)                             # all 4 pages park on the LRU
    assert kv.cached_pages == 4 and not kv.free
    sched = PagedScheduler(kv, SchedConfig(spec_k=4))
    seq = SeqState(make_req(2, list(range(4)), 8,
                            hints=list(range(50, 58))))
    assert not sched._reserve_draft(seq, 1)
    assert kv.cached_pages == 4, "a draft reclaimed cached prefix pages"
    assert kv.prefix_index, "draft pressure emptied the prefix index"


# ------------------------------------------- rollback property: churn
def test_rollback_under_churn_and_preemption_no_leaks():
    """The rollback property suite: speculative lanes (mixed good and
    garbage hints) on an overcommitted shared-prefix pool, with
    preemption churn — refcount invariants hold at EVERY step, shared
    pages are never rolled back, and the pool drains leak-free."""
    rng = np.random.default_rng(17)
    common = rng.integers(0, CFG.vocab_size, 8)       # 2 shared pages
    eng = ServingEngine(CFG, max_slots=3, seq_cap=32, page_size=4, seed=0,
                        backend="paged", pool_pages=10, chunk_tokens=8,
                        attn_impl="ref", spec_k=3)
    reqs = []
    for i in range(6):
        tail = rng.integers(0, CFG.vocab_size, 4)
        hints = (list(rng.integers(0, CFG.vocab_size, 6))
                 if i % 2 else None)                  # garbage hints
        reqs.append(Request(
            req_id=i, tenant="T1", prompt_len=12, max_new_tokens=6,
            arrival=float(i), priority=float(rng.integers(0, 3)),
            prompt_tokens=np.concatenate([common, tail]),
            draft_hints=hints))
    for r in reqs[:3]:
        assert eng.submit(r)
    steps = 0
    while eng.has_work():
        if steps == 4:
            for r in reqs[3:]:
                assert eng.submit(r)
        rep = eng.step()
        assert_refcount_invariants(eng.kv)
        eng.finalize_step(rep, float(steps))
        steps += 1
        assert steps < 800
    assert all(r.done for r in reqs)
    assert_no_leaks(eng)


def test_preempted_speculative_lane_regenerates_identical_tokens():
    """Recompute-style preemption of a lane that had committed
    speculative bursts must regenerate the identical output."""
    rng = np.random.default_rng(19)
    toks = rng.integers(0, CFG.vocab_size, 8)

    solo = ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4, seed=0,
                         backend="paged", chunk_tokens=8, attn_impl="ref")
    ref_req = make_req(9, toks, 8)
    assert solo.submit(ref_req)
    drain(solo)

    eng = ServingEngine(CFG, max_slots=4, seq_cap=32, page_size=4, seed=0,
                        backend="paged", pool_pages=6, chunk_tokens=8,
                        attn_impl="ref", spec_k=3)
    hi = make_req(0, rng.integers(0, CFG.vocab_size, 8), 8, priority=2.0)
    lo = make_req(1, toks, 8, hints=ref_req.output_tokens, priority=0.5)
    assert eng.submit(hi) and eng.submit(lo)
    drain(eng)
    assert any(v == lo.req_id for v, _ in eng.runtime.sched.preempt_log), \
        "overcommitted pool never preempted the low-priority lane"
    assert lo.output_tokens == ref_req.output_tokens
    assert_no_leaks(eng)


# ----------------------------------------------- kvcache.truncate unit
def test_truncate_frees_whole_pages_only():
    kv = PagedKVCache(num_pages=8, page_size=4, enable_prefix_cache=False)
    kv.allocate(1, prompt_len=12)             # 3 pages, length 12
    pages = list(kv.tables[1].pages)
    kv.truncate(1, 6)                         # keep ceil(6/4)=2 pages
    assert kv.tables[1].pages == pages[:2]
    assert kv.tables[1].length == 6
    assert pages[2] in kv.free
    kv.truncate(1, 6)                         # idempotent
    assert kv.tables[1].pages == pages[:2]
    kv.truncate(1, 0)                         # full rollback
    assert kv.tables[1].pages == []
    assert len(kv.free) == 8
    kv.release(1)


def test_truncate_never_marks_tokens_live():
    kv = PagedKVCache(num_pages=8, page_size=4)
    kv.allocate(1, prompt_len=4)
    kv.reserve(1, 12)                         # 3 pages held, 4 live
    assert kv.tables[1].length == 4 and len(kv.tables[1].pages) == 3
    kv.truncate(1, 8)                         # drop the 3rd page only
    assert len(kv.tables[1].pages) == 2
    assert kv.tables[1].length == 4           # live length untouched
    with pytest.raises(ValueError):
        kv.truncate(1, -1)


def test_truncate_into_shared_page_raises():
    """The refcount-safety contract: a page with live sharers must never
    be rolled back, and the failed call must not mutate anything."""
    kv = PagedKVCache(num_pages=8, page_size=4)
    toks = list(range(100, 112))              # 3 pages worth
    kv.allocate(1, prompt_len=12)
    kv.commit_prefix(1, toks, 12)
    matched = kv.match_prefix(2, toks)        # seq 2 shares 2 full pages
    assert matched == 8
    shared = list(kv.tables[2].pages)
    assert all(kv.ref[p] == 2 for p in shared)
    before = (list(kv.tables[2].pages), dict(kv.ref), list(kv.free))
    with pytest.raises(ValueError):
        kv.truncate(2, 4)                     # into a shared page
    assert (list(kv.tables[2].pages), dict(kv.ref), list(kv.free)) == before
    # above the shared boundary truncation is fine
    kv.reserve(2, 16)                         # grow two private pages
    kv.truncate(2, 8)                         # drops only the private ones
    assert kv.tables[2].pages == shared
    assert all(kv.ref[p] == 2 for p in shared)
    kv.release(1)
    kv.release(2)


def test_truncate_parks_indexed_pages_on_cached_lru():
    """Truncating a sole-holder page that is prefix-indexed parks it on
    the cached LRU (KV intact, still matchable) instead of the free
    list — same contract as release()."""
    kv = PagedKVCache(num_pages=8, page_size=4)
    toks = list(range(200, 212))
    kv.allocate(1, prompt_len=12)
    kv.commit_prefix(1, toks, 12)             # 3 indexed pages
    third = kv.tables[1].pages[2]
    kv.truncate(1, 8)
    assert third in kv.cached and third not in kv.free
    kv.release(1)


def test_spec_k_on_dense_backend_rejected():
    with pytest.raises(ValueError):
        ServingEngine(CFG, backend="dense", spec_k=4)
