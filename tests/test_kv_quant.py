"""Opt-in int8 KV cache (beyond-paper, decode memory term)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import Model, decode_step, prefill


@pytest.fixture
def kv_int8(monkeypatch):
    monkeypatch.setenv("REPRO_KV_INT8", "1")


def test_quantized_decode_close_to_exact(kv_int8):
    cfg = reduced(get_config("stablelm_3b"))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    _, cache = prefill(params, cfg, {"tokens": toks[:, :11]}, seq_cap=16)
    assert cache["period"]["sub0"]["self"]["k"].dtype == jnp.int8
    lg_q, _ = decode_step(params, cfg, cache, toks[:, 11],
                          jnp.array([11], jnp.int32))
    # exact reference without quantization
    os.environ.pop("REPRO_KV_INT8")
    _, cache_f = prefill(params, cfg, {"tokens": toks[:, :11]}, seq_cap=16)
    lg_f, _ = decode_step(params, cfg, cache_f, toks[:, 11],
                          jnp.array([11], jnp.int32))
    err = np.max(np.abs(np.asarray(lg_q, np.float32)
                        - np.asarray(lg_f, np.float32)))
    ref = np.max(np.abs(np.asarray(lg_f, np.float32))) + 1e-6
    assert err / ref < 0.08, f"relative logits error {err/ref:.3f}"


def test_quantized_cache_halves_bytes(kv_int8):
    from repro.launch.shardings import make_policy
    from repro.launch.specs import decode_arg_plans
    from repro.configs.base import INPUT_SHAPES
    from repro.models.params import P

    class M:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("stablelm_3b")
    cplan, _, _ = decode_arg_plans(cfg, INPUT_SHAPES["decode_32k"], M())
    import jax as _j
    leaves = _j.tree.leaves(cplan, is_leaf=lambda x: isinstance(x, P))
    kv_bytes = sum(int(np.prod(p.shape)) for p in leaves if p.dtype == "int8")
    scale_bytes = sum(int(np.prod(p.shape)) * 2 for p in leaves
                      if "float" in p.dtype and len(p.shape) == 3)
    os.environ.pop("REPRO_KV_INT8")
    cplan_f, _, _ = decode_arg_plans(cfg, INPUT_SHAPES["decode_32k"], M())
    leaves_f = _j.tree.leaves(cplan_f, is_leaf=lambda x: isinstance(x, P))
    kv_bytes_f = sum(int(np.prod(p.shape)) * 2 for p in leaves_f
                     if p.dtype == "bfloat16")
    assert kv_bytes + scale_bytes < 0.55 * kv_bytes_f
