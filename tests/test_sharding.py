"""Sharding-plan resolution and MoE dispatch correctness (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.configs.base import INPUT_SHAPES, get_config, reduced
from repro.models.common import NO_POLICY
from repro.models.moe import moe_ffn, moe_plan
from repro.models.params import P, init_from_plan, resolve_pspec


class FakeMesh:
    """Duck-typed mesh for resolve_pspec unit tests."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_resolve_drops_nondivisible_axes():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 36 heads on a 16-way axis: dropped (jit args need exact divisibility)
    p = P((4608, 36, 128), pspec=("data", "model", None))
    assert resolve_pspec(mesh, p) == PartitionSpec("data", None, None)
    # 49155 vocab likewise
    p = P((49155, 4096), pspec=("model", "data"))
    assert resolve_pspec(mesh, p) == PartitionSpec(None, "data")


def test_resolve_uses_alt_when_primary_underutilises():
    mesh = FakeMesh({"data": 16, "model": 16})
    # Mixtral: 8 experts < 16-way model axis -> tensor-parallel-in-expert
    p = P((8, 4096, 2, 14336), pspec=("model", "data", None, None),
          alt=(None, "data", None, "model"))
    assert resolve_pspec(mesh, p) == \
        PartitionSpec(None, "data", None, "model")
    # DeepSeek: 160 experts divide 16 -> expert parallel kept
    p = P((160, 5120, 2, 1536), pspec=("model", "data", None, None),
          alt=(None, "data", None, "model"))
    assert resolve_pspec(mesh, p) == \
        PartitionSpec("model", "data", None, None)


def test_resolve_drops_axes_missing_from_mesh():
    mesh = FakeMesh({"data": 4, "model": 2})
    p = P((64, 64), pspec=(("pod", "data"), "model"))
    assert resolve_pspec(mesh, p) == PartitionSpec(("data",), "model")


def test_policy_long_context_shards_cache_sequence():
    import jax as _jax
    from repro.launch.shardings import make_policy

    class M:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("deepseek_v2_236b")
    pol = make_policy(cfg, INPUT_SHAPES["long_500k"], M())
    assert pol.mla_cache[1] == ("data", "model")   # seq over both axes
    cfg2 = get_config("gemma2_27b")                # kv=16 divides 16
    pol2 = make_policy(cfg2, INPUT_SHAPES["long_500k"], M())
    assert pol2.kv_cache == (None, "data", "model", None)


# ------------------------------------------------------------------- MoE
def dense_moe_reference(params, x, spec):
    """O(T*E) reference: every expert on every token, gated combine."""
    t, d = x.shape
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    for e in range(spec.num_experts):
        gu = jnp.einsum("td,dgf->tgf", x, params["wi"][e])
        h = jax.nn.silu(gu[:, 0]) * gu[:, 1]
        outs.append(h @ params["wo"][e])
    outs = jnp.stack(outs, 1)                       # [T, E, d]
    mask = jax.nn.one_hot(idx, spec.num_experts)    # [T, k, E]
    w = (mask * gates[..., None]).sum(1)            # [T, E]
    return jnp.einsum("ted,te->td", outs, w.astype(x.dtype))


def test_moe_dispatch_matches_dense_reference():
    cfg = reduced(get_config("mixtral_8x7b"))
    spec = cfg.moe
    plan = moe_plan(cfg, spec)
    params = init_from_plan(plan, jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)) * 0.3,
                    jnp.float32)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    out, aux = moe_ffn(params, x, spec, cfg, NO_POLICY)
    ref = dense_moe_reference(params, x.reshape(-1, cfg.d_model), spec)
    # capacity factor is generous at this size: no drops expected
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens_not_correctness():
    """With capacity 1 token/expert, output stays finite and bounded."""
    cfg = reduced(get_config("mixtral_8x7b"))
    import dataclasses
    spec = dataclasses.replace(cfg.moe, capacity_factor=0.01)
    plan = moe_plan(cfg, spec)
    params = init_from_plan(plan, jax.random.key(0))
    x = jnp.ones((1, 32, cfg.d_model), jnp.float32) * 0.1
    out, _ = moe_ffn(params, x, spec, cfg, NO_POLICY)
    assert jnp.all(jnp.isfinite(out))
