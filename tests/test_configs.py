"""Config integrity for all ten assigned architectures."""
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, all_configs, get_config, reduced

EXPECTED = {
    "jamba_v0_1_52b": dict(layers=32, d_model=4096, vocab=65536),
    "stablelm_3b": dict(layers=32, d_model=2560, vocab=50304),
    "phi_3_vision_4_2b": dict(layers=32, d_model=3072, vocab=32064),
    "mixtral_8x7b": dict(layers=32, d_model=4096, vocab=32000),
    "starcoder2_7b": dict(layers=32, d_model=4608, vocab=49152),
    "seamless_m4t_large_v2": dict(layers=24, d_model=1024, vocab=256206),
    "rwkv6_1_6b": dict(layers=24, d_model=2048, vocab=65536),
    "deepseek_v2_236b": dict(layers=60, d_model=5120, vocab=102400),
    "granite_3_8b": dict(layers=40, d_model=4096, vocab=49155),
    "gemma2_27b": dict(layers=46, d_model=4608, vocab=256000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_published_dims(arch):
    cfg = get_config(arch)
    exp = EXPECTED[arch]
    assert cfg.num_layers == exp["layers"]
    assert cfg.d_model == exp["d_model"]
    assert cfg.vocab_size == exp["vocab"]
    assert cfg.source


def test_all_ten_archs_registered():
    cfgs = all_configs()
    assert len(cfgs) == 10
    families = {c.family for c in cfgs.values()}
    assert families == {"dense", "moe", "hybrid", "ssm", "vlm", "audio"}


def test_jamba_interleave_ratio():
    cfg = get_config("jamba_v0_1_52b")
    specs = cfg.layer_specs()
    attn = sum(1 for l in specs if l.mixer == "attn")
    mamba = sum(1 for l in specs if l.mixer == "mamba")
    assert attn == 4 and mamba == 28          # 1:7 interleave
    moe = sum(1 for l in specs if l.ffn == "moe")
    assert moe == 16                          # every other layer


def test_deepseek_moe_spec():
    cfg = get_config("deepseek_v2_236b")
    assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
    assert cfg.moe.num_shared_experts == 2
    assert cfg.attn.kv_lora_rank == 512 and cfg.attn.kind == "mla"
    assert cfg.prefix[0].ffn == "dense"       # first layer dense


def test_gemma2_alternation_and_softcaps():
    cfg = get_config("gemma2_27b")
    specs = cfg.layer_specs()
    assert specs[0].window == 4096 and specs[1].window == 0
    assert cfg.attn.logit_softcap == 50.0
    assert cfg.final_logit_softcap == 30.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_invariants(arch):
    """Smoke configs: <=2 layers, d_model<=512, <=4 experts."""
    r = reduced(get_config(arch))
    assert r.num_layers <= 2
    assert r.d_model <= 512
    for f in (r.ffn, r.moe):
        if f is not None and f.num_experts:
            assert f.num_experts <= 4


def test_long_context_rule():
    runs = {a for a in ARCH_IDS if get_config(a).supports_long_context}
    assert runs == {"jamba_v0_1_52b", "rwkv6_1_6b", "mixtral_8x7b",
                    "starcoder2_7b", "gemma2_27b", "deepseek_v2_236b"}


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
