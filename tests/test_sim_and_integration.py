"""End-to-end behaviour: the controller against the discrete-event cluster
(short runs), reproducing the paper's *directional* claims; plus the
admission controller and the real serving-engine integration."""
import numpy as np
import pytest

from repro.core.admission import (AdmissionConfig, AdmissionController,
                                  AdmissionVerdict, TenantDemand)
from repro.core.controller import Controller, ControllerConfig
from repro.core.kingman import GG1
from repro.core.policy import PolicyConfig
from repro.core.topology import Slot, make_p4d_cluster
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule


def controller_factory(**flags):
    def make(sim):
        cfg = ControllerConfig(**flags)
        c = Controller(sim.topo, sim.lattice, sim, cfg)
        sim.register_tenants(c)
        return c
    return make


@pytest.fixture(scope="module")
def short_results():
    p = SimParams(duration_s=900.0, seed=7,
                  schedule=default_schedule(900.0))
    static = ClusterSim(p).run()
    full = ClusterSim(p, controller_factory()).run()
    return static, full


def test_controller_reduces_tail_latency(short_results):
    static, full = short_results
    assert full.p99 < static.p99, \
        f"controller did not improve p99: {full.p99} vs {static.p99}"
    assert full.miss_rate < static.miss_rate


def test_throughput_budget_respected(short_results):
    """Paper constraint: <= 5% throughput cost."""
    static, full = short_results
    assert full.throughput_rps >= 0.93 * static.throughput_rps


def test_reconfig_pauses_in_paper_band(short_results):
    _, full = short_results
    for pause in full.reconfig_times:
        assert 8.0 <= pause <= 35.0      # 18 +- 6 s, clamped


def test_controller_cpu_overhead_under_2_percent(short_results):
    _, full = short_results
    assert full.controller_cpu_frac < 0.02


def test_structural_actions_respect_dwell():
    """Gap between *policy-initiated* structural actions >= dwell.
    (Rollbacks are validation-driven and exempt, per §2.4.)"""
    p = SimParams(duration_s=900.0, seed=3, schedule=default_schedule(900.0))
    sim = ClusterSim(p, controller_factory())
    sim.run()
    times = [d.time for d in sim.controller.audit.decisions
             if d.action in ("move", "reconfigure", "relax")]
    gaps = np.diff(times)
    dwell = PolicyConfig().dwell_obs * p.sample_period_s
    assert all(g >= dwell * 0.9 for g in gaps), gaps


def test_ablation_components_all_help():
    p = SimParams(duration_s=900.0, seed=11, schedule=default_schedule(900.0))
    static = ClusterSim(p).run()
    for flags in (dict(enable_mig=True, enable_placement=False,
                       enable_guardrails=False),
                  dict(enable_mig=False, enable_placement=True,
                       enable_guardrails=False),
                  dict(enable_mig=False, enable_placement=False,
                       enable_guardrails=True)):
        res = ClusterSim(p, controller_factory(**flags)).run()
        assert res.p99 <= static.p99 * 1.05, (flags, res.p99, static.p99)


def test_mig_moves_are_rare():
    """Paper Table 4: < 5 moves/hr."""
    p = SimParams(duration_s=3600.0, seed=5)
    sim = ClusterSim(p, controller_factory())
    res = sim.run()
    assert res.actions.get("reconfigure", 0) < 5
    assert res.actions.get("move", 0) < 5


# ------------------------------------------------------------- admission
def test_admission_queue_and_reject():
    topo = make_p4d_cluster(1)
    adm = AdmissionController(topo, AdmissionConfig(max_queue=1))
    placements = {"T1": Slot(0, "h0:g0", 0)}
    demands = {"T1": TenantDemand("T1", 1e9)}
    gg1 = {"T1": GG1(arrival_rate=30, mean_service=0.008)}
    heavy = TenantDemand("T9", 30e9)     # exceeds any root capacity
    verdict, slot = adm.decide(heavy, placements, demands, gg1,
                               topo.slots())
    assert verdict == AdmissionVerdict.QUEUE and slot is None
    verdict, _ = adm.decide(heavy, placements, demands, gg1, topo.slots())
    assert verdict == AdmissionVerdict.REJECT


def test_admission_admits_light_tenant():
    topo = make_p4d_cluster(1)
    adm = AdmissionController(topo)
    light = TenantDemand("T9", 1e9)
    verdict, slot = adm.decide(light, {}, {}, {}, topo.slots())
    assert verdict == AdmissionVerdict.ADMIT and slot is not None
