"""End-to-end behaviour: the controller against the discrete-event cluster
(short runs), reproducing the paper's *directional* claims.  (The
registry-driven admission matrix lives in tests/test_admission.py.)"""
import numpy as np
import pytest

from repro.core.controller import Controller, ControllerConfig
from repro.core.policy import PolicyConfig
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule


def controller_factory(**flags):
    def make(sim):
        cfg = ControllerConfig(**flags)
        c = Controller(sim.topo, sim.lattice, sim, cfg)
        sim.register_tenants(c)
        return c
    return make


@pytest.fixture(scope="module")
def short_results():
    p = SimParams(duration_s=900.0, seed=7,
                  schedule=default_schedule(900.0))
    static = ClusterSim(p).run()
    full = ClusterSim(p, controller_factory()).run()
    return static, full


def test_controller_reduces_tail_latency(short_results):
    static, full = short_results
    assert full.p99 < static.p99, \
        f"controller did not improve p99: {full.p99} vs {static.p99}"
    assert full.miss_rate < static.miss_rate


def test_throughput_budget_respected(short_results):
    """Paper constraint: <= 5% throughput cost."""
    static, full = short_results
    assert full.throughput_rps >= 0.93 * static.throughput_rps


def test_reconfig_pauses_in_paper_band(short_results):
    _, full = short_results
    for pause in full.reconfig_times:
        assert 8.0 <= pause <= 35.0      # 18 +- 6 s, clamped


def test_controller_cpu_overhead_under_2_percent(short_results):
    _, full = short_results
    assert full.controller_cpu_frac < 0.02


def test_structural_actions_respect_dwell():
    """Gap between *policy-initiated* structural actions >= dwell.
    (Rollbacks are validation-driven and exempt, per §2.4.)"""
    p = SimParams(duration_s=900.0, seed=3, schedule=default_schedule(900.0))
    sim = ClusterSim(p, controller_factory())
    sim.run()
    times = [d.time for d in sim.controller.audit.decisions
             if d.action in ("move", "reconfigure", "relax")]
    gaps = np.diff(times)
    dwell = PolicyConfig().dwell_obs * p.sample_period_s
    assert all(g >= dwell * 0.9 for g in gaps), gaps


def test_ablation_components_all_help():
    p = SimParams(duration_s=900.0, seed=11, schedule=default_schedule(900.0))
    static = ClusterSim(p).run()
    for flags in (dict(enable_mig=True, enable_placement=False,
                       enable_guardrails=False),
                  dict(enable_mig=False, enable_placement=True,
                       enable_guardrails=False),
                  dict(enable_mig=False, enable_placement=False,
                       enable_guardrails=True)):
        res = ClusterSim(p, controller_factory(**flags)).run()
        assert res.p99 <= static.p99 * 1.05, (flags, res.p99, static.p99)


def test_mig_moves_are_rare():
    """Paper Table 4: < 5 moves/hr."""
    p = SimParams(duration_s=3600.0, seed=5)
    sim = ClusterSim(p, controller_factory())
    res = sim.run()
    assert res.actions.get("reconfigure", 0) < 5
    assert res.actions.get("move", 0) < 5


# ------------------------------------------------------- ledger coupling
def test_sim_ledger_mirrors_replica_state():
    """The sim's free_slots/headroom derive from the shared ledger, and
    the ledger tracks actuator-driven moves/reconfigures."""
    from repro.core.profiles import A100_MIG

    sim = ClusterSim(SimParams(duration_s=60.0, schedule=()))
    assert {s.key for s in sim.free_slots()} == \
        {s.key for s in sim.ledger.free_slots()}
    assert sim.ledger.owner_of("h0:g0:s0") == "T1/r0"
    h0 = sim.headroom_units("h0:g0")          # T1 (2u) + T3 (2u), home
    assert h0 == 3
    sim.reconfigure("T1", A100_MIG["4g.40gb"])
    assert sim.headroom_units("h0:g0") == h0 - 2
    target = next(s for s in sim.free_slots() if s.device == "h0:g2")
    sim.move("T1", target)
    assert sim.ledger.owner_of(target.key) == "T1/r0"
    assert sim.ledger.owner_of("h0:g0:s0") is None
    sim.ledger.check()
