"""Controller invariants: FSM gating (Algorithm 1), greedy upgrade
termination (§2.5.2), guardrail bounds (Table 1), audit/rollback (§2.4)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.audit import AuditLog, Decision, TenantConfig
from repro.core.guardrails import GuardrailBounds, GuardrailManager
from repro.core.optimizer import greedy_upgrade, upgrades_remaining
from repro.core.policy import DecisionFSM, PolicyConfig, Trigger
from repro.core.profiles import A100_MIG, TPU_SLICE


class FakeActuator:
    def __init__(self):
        self.calls = []

    def set_io_throttle(self, tenant, v):
        self.calls.append(("io", tenant, v))

    def set_mps_quota(self, tenant, v):
        self.calls.append(("mps", tenant, v))


# ------------------------------------------------------------------ FSM
def test_fsm_requires_persistence():
    fsm = DecisionFSM(PolicyConfig(persistence=3))
    assert fsm.observe(0.020) == Trigger.NONE
    assert fsm.observe(0.020) == Trigger.NONE
    assert fsm.observe(0.020) == Trigger.BREACH


def test_fsm_breach_streak_resets_on_recovery():
    fsm = DecisionFSM(PolicyConfig(persistence=3))
    fsm.observe(0.020)
    fsm.observe(0.020)
    fsm.observe(0.010)     # recovered
    assert fsm.observe(0.020) == Trigger.NONE
    assert fsm.observe(0.020) == Trigger.NONE
    assert fsm.observe(0.020) == Trigger.BREACH


def test_fsm_dwell_and_cooldown_gate_structural_actions():
    cfg = PolicyConfig(persistence=1, dwell_obs=10, cooldown_obs=5,
                       validation_obs=0)
    fsm = DecisionFSM(cfg)
    assert fsm.observe(0.02) == Trigger.BREACH
    fsm.action_taken(0.02)
    assert not fsm.at_reconfig_boundary()
    assert fsm.is_cooling_down()
    for _ in range(9):
        fsm.observe(0.02)
    assert not fsm.at_reconfig_boundary()
    fsm.observe(0.02)
    assert fsm.at_reconfig_boundary()
    assert not fsm.is_cooling_down()       # cooldown (5) expired before dwell


def test_fsm_validation_gates_triggers_then_verdicts():
    cfg = PolicyConfig(persistence=1, validation_obs=3)
    fsm = DecisionFSM(cfg)
    fsm.action_taken(pre_change_p99=0.020)
    assert fsm.observe(0.030) == Trigger.NONE   # gated during validation
    fsm.observe(0.030)
    fsm.observe(0.030)
    assert fsm.validation_result(0.030) is False   # worsened -> rollback
    fsm.action_taken(pre_change_p99=0.020)
    for _ in range(3):
        fsm.observe(0.010)
    assert fsm.validation_result(0.012) is True


@given(p99s=st.lists(st.floats(min_value=0.0, max_value=0.1,
                               allow_nan=False), min_size=1, max_size=200))
@settings(max_examples=30, deadline=None)
def test_fsm_never_triggers_during_dwell(p99s):
    """Property: after any action, no trigger can fire for dwell_obs
    observations when structural gating is honoured."""
    cfg = PolicyConfig(persistence=1, dwell_obs=50, cooldown_obs=20,
                       validation_obs=0)
    fsm = DecisionFSM(cfg)
    fsm.action_taken(0.02)
    for i, p in enumerate(p99s[:49]):
        fsm.observe(p)
        assert not fsm.at_reconfig_boundary()


# --------------------------------------------------------------- greedy
def test_greedy_upgrade_maximises_delta_mu_within_headroom():
    assert greedy_upgrade(A100_MIG, A100_MIG["2g.20gb"], 5).name == "7g.80gb"
    assert greedy_upgrade(A100_MIG, A100_MIG["2g.20gb"], 2).name == "4g.40gb"
    assert greedy_upgrade(A100_MIG, A100_MIG["2g.20gb"], 0) is None


def test_upgrade_sequences_terminate():
    """Finite termination: at most |M|-1 upgrades (paper §2.5.2)."""
    for lattice in (A100_MIG, TPU_SLICE):
        p = lattice.bottom
        steps = 0
        while True:
            nxt = greedy_upgrade(lattice, p, headroom_units=10**9)
            if nxt is None:
                break
            assert nxt.mu() > p.mu()       # strictly increasing isolation
            p = nxt
            steps += 1
        assert steps <= len(lattice) - 1
        assert upgrades_remaining(lattice, p) == 0


def test_profile_lattice_is_ordered():
    units = [p.compute_units for p in A100_MIG.profiles]
    assert units == sorted(units)
    assert A100_MIG.top.name == "7g.80gb"
    assert A100_MIG.bottom.name == "1g.10gb"


# ------------------------------------------------------------ guardrails
def test_guardrail_bounds_clamped_to_table1():
    gm = GuardrailManager(GuardrailBounds())
    act = FakeActuator()
    v = gm.throttle_io(act, "T2", 10e9, now=0.0)      # above 500 MB/s cap
    assert v == 500e6
    v = gm.throttle_io(act, "T2", 1e3, now=0.0)       # below 100 MB/s floor
    assert v == 100e6
    q = gm.set_mps_quota(act, "T3", 0.1)
    assert q == 0.5
    q = gm.set_mps_quota(act, "T3", 2.0)
    assert q == 1.0


def test_guardrail_bounded_window_expiry():
    gm = GuardrailManager(GuardrailBounds(io_window_s=30.0))
    act = FakeActuator()
    gm.throttle_io(act, "T2", 300e6, now=100.0)
    assert gm.is_throttled("T2")
    assert gm.tick(act, 120.0) == []
    assert gm.tick(act, 131.0) == ["T2"]
    assert not gm.is_throttled("T2")
    assert act.calls[-1] == ("io", "T2", None)        # throttle removed


def test_claim1_hook_total_throttle():
    gm = GuardrailManager()
    act = FakeActuator()
    gm.throttle_io(act, "T2", 400e6, now=0.0)
    gm.throttle_io(act, "T4", 200e6, now=0.0)
    assert gm.total_throttle() == pytest.approx(600e6)


# ---------------------------------------------------------------- audit
def test_audit_rollback_bookkeeping():
    log = AuditLog()
    good = TenantConfig(profile="2g.20gb", device="h0:g0", slot=0)
    log.mark_good("T1", good)
    log.record(Decision(1.0, "reconfigure", "T1", {"profile": "4g.40gb"}, {}))
    log.set_validation(False)
    assert log.decisions[-1].validated is False
    restored = log.last_known_good("T1")
    assert restored.profile == "2g.20gb"
    # mark_good copies: mutating the restored config must not corrupt the log
    restored.profile = "7g.80gb"
    assert log.last_known_good("T1").profile == "2g.20gb"


def test_audit_counts():
    log = AuditLog()
    for a in ("move", "move", "throttle_io"):
        log.record(Decision(0.0, a, "T1", {}, {}))
    assert log.counts() == {"move": 2, "throttle_io": 1}
    assert len(log.actions_of("move")) == 2
