"""Hypothesis property tests on simulator invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule


@given(seed=st.integers(min_value=0, max_value=50))
@settings(max_examples=5, deadline=None)
def test_latency_never_below_compute_floor(seed):
    """Every latency >= the best-case compute time at the largest profile."""
    p = SimParams(seed=seed, duration_s=300.0,
                  schedule=default_schedule(300.0))
    sim = ClusterSim(p)
    res = sim.run()
    floor = p.t1_c0_s * (p.t1_ref_units / 7) ** p.t1_gamma
    assert res.latencies.min() >= floor


def test_interference_raises_contended_tail():
    """With T2/T3 never active, tails are strictly better."""
    quiet = SimParams(seed=1, duration_s=600.0, schedule=())
    noisy = SimParams(seed=1, duration_s=600.0,
                      schedule=default_schedule(600.0))
    r_q = ClusterSim(quiet).run()
    r_n = ClusterSim(noisy).run()
    assert r_q.p99 < r_n.p99
    assert r_q.miss_rate <= r_n.miss_rate


def test_conservation_offered_equals_completed_plus_queue():
    p = SimParams(seed=3, duration_s=400.0, schedule=default_schedule(400.0))
    sim = ClusterSim(p)
    res = sim.run()
    in_flight = sim.in_flight("T1")
    assert res.offered == res.completed + res.dropped + in_flight


def test_determinism_same_seed_same_result():
    p = SimParams(seed=9, duration_s=300.0, schedule=default_schedule(300.0))
    a = ClusterSim(p).run()
    b = ClusterSim(p).run()
    assert a.completed == b.completed
    np.testing.assert_array_equal(a.latencies, b.latencies)
