"""Property tests for the PS fabric model and Kingman guidance (paper §2.5)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import psmodel
from repro.core.kingman import GG1, service_rate_needed

pos = st.floats(min_value=0.01, max_value=100.0, allow_nan=False)


@given(ws=st.lists(pos, min_size=1, max_size=6), cap=pos)
@settings(max_examples=60, deadline=None)
def test_ps_shares_respect_fair_share_and_caps(ws, cap):
    demands = {f"t{i}": psmodel.Demand(weight=w) for i, w in enumerate(ws)}
    shares = psmodel.ps_shares(demands, cap)
    total_w = sum(ws)
    for i, w in enumerate(ws):
        assert shares[f"t{i}"] == pytest.approx(cap * w / total_w, rel=1e-9)


@given(ws=st.lists(pos, min_size=2, max_size=5), cap=pos,
       g=st.floats(min_value=0.001, max_value=1.0))
@settings(max_examples=60, deadline=None)
def test_ps_throttle_binds(ws, cap, g):
    """b_i = min(fair, g_i): a throttle below fair share must bind."""
    demands = {f"t{i}": psmodel.Demand(weight=w) for i, w in enumerate(ws)}
    fair0 = cap * ws[0] / sum(ws)
    demands["t0"] = psmodel.Demand(weight=ws[0], throttle=g * fair0)
    shares = psmodel.ps_shares(demands, cap)
    assert shares["t0"] == pytest.approx(min(fair0, g * fair0), rel=1e-9)


@given(ws=st.lists(pos, min_size=1, max_size=6), cap=pos,
       data=st.data())
@settings(max_examples=60, deadline=None)
def test_waterfill_conserves_capacity(ws, cap, data):
    """Water-filling never allocates more than B in total, and uncapped
    flows split the residual by weight."""
    demands = {}
    for i, w in enumerate(ws):
        throttle = data.draw(st.one_of(st.none(), pos))
        demands[f"t{i}"] = psmodel.Demand(weight=w, throttle=throttle)
    alloc = psmodel.ps_shares_waterfill(demands, cap)
    assert sum(alloc.values()) <= cap * (1 + 1e-9)
    for k, d in demands.items():
        if d.throttle is not None:
            assert alloc.get(k, 0.0) <= d.throttle + 1e-9


def test_waterfill_redistributes_slack():
    """A tenant capped below fair share returns capacity to the others —
    the beyond-paper refinement over the paper's plain min()."""
    demands = {"a": psmodel.Demand(), "b": psmodel.Demand(throttle=1.0)}
    plain = psmodel.ps_shares(demands, 10.0)
    wf = psmodel.ps_shares_waterfill(demands, 10.0)
    assert plain["a"] == pytest.approx(5.0)
    assert wf["a"] == pytest.approx(9.0)
    assert wf["b"] == pytest.approx(1.0)


def test_stability_claim_condition():
    """Claim 1 (iii): sum g_j < B."""
    assert psmodel.stable_under_throttles({"a": 3.0, "b": 4.0}, 10.0)
    assert not psmodel.stable_under_throttles({"a": 6.0, "b": 5.0}, 10.0)


def test_latency_decomposition():
    lat = psmodel.latency(compute_s=0.005, size_bytes=10e6, bandwidth=10e9,
                          noise_s=0.001)
    assert lat == pytest.approx(0.005 + 0.001 + 0.001)


@given(lam=st.floats(min_value=0.1, max_value=50),
       es=st.floats(min_value=1e-4, max_value=0.019))
@settings(max_examples=50, deadline=None)
def test_kingman_monotone_in_rho(lam, es):
    g = GG1(arrival_rate=lam, mean_service=es)
    if g.rho >= 0.999:
        return
    g2 = GG1(arrival_rate=lam, mean_service=es * 1.02)
    if g2.rho >= 1.0:
        assert g2.mean_wait() == math.inf
    else:
        assert g2.mean_wait() >= g.mean_wait()


def test_kingman_saturation_inflates_tails():
    low = GG1(arrival_rate=10, mean_service=0.01)     # rho 0.1
    high = GG1(arrival_rate=95, mean_service=0.01)    # rho 0.95
    assert high.tail_inflation() > 5 * low.tail_inflation()


def test_service_rate_needed():
    assert service_rate_needed(70.0, 0.7) == pytest.approx(100.0)
