"""Serving engine: continuous batching, paged KV accounting, TTFT metrics,
and the MPS-quota guardrail hook."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config, reduced
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import PagedKVCache
from repro.serving.metrics import EMA, LatencyWindow, TenantMetrics
from repro.serving.request import Request


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("stablelm_3b"))
    return ServingEngine(cfg, max_slots=4, seq_cap=64, seed=0)


def drain(eng, max_steps=400):
    now = 0.0
    reports = []
    while eng.has_work() and len(reports) < max_steps:
        rep = eng.step()
        now += max(rep.compute_s, 1e-4)
        eng.finalize_step(rep, now)
        reports.append(rep)
    return reports, now


def test_engine_completes_all_requests(engine):
    reqs = [Request(req_id=i, tenant="T1", prompt_len=16, max_new_tokens=4,
                    arrival=0.0, slo_ms=500.0) for i in range(6)]
    for r in reqs:
        assert engine.submit(r)
    reports, _ = drain(engine)
    assert all(r.done for r in reqs)
    assert all(len(r.output_tokens) == r.max_new_tokens for r in reqs)
    assert all(r.ttft is not None and r.ttft > 0 for r in reqs)
    assert engine.kv.used_pages == 0          # everything released


def test_continuous_batching_interleaves(engine):
    """New requests join while others are decoding (slot reuse)."""
    reqs = [Request(req_id=100 + i, tenant="T1", prompt_len=8,
                    max_new_tokens=6, arrival=0.0) for i in range(8)]
    for r in reqs:
        engine.submit(r)
    reports, _ = drain(engine)
    kinds = [r.kind for r in reports]
    # prefills interleave with decodes, not all up front (4 slots, 8 reqs)
    first_decode = kinds.index("decode")
    assert "prefill" in kinds[first_decode:]


def test_quota_caps_concurrency(engine):
    engine.set_quota(0.5)
    assert engine.active_slot_budget == 2
    engine.set_quota(1.0)
    assert engine.active_slot_budget == 4


def test_admission_rejects_when_pool_full():
    cfg = reduced(get_config("stablelm_3b"))
    eng = ServingEngine(cfg, max_slots=2, seq_cap=32, page_size=16)
    ok = eng.submit(Request(req_id=0, tenant="T1", prompt_len=30,
                            max_new_tokens=2, arrival=0.0))
    assert ok
    # pool is 2*(32/16)=4 pages; request needing 3 more pages won't fit
    assert not eng.submit(Request(req_id=1, tenant="T1", prompt_len=30,
                                  max_new_tokens=18, arrival=0.0))


# ---------------------------------------------------------------- paging
def test_paged_kvcache_alloc_grow_release():
    kv = PagedKVCache(num_pages=8, page_size=16)
    e = kv.allocate(1, prompt_len=20)          # 2 pages
    assert len(e.pages) == 2 and kv.used_pages == 2
    for _ in range(12):
        kv.append_token(1)
    assert len(e.pages) == 2                   # 32 tokens fit in 2 pages
    kv.append_token(1)                         # 33rd token -> 3rd page
    assert len(e.pages) == 3
    bt = kv.block_table(1, pages_per_seq=4)
    assert list(bt[:3]) == e.pages and bt[3] == 0
    kv.release(1)
    assert kv.used_pages == 0


@given(st.lists(st.integers(min_value=1, max_value=40), min_size=1,
                max_size=12))
@settings(max_examples=30, deadline=None)
def test_paged_kvcache_never_double_allocates(prompt_lens):
    """Property: no page is owned by two sequences; free+used == pool."""
    kv = PagedKVCache(num_pages=64, page_size=16)
    owned = {}
    for i, pl in enumerate(prompt_lens):
        if not kv.can_admit(pl, 0):
            continue
        e = kv.allocate(i, pl)
        owned[i] = list(e.pages)
    all_pages = [p for pages in owned.values() for p in pages]
    assert len(all_pages) == len(set(all_pages))
    assert len(all_pages) + len(kv.free) == 64


# --------------------------------------------------------------- metrics
def test_latency_window_quantiles():
    w = LatencyWindow()
    for i, v in enumerate(np.linspace(0.001, 0.1, 100)):
        w.observe(float(i), float(v), slo=0.05)
    assert w.quantile(0.5) == pytest.approx(0.0505, rel=0.05)
    assert w.miss_rate(0.05) == pytest.approx(0.5, abs=0.03)
    assert w.p999() <= 0.1


@given(st.lists(st.tuples(st.floats(0.0, 100.0, allow_nan=False),
                          st.floats(1e-4, 8.0, allow_nan=False)),
                min_size=1, max_size=120,
                unique_by=lambda p: p[0]))
@settings(max_examples=40, deadline=None)
def test_latency_window_trim_vs_horizon(samples):
    """Property: out-of-order observes + the 2x-capacity trim interact
    safely — retained samples are always the time-sorted TAIL of
    everything observed (drops are strictly oldest-first, so a sample
    inside the horizon can only fall out after every older sample did),
    quantiles read exactly the in-horizon retained samples, and the
    cumulative histogram side never trims."""
    w = LatencyWindow(max_samples=8, horizon_s=10.0)
    expected = []
    for now, lat in samples:
        w.observe(float(now), float(lat))
        expected.append((float(now), float(lat)))
        expected.sort(key=lambda p: p[0])
        if len(expected) > 2 * w.max_samples:
            expected = expected[-w.max_samples:]
        assert w.samples == expected
    # quantile over a horizon anchored at the newest stamp reads the
    # in-horizon retained samples, nothing more, nothing less
    newest = max(t for t, _ in expected)
    in_h = [v for t, v in expected if t >= newest - w.horizon_s]
    assert w.quantile(0.5, newest) == \
        pytest.approx(float(np.quantile(in_h, 0.5)))
    # cumulative histogram counters are trim-immune
    assert w.total == len(samples)
    hist = w.hist()
    assert hist[-1] == (float("inf"), w.total)
    counts = [c for _, c in hist]
    assert counts == sorted(counts)             # cumulative: monotone
    assert w.sum == pytest.approx(sum(v for _, v in samples))


def test_latency_window_hist_le_buckets():
    """Prometheus ``le`` semantics: a sample equal to a bucket edge
    counts in that bucket; overflow lands only in +Inf."""
    w = LatencyWindow()
    for v in (0.001, 0.0011, 5.0, 99.0):
        w.observe(0.0, v)
    h = dict(w.hist())
    assert h[0.001] == 1        # == edge: inclusive
    assert h[0.0025] == 2
    assert h[3.2] == 2
    assert h[6.4] == 3
    assert h[float("inf")] == 4 == w.total


def test_throughput_running_sum_matches_brute_force_scan():
    """The O(1) running-sum throughput must equal the O(n) window scan
    it replaced, at every tick, including after lazy expiry."""
    m = TenantMetrics()
    rng = np.random.default_rng(3)
    t, log = 0.0, []
    for _ in range(300):
        t += float(rng.exponential(0.4))
        n = int(rng.integers(1, 50))
        m.observe_tokens(t, n)
        log.append((t, n))
        h = m.throughput_horizon_s
        assert m.throughput(t) == pytest.approx(
            sum(k for tt, k in log if tt >= t - h) / h)
    # a narrower horizon still scans only the retained tail
    assert m.throughput(t, horizon_s=2.0) == pytest.approx(
        sum(k for tt, k in log if tt >= t - 2.0) / 2.0)
    # lazy expiry keeps the window bounded by the horizon
    assert all(tt >= t - m.throughput_horizon_s
               for tt, _ in m.throughput_window)


def test_ema_hysteresis_deadband():
    e = EMA(alpha=0.5, hysteresis=0.10)
    e.update(100.0)
    assert e.update(101.0) == 100.0     # within dead-band: ignored
    assert e.update(200.0) == 150.0     # real move passes through


def test_ema_deadband_holds_for_negative_signals():
    """The dead-band guard is on |value|: a signal living below zero
    (headroom deltas, error terms) gets the same hysteresis as a
    positive one instead of silently losing it."""
    e = EMA(alpha=0.5, hysteresis=0.10)
    e.update(-100.0)
    assert e.update(-101.0) == -100.0   # sub-hysteresis wiggle: ignored
    assert e.update(-200.0) == -150.0   # real move passes through
