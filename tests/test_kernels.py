"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis
property tests, executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_attention_mixed)
from repro.kernels.paged_attention.ref import (paged_attention_mixed_ref,
                                               paged_attention_ref)
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("b,s,t,h,kv,hd,causal,window,cap,dtype", [
    (2, 128, 128, 4, 2, 64, True, 0, None, jnp.float32),
    (1, 256, 256, 8, 8, 128, True, 128, 50.0, jnp.float32),
    (2, 64, 192, 4, 1, 64, True, 0, None, jnp.float32),
    (1, 128, 128, 4, 4, 64, False, 0, None, jnp.float32),
    (1, 128, 128, 2, 2, 128, True, 0, None, jnp.bfloat16),
    (1, 384, 384, 4, 2, 64, True, 256, None, jnp.float32),
])
def test_flash_attention_allclose(b, s, t, h, kv, hd, causal, window, cap,
                                  dtype):
    q = jnp.asarray(RNG.standard_normal((b, s, h, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, t, kv, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, t, kv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64, 128]),
       s=st.sampled_from([64, 128, 192]))
def test_flash_attention_block_shape_invariance(bq, bk, s):
    """Property: output is independent of the BlockSpec tiling."""
    q = jnp.asarray(RNG.standard_normal((1, s, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, s, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, s, 2, 64)), jnp.float32)
    a = flash_attention(q, k, v, block_q=bq, block_k=bk)
    b = flash_attention(q, k, v, block_q=64, block_k=64)
    # fp32 online-softmax reassociation differs across tilings: ~1e-4
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4,
                               atol=5e-4)


# ---------------------------------------------------------------- paged
@pytest.mark.parametrize("b,h,kv,hd,page,pps,npages", [
    (2, 4, 2, 64, 128, 4, 16),
    (4, 8, 8, 128, 128, 2, 8),
    (1, 4, 1, 64, 128, 8, 32),
    (3, 6, 2, 64, 256, 2, 6),
])
def test_paged_attention_allclose(b, h, kv, hd, page, pps, npages):
    q = jnp.asarray(RNG.standard_normal((b, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, npages, (b, pps)), jnp.int32)
    lens = jnp.asarray(RNG.integers(1, pps * page, (b,)), jnp.int32)
    # impl="kernel" pins the Pallas kernel (interpret mode on CPU); the
    # default impl="auto" routes to the oracle off-TPU, which would make
    # this comparison vacuous
    out = paged_attention(q, kp, vp, bt, lens, impl="kernel")
    ref = paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("impl", ["kernel", "ref"])
def test_paged_attention_ignores_pages_beyond_length(impl):
    """Property: garbage in pages past `lengths` must not leak into output."""
    b, h, kv, hd, page, pps, npages = 1, 2, 2, 64, 128, 4, 8
    q = jnp.asarray(RNG.standard_normal((b, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    lens = jnp.asarray([130], jnp.int32)
    out1 = paged_attention(q, kp, vp, bt, lens, impl=impl)
    kp2 = kp.at[2:].set(1e4)     # poison pages beyond length
    vp2 = vp.at[2:].set(-1e4)
    out2 = paged_attention(q, kp2, vp2, bt, lens, impl=impl)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5)


@pytest.mark.parametrize("b,qn,h,kv,hd,page,pps,npages", [
    (2, 8, 4, 2, 64, 128, 4, 16),
    (3, 16, 8, 4, 128, 128, 2, 8),
    (1, 4, 4, 1, 64, 128, 8, 32),
])
def test_paged_attention_mixed_allclose(b, qn, h, kv, hd, page, pps, npages):
    """Ragged mixed rows (per-row causal positions, including pad rows at
    position 0): Pallas kernel (interpret) vs oracle."""
    q = jnp.asarray(RNG.standard_normal((b, qn, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, npages, (b, pps)), jnp.int32)
    # lane 0: a prefill-style run of consecutive positions; other lanes:
    # random valid positions with trailing pad rows at 0
    qpos = RNG.integers(0, pps * page, (b, qn)).astype(np.int32)
    qpos[0] = np.arange(qn) + RNG.integers(0, pps * page - qn)
    qpos[:, qn - qn // 2:] = 0                      # pad-row tail
    qpos = jnp.asarray(qpos)
    out = paged_attention_mixed(q, kp, vp, bt, qpos, impl="kernel")
    ref = paged_attention_mixed_ref(q, kp, vp, bt, qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_paged_attention_mixed_q1_matches_decode():
    """Property: the ragged path with q_len=1 IS the decode path."""
    b, h, kv, hd, page, pps, npages = 2, 4, 2, 64, 128, 4, 16
    q = jnp.asarray(RNG.standard_normal((b, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, npages, (b, pps)), jnp.int32)
    lens = jnp.asarray([200, 400], jnp.int32)
    dec = paged_attention(q, kp, vp, bt, lens, impl="ref")
    mix = paged_attention_mixed(q[:, None], kp, vp, bt,
                                (lens - 1)[:, None], impl="ref")
    np.testing.assert_allclose(np.asarray(mix[:, 0]), np.asarray(dec),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("impl", ["kernel", "ref"])
def test_paged_attention_mixed_causal_within_chunk(impl):
    """Garbage at key slots PAST a row's position must not leak into that
    row — the in-page-walk causal mask (poisoning slots past position p
    leaves rows <= p bit-identical)."""
    b, qn, h, kv, hd, page, pps, npages = 1, 4, 2, 2, 64, 128, 2, 4
    q = jnp.asarray(RNG.standard_normal((b, qn, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray([[0, 1]], jnp.int32)
    qpos = jnp.asarray([[60, 61, 62, 63]], jnp.int32)
    out1 = paged_attention_mixed(q, kp, vp, bt, qpos, impl=impl)
    kp2 = kp.at[0, 64:].set(1e4).at[1].set(1e4)     # poison past pos 63
    vp2 = vp.at[0, 64:].set(-1e4).at[1].set(-1e4)
    out2 = paged_attention_mixed(q, kp2, vp2, bt, qpos, impl=impl)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5)


@pytest.mark.parametrize("impl", ["kernel", "ref"])
def test_paged_attention_int8_pages_close(impl):
    """int8 pages + per-page-row scales stay close to the fp path."""
    b, qn, h, kv, hd, page, pps, npages = 2, 4, 4, 2, 64, 128, 2, 8
    q = jnp.asarray(RNG.standard_normal((b, qn, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, npages, (b, pps)), jnp.int32)
    qpos = jnp.asarray(RNG.integers(0, pps * page, (b, qn)), jnp.int32)

    def quant(p):
        s = np.abs(np.asarray(p)).max(-1) / 127.0 + 1e-8
        iv = np.clip(np.round(np.asarray(p) / s[..., None]), -127, 127)
        return jnp.asarray(iv.astype(np.int8)), jnp.asarray(s, jnp.float32)

    kq, ks = quant(kp)
    vq, vs = quant(vp)
    fp = paged_attention_mixed(q, kp, vp, bt, qpos, impl=impl)
    i8 = paged_attention_mixed(q, kq, vq, bt, qpos, impl=impl,
                               k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(i8), np.asarray(fp), rtol=0.05,
                               atol=0.05)


def test_paged_attention_bucketed_width_invariance():
    """Property: narrowing the block table to the live pages (the
    runtime's width bucketing) must not change the output."""
    b, h, kv, hd, page, npages = 2, 4, 2, 64, 128, 8
    q = jnp.asarray(RNG.standard_normal((b, h, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((npages, page, kv, hd)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, npages, (b, 4)), jnp.int32)
    lens = jnp.asarray([100, 200], jnp.int32)    # <= 2 pages live
    wide = paged_attention(q, kp, vp, bt, lens, impl="ref")
    narrow = paged_attention(q, kp, vp, bt[:, :2], lens, impl="ref")
    np.testing.assert_allclose(np.asarray(wide), np.asarray(narrow),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------- sel. scan
@pytest.mark.parametrize("b,s,d,n,block_d,chunk", [
    (2, 64, 128, 16, 64, 32),
    (1, 256, 256, 8, 128, 64),
    (1, 96, 64, 4, 64, 96),
])
def test_selective_scan_allclose(b, s, d, n, block_d, chunk):
    x = jnp.asarray(RNG.standard_normal((b, s, d)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, s, d))) * 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.standard_normal((d, n))) - 0.1, jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.5, jnp.float32)
    c = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.5, jnp.float32)
    dd = jnp.asarray(RNG.standard_normal((d,)), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((b, d, n)) * 0.1, jnp.float32)
    y, hf = selective_scan(x, dt, a, bb, c, dd, h0, block_d=block_d,
                           chunk=chunk)
    yr, hr = selective_scan_ref(x, dt, a, bb, c, dd, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=1e-4,
                               atol=1e-4)


def test_selective_scan_chunk_boundary_state_continuity():
    """Property: chunked scan == two half-scans chained via state."""
    b, s, d, n = 1, 64, 32, 8
    x = jnp.asarray(RNG.standard_normal((b, s, d)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, s, d))) * 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.standard_normal((d, n))) - 0.1, jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.5, jnp.float32)
    c = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.5, jnp.float32)
    dd = jnp.asarray(RNG.standard_normal((d,)), jnp.float32)
    y, hf = selective_scan(x, dt, a, bb, c, dd, chunk=16)
    y1, h1 = selective_scan(x[:, :32], dt[:, :32], a, bb[:, :32], c[:, :32],
                            dd, chunk=16)
    y2, h2 = selective_scan(x[:, 32:], dt[:, 32:], a, bb[:, 32:], c[:, 32:],
                            dd, h1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf), rtol=1e-4,
                               atol=1e-4)


# ------------------------------------------------------------------ rwkv
@pytest.mark.parametrize("b,s,h,hd,chunk", [
    (2, 64, 4, 32, 32),
    (1, 96, 2, 64, 48),
    (1, 33, 1, 32, 16),   # ragged chunk boundary
])
def test_rwkv6_scan_allclose(b, s, h, hd, chunk):
    if s % chunk:
        pytest.skip("kernel requires chunk | seq (padding handled by caller)")
    r = jnp.asarray(RNG.standard_normal((b, s, h, hd)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, hd)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, hd)) * 0.5, jnp.float32)
    w = jnp.asarray(0.45 + 0.5 / (1 + np.exp(-RNG.standard_normal((b, s, h, hd)))),
                    jnp.float32)
    u = jnp.asarray(RNG.standard_normal((h, hd)) * 0.5, jnp.float32)
    s0 = jnp.asarray(RNG.standard_normal((b, h, hd, hd)) * 0.1, jnp.float32)
    y, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    yr, sr = rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), rtol=1e-4,
                               atol=1e-4)


def test_rwkv6_matches_model_lax_scan():
    """The Pallas kernel and the model's lax.scan implement one recurrence."""
    from repro.configs.base import get_config, reduced
    from repro.models import rwkv as rwkv_mod
    cfg = reduced(get_config("rwkv6_1_6b"))
    heads, hd = cfg.d_model // cfg.rwkv.head_dim, cfg.rwkv.head_dim
    b, s = 1, 32
    r = jnp.asarray(RNG.standard_normal((b, s, heads, hd)) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, heads, hd)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, heads, hd)) * 0.3, jnp.float32)
    w = jnp.asarray(0.5 + 0.4 / (1 + np.exp(-RNG.standard_normal((b, s, heads, hd)))),
                    jnp.float32)
    u = jnp.asarray(RNG.standard_normal((heads, hd)) * 0.3, jnp.float32)
    y_kernel, _ = rwkv6_scan(r, k, v, w, u, chunk=16)
    y_ref, _ = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
