"""Sim <-> serving parity: the two Actuator implementations (ClusterSim and
ServingActuator) are driven through identical controller decision scripts
— reconfigure / move / throttle sequences — and must report identical
ledger views (slot occupancy, per-GPU unit use, headroom, per-root fabric
demand) step for step.  This is the guarantee that lets the *same*
Controller object manage either backend.

Also covers the serving actuator's seeded reconfig-pause RNG and the
per-tenant io.max throttles on FabricState.
"""
import numpy as np
import pytest

from repro.core.ledger import DeviceLedger
from repro.core.profiles import A100_MIG
from repro.core.tenancy import TenantRegistry
from repro.core.topology import make_p4d_cluster
from repro.serving.actuator import FabricState, ServingActuator
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams

pytestmark = pytest.mark.tier2


class _FakeEngine:
    """Quota-bearing stand-in: the parity script never steps an engine."""

    def __init__(self):
        self.quota = 1.0

    def set_quota(self, q):
        self.quota = q


def make_pair(n_tenants=2, replicas=2):
    """One ClusterSim and one ServingActuator over the same registry,
    topology and ledger parameters."""
    reg = TenantRegistry.slo_fleet(n_tenants, replicas)
    specs = tuple(reg)
    p = SimParams(duration_s=60.0, schedule=(), tenants=specs)
    sim = ClusterSim(p)

    topo = make_p4d_cluster(2)
    reg2 = TenantRegistry(specs)
    ledger = DeviceLedger.from_registry(
        topo, reg2, A100_MIG, home_devices=p.home_devices,
        ambient_units=p.ambient_units)
    engines = {s.name: [_FakeEngine() for _ in range(replicas)]
               for s in reg2.latency()}
    act = ServingActuator(engines, FabricState(), topo, lambda: 0.0,
                          ledger=ledger, rng=np.random.default_rng(0))
    return sim, act


def assert_parity(sim, act):
    assert sim.ledger.view() == act.ledger.view()
    assert [s.key for s in sim.free_slots()] == \
        [s.key for s in act.free_slots()]
    for dev in sim.topo.devices():
        assert sim.headroom_units(dev) == act.headroom_units(dev)


def decision_script(sim):
    """A controller-shaped action sequence, chosen against the (shared)
    ledger state so it is identical for both actuators."""
    lat = list(sim.lat)
    first, second = lat[0], lat[1]
    cur_dev = sim.ledger.slots_of(second)[0].device
    target = next(s for s in sim.free_slots()
                  if s.device != cur_dev
                  and sim.headroom_units(s.device) >= 2)
    back = sim.ledger.slots_of(second)[0]
    return [
        ("reconfigure", first, A100_MIG["3g.40gb"]),
        ("throttle", "ETL", 3e8),
        ("move", second, target),
        ("reconfigure", second, A100_MIG["4g.40gb"]),
        ("reconfigure", first, A100_MIG["2g.20gb"]),   # relax path
        ("throttle", "ETL", None),
        ("reconfigure", second, A100_MIG["2g.20gb"]),
        ("move", second, back),
    ]


def apply(actuator, step):
    kind, tenant, arg = step
    if kind == "reconfigure":
        actuator.reconfigure(tenant, arg)
    elif kind == "move":
        actuator.move(tenant, arg)
    elif kind == "throttle":
        actuator.set_io_throttle(tenant, arg)


def test_ledger_views_identical_step_for_step():
    sim, act = make_pair()
    assert_parity(sim, act)                   # identical starting state
    for step in decision_script(sim):
        apply(sim, step)
        apply(act, step)
        assert_parity(sim, act)
    sim.ledger.check()
    act.ledger.check()


def test_parity_holds_across_fleet_shapes():
    for n, r in ((2, 1), (4, 2)):
        sim, act = make_pair(n, r)
        assert_parity(sim, act)
        first = next(iter(sim.lat))
        apply(sim, ("reconfigure", first, A100_MIG["4g.40gb"]))
        apply(act, ("reconfigure", first, A100_MIG["4g.40gb"]))
        assert_parity(sim, act)


def test_budget_checked_reconfigure_raises_identically():
    """An oversubscribing resize must be refused by BOTH ledgers (the
    controller's arbiter normally prevents it ever being issued)."""
    from repro.core.ledger import LedgerError
    sim, act = make_pair(2, 2)
    first = next(iter(sim.lat))
    # 7g on a device that also hosts other occupants cannot fit
    dev = sim.ledger.slots_of(first)[0].device
    if sim.ledger.used_units(dev) > sim.ledger._profile_units(
            A100_MIG, first):
        with pytest.raises(LedgerError):
            sim.reconfigure(first, A100_MIG["7g.80gb"])
        with pytest.raises(LedgerError):
            act.reconfigure(first, A100_MIG["7g.80gb"])
        assert_parity(sim, act)


# ---------------------------------------------- serving actuator details
def test_reconfig_pauses_vary_and_reseed_reproducibly():
    """The pause draw must come from the run's seeded RNG: repeated
    reconfigs sample the 18 +- 6 s distribution (not one frozen value),
    and the same seed reproduces the same sequence."""
    def pauses(seed):
        sim, act = make_pair()
        act.rng = np.random.default_rng(seed)
        first = next(iter(act.engines))
        out = []
        for prof in ("3g.40gb", "4g.40gb", "3g.40gb", "2g.20gb"):
            out.append(act.reconfigure(first, A100_MIG[prof]))
        return out

    a = pauses(7)
    assert len(set(a)) > 1                    # not the frozen constant
    assert a == pauses(7)                     # seeded: reproducible
    assert a != pauses(8)
    assert all(p >= 8.0 for p in a)


def test_io_throttle_is_per_tenant():
    fabric = FabricState(t2_active=True)
    fabric.set_on_root("T1", True)
    choked = fabric.bandwidth("T1")
    # throttling an unrelated tenant must NOT relieve the ETL stream
    fabric.set_io_throttle("TRAIN", 1e8)
    assert fabric.bandwidth("T1") == choked
    assert fabric.io_throttle_of("TRAIN") == 1e8
    assert fabric.io_throttle_of("T2") is None
    # throttling the ETL stream itself does
    fabric.set_io_throttle("T2", 1e8)
    assert fabric.bandwidth("T1") > choked
    assert fabric.io_throttle == 1e8          # legacy view = T2's cap
    # lifting it restores contention
    fabric.set_io_throttle("T2", None)
    assert fabric.bandwidth("T1") == choked
    assert fabric.io_throttle_of("TRAIN") == 1e8   # untouched
