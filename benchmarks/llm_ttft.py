"""LLM serving case study (paper §4.0.1, Table 2): vLLM-style serving of
OLMo-2-7B-Instruct under T2/T3 interference; SLO TTFT p99 <= 200 ms.

The REAL JAX serving engine (paged accounting, continuous batching, greedy
decode) runs a reduced OLMo-2 config; its measured per-step compute is
scaled to the 7B operating point, and the PS fabric model injects the
transfer/interference component exactly as in the non-LLM experiments.
The controller is *unchanged* (the paper's point: "without changing the
controller") — it sees TTFT tails instead of request tails.

``--backend paged`` serves through the block-table paged runtime (fused
mixed prefill+decode steps + SLO-aware preemption); ``--backend both``
emits the dense-vs-paged TTFT/ITL p99 A/B side by side — the in-repo
analogue of the paper's vLLM claim (paged KV + budgeted mixed scheduling
holds the TTFT tail under the same interference, and because decode lanes
ride in every step the ITL tail no longer spikes when churn admits new
prompts).  ``--shared-prefix`` runs the prefix-cache workload arm: every
request shares a common system prompt, and the A/B against the
no-sharing baseline reports the prefix-hit rate plus the TTFT/ITL p99
improvement (shared-prefix TTFT is O(tail), not O(prompt)).

Paper Table 2:  Static MIG 232 ms TTFT p99, 1.00 thr
                Full system 199 ms TTFT p99, 0.96 thr
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.controller import Controller, ControllerConfig
from repro.core.policy import PolicyConfig
from repro.core.profiles import A100_MIG
from repro.core.signals import Snapshot, SystemSignals, TenantSignals
from repro.core.topology import Slot, make_p4d_cluster
from repro.serving.actuator import FabricState, ServingActuator
from repro.serving.engine import ServingEngine
from repro.serving.metrics import LatencyWindow
from repro.serving.request import Request
from repro.sim.params import default_schedule


def run(duration=1800.0, qps=1.75, seed=0, with_controller=True,
        verbose=True, compute_scale_7b=34.0, auto_calibrate=False,
        backend="dense", shared_prefix=0, prefix_cache=True):
    """Virtual-time serving loop.  compute_scale_7b maps the reduced
    model's measured prefill compute to the 7B-on-A100 operating point.

    The fixed scale assumes the calibration host's CPU speed; on slower
    machines the measured compute (x34) alone can exceed the 200 ms SLO
    and the case study degenerates.  ``auto_calibrate=True`` instead
    derives the scale from the warm prefill measurements so the static
    operating point lands at ~120 ms virtual prefill (paper Table 2's
    232 ms p99 under queueing + interference) on any host."""
    cfg = reduced(get_config("olmo2_7b"))
    engine = ServingEngine(cfg, max_slots=8, seq_cap=128, seed=seed,
                           backend=backend, prefix_cache=prefix_cache)
    rng = np.random.default_rng(seed)
    # --shared-prefix arm: every request opens with the same
    # ``shared_prefix``-token system prompt followed by a random tail, so
    # the paged prefix cache can map the common pages and skip their
    # prefill entirely (the no-sharing baseline runs the SAME workload
    # with the cache disabled)
    common = (rng.integers(0, cfg.vocab_size, shared_prefix)
              if shared_prefix else None)

    def make_prompt(prompt_len):
        if common is None:
            return None
        tail = rng.integers(0, cfg.vocab_size, prompt_len - len(common))
        return np.concatenate([common, tail])
    fabric = FabricState()
    topo = make_p4d_cluster(2)
    now = [0.0]
    actuator = ServingActuator(engine, fabric, topo, lambda: now[0],
                               rng=np.random.default_rng(seed + 1))
    ttft_window = LatencyWindow(max_samples=1 << 14, horizon_s=60.0)

    controller = None
    if with_controller:
        ccfg = ControllerConfig(policy=PolicyConfig(tau_s=0.200,
                                                    stable_obs=120))
        controller = Controller(topo, A100_MIG, actuator, ccfg)
        controller.register_tenant("T1", "latency", Slot(0, "h0:g0", 0),
                                   A100_MIG["2g.20gb"])
        controller.register_tenant("T2", "background", Slot(0, "h0:g1", 0),
                                   A100_MIG["7g.80gb"])
        controller.register_tenant("T3", "background", Slot(0, "h0:g0", 1),
                                   A100_MIG["2g.20gb"])

    rng = np.random.default_rng(seed)
    schedule = default_schedule(duration)
    next_arrival = rng.exponential(1.0 / qps)
    next_sample = 1.0
    req_id = 0
    completed = 0
    shed = 0
    # warm every jit shape (3 prompt buckets + the batched decode) so
    # compile time never leaks into measured compute
    for j, pl_ in enumerate((32, 64, 96)):
        engine.submit(Request(req_id=-10 - j, tenant="T1", prompt_len=pl_,
                              max_new_tokens=2, arrival=0.0))
    while engine.has_work():
        engine.finalize_step(engine.step(), 0.0)
    if auto_calibrate:
        # measure warm PER-TOKEN prefill compute on THIS host and target
        # ~120 ms virtual prefill for the 64-token median prompt.  The
        # samples are normalised by the step's prefill tokens so the
        # calibration is backend-agnostic: the paged runtime packs several
        # prompts' chunks (plus decode rows) into one fused step, and a
        # per-STEP mean would overweight those bigger steps and hand the
        # paged backend a flattering scale
        samples = []
        for j, pl_ in enumerate((32, 64, 96)):
            engine.submit(Request(req_id=-20 - j, tenant="T1",
                                  prompt_len=pl_, max_new_tokens=2,
                                  arrival=0.0))
        while engine.has_work():
            rep = engine.step()
            if rep.prefill_tokens:
                samples.append(rep.compute_s / rep.prefill_tokens)
            engine.finalize_step(rep, 0.0)
        compute_scale_7b = (0.120 / 64.0) / float(np.mean(samples))

    def t2_active_at(t):
        return any(w.tenant == "T2" and w.start <= t < w.end
                   for w in schedule)

    while now[0] < duration:
        fabric.t2_active = t2_active_at(now[0])
        # arrivals (load-shed 503-style while the tenant is paused for a
        # reconfiguration/move — counts against throughput, not latency)
        while next_arrival <= now[0]:
            if next_arrival < actuator.pause_until:
                shed += 1
            else:
                pl_ = int(rng.choice([32, 64, 96]))
                if common is not None:
                    pl_ = max(pl_, shared_prefix + 32)
                r = Request(req_id=req_id, tenant="T1", prompt_len=pl_,
                            max_new_tokens=4, arrival=next_arrival,
                            slo_ms=200.0, prompt_tokens=make_prompt(pl_))
                engine.submit(r)
                req_id += 1
            next_arrival += rng.exponential(1.0 / qps)
        # controller sampling
        if controller is not None and now[0] >= next_sample:
            t1 = TenantSignals(
                p99=ttft_window.quantile(0.99, now[0]),
                p95=ttft_window.quantile(0.95, now[0]),
                p999=ttft_window.quantile(0.999, now[0]),
                miss_rate=ttft_window.miss_rate(0.200, now[0]),
                rps=completed / max(now[0], 1.0),
                ttft_p99=ttft_window.quantile(0.99, now[0]))
            sys = SystemSignals()
            t2r = topo.root_of("h0:g1")
            for root in topo.roots():
                sys.pcie_bytes[root] = (fabric.t2_demand if
                                        fabric.t2_active and root == t2r
                                        else 1e9)
            sys.host_io[topo.numa_of("h0:g1")] = \
                2.5e9 if fabric.t2_active else 0.0
            controller.on_snapshot(Snapshot(now[0], {"T1": t1}, sys))
            next_sample = now[0] + 1.0
        def advance_to(*candidates):
            """Monotone virtual-clock jump to the next future event."""
            future = [c for c in candidates if c > now[0]]
            now[0] = min(future) if future else now[0] + 0.05

        # engine work
        if now[0] < actuator.pause_until:
            advance_to(actuator.pause_until, next_arrival, next_sample)
            continue
        rep = engine.step()
        if rep.kind == "idle":
            advance_to(next_arrival, next_sample, now[0] + 0.05)
            continue
        compute = rep.compute_s * compute_scale_7b * actuator.compute_scale
        # only the prompt share of a (possibly mixed) step pays transfer
        sbytes = rep.prefill_tokens * 1.5e6      # per-token transfer bytes
        transfer = sbytes / fabric.t1_bandwidth()
        now[0] += compute + transfer
        engine.finalize_step(rep, now[0])
        for pr in rep.prefilled:
            ttft_window.observe(now[0], pr.ttft, slo=0.200)
        completed += len(rep.completed)

    lats = np.array([v for _, v in ttft_window.samples])
    out = {
        "backend": backend,
        "ttft_p99_ms": float(np.quantile(lats, 0.99) * 1e3) if lats.size else 0.0,
        "ttft_p50_ms": float(np.quantile(lats, 0.50) * 1e3) if lats.size else 0.0,
        "itl_p99_ms": engine.metrics.itl.quantile(0.99) * 1e3,
        "miss_rate": float(np.mean(lats > 0.200)) if lats.size else 0.0,
        "throughput_rps": completed / duration,
        "shed": shed,
        "kv_reserved_frac": engine.metrics.kv_utilisation(),
        "kv_used_frac": engine.metrics.kv_live_utilisation(),
        "prefix_hit_rate": engine.metrics.prefix_hit_rate(),
        "actions": controller.audit.counts() if controller else {},
    }
    return out


def run_shared_prefix(duration=600.0, qps=1.75, prefix_len=64, seed=0,
                      verbose=True):
    """Prefix-cache A/B on the paged backend: the same shared-system-
    prompt workload with the prefix cache ON vs OFF (no controller — the
    comparison isolates the serving-layer effect).  Reports the hit rate
    and the TTFT/ITL p99 improvement."""
    base = run(duration=duration, qps=qps, seed=seed, with_controller=False,
               backend="paged", shared_prefix=prefix_len, prefix_cache=False)
    shared = run(duration=duration, qps=qps, seed=seed, with_controller=False,
                 backend="paged", shared_prefix=prefix_len, prefix_cache=True)
    out = {
        "workload": {"duration_s": duration, "qps": qps,
                     "prefix_len": prefix_len},
        "baseline": base,
        "prefix_cache": shared,
        "prefix_hit_rate": shared["prefix_hit_rate"],
        "ttft_p99_speedup": (base["ttft_p99_ms"] /
                             max(shared["ttft_p99_ms"], 1e-9)),
        "itl_p99_speedup": (base["itl_p99_ms"] /
                            max(shared["itl_p99_ms"], 1e-9)),
    }
    if verbose:
        print("== shared-prefix workload (paged backend) ==")
        print(f"  no sharing : TTFT p99={base['ttft_p99_ms']:7.1f}ms "
              f"ITL p99={base['itl_p99_ms']:6.1f}ms")
        print(f"  prefix hit : TTFT p99={shared['ttft_p99_ms']:7.1f}ms "
              f"ITL p99={shared['itl_p99_ms']:6.1f}ms "
              f"hit-rate={shared['prefix_hit_rate']*100:.1f}%")
        print(f"  TTFT p99 speedup: {out['ttft_p99_speedup']:.2f}x "
              f"(>= 2x expected at >= 50% hit rate)")
    return out


def run_backend(backend="dense", verbose=True, seed=0, duration=1800.0):
    static = run(with_controller=False, seed=seed, backend=backend,
                 duration=duration)
    full = run(with_controller=True, seed=seed, backend=backend,
               duration=duration)
    norm = full["throughput_rps"] / max(static["throughput_rps"], 1e-9)
    if verbose:
        print(f"  [{backend}] static: TTFT p99={static['ttft_p99_ms']:6.1f}ms "
              f"(paper 232ms) ITL p99={static['itl_p99_ms']:5.1f}ms "
              f"miss={static['miss_rate']*100:.1f}%")
        print(f"  [{backend}] full  : TTFT p99={full['ttft_p99_ms']:6.1f}ms "
              f"(paper 199ms) ITL p99={full['itl_p99_ms']:5.1f}ms "
              f"miss={full['miss_rate']*100:.1f}% "
              f"actions={full['actions']}")
        print(f"  [{backend}] TTFT p99 reduction: "
              f"{(1 - full['ttft_p99_ms']/static['ttft_p99_ms'])*100:.1f}% "
              f"(paper ~13%)  norm throughput: {norm:.3f} (paper 0.96)")
    return {"static": static, "full": full, "norm_throughput": norm}


def _maybe_dump(out, json_path):
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(verbose=True, backend="dense", shared_prefix=False,
         duration=1800.0, json_path=None):
    if verbose:
        print("== LLM serving case study (vLLM-style, OLMo-2-7B) ==")
    if shared_prefix:
        return _maybe_dump(run_shared_prefix(duration=duration,
                                             verbose=verbose), json_path)
    if backend != "both":
        return _maybe_dump(run_backend(backend, verbose=verbose,
                                       duration=duration), json_path)
    # A/B: the same trace + controller through both runtimes, side by side
    out = {b: run_backend(b, verbose=verbose, duration=duration)
           for b in ("dense", "paged")}
    if verbose:
        d, p = out["dense"]["full"], out["paged"]["full"]
        print(f"  A/B (full system): TTFT p99 dense {d['ttft_p99_ms']:.1f}ms "
              f"vs paged {p['ttft_p99_ms']:.1f}ms "
              f"({(1 - p['ttft_p99_ms']/max(d['ttft_p99_ms'], 1e-9))*100:+.1f}%)"
              f" | ITL p99 dense {d['itl_p99_ms']:.1f}ms "
              f"vs paged {p['itl_p99_ms']:.1f}ms")
    return _maybe_dump(out, json_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("dense", "paged", "both"),
                    default="dense",
                    help="engine backend; 'both' emits the dense-vs-paged "
                         "TTFT/ITL A/B side by side")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="prefix-cache workload arm (paged backend): "
                         "shared-system-prompt traffic, cache on vs off, "
                         "reporting hit rate and TTFT/ITL p99 speedups")
    ap.add_argument("--duration", type=float, default=1800.0,
                    help="virtual-time seconds per run (CI uses a short "
                         "duration)")
    ap.add_argument("--json", default=None,
                    help="write the result dict to this JSON file")
    args = ap.parse_args()
    main(backend=args.backend, shared_prefix=args.shared_prefix,
         duration=args.duration, json_path=args.json)
