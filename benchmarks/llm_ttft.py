"""LLM serving case study (paper §4.0.1, Table 2): vLLM-style serving of
OLMo-2-7B-Instruct under T2/T3 interference; SLO TTFT p99 <= 200 ms.

The REAL JAX serving engine (paged accounting, continuous batching, greedy
decode) runs a reduced OLMo-2 config; its measured per-step compute is
scaled to the 7B operating point, and the PS fabric model injects the
transfer/interference component exactly as in the non-LLM experiments.
The controller is *unchanged* (the paper's point: "without changing the
controller") — it sees TTFT tails instead of request tails.

``--backend paged`` serves through the block-table paged runtime (fused
mixed prefill+decode steps + SLO-aware preemption); ``--backend both``
emits the dense-vs-paged TTFT/ITL p99 A/B side by side — the in-repo
analogue of the paper's vLLM claim (paged KV + budgeted mixed scheduling
holds the TTFT tail under the same interference, and because decode lanes
ride in every step the ITL tail no longer spikes when churn admits new
prompts).  ``--shared-prefix`` runs the prefix-cache workload arm: every
request shares a common system prompt, and the A/B against the
no-sharing baseline reports the prefix-hit rate plus the TTFT/ITL p99
improvement (shared-prefix TTFT is O(tail), not O(prompt)).  ``--spec``
runs the speculative-decode A/B: decode-heavy repetitive/templated
traffic (the engine-side response cache self-primes draft hints from
each template's first completion — no client hints) and a random
control trace, spec on vs off, reporting accept rate, ITL p99/p50 and
throughput deltas — the per-step fixed cost amortised k-ways on
predictable traffic, with adaptive per-lane k keeping the random trace
within noise of non-speculative decode.  ``--replicas N`` runs the
cluster-wide KV reuse A/B: N paged replicas behind one dispatcher on a
shared-prefix-group trace, cache-aware routing (content-hash prefix
directory, route-to-longest-held-prefix) vs blind least-loaded.

Paper Table 2:  Static MIG 232 ms TTFT p99, 1.00 thr
                Full system 199 ms TTFT p99, 0.96 thr
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.controller import Controller, ControllerConfig
from repro.core.policy import PolicyConfig
from repro.core.profiles import A100_MIG
from repro.core.signals import Snapshot, SystemSignals, TenantSignals
from repro.core.topology import Slot, make_p4d_cluster
from repro.serving.actuator import FabricState, ServingActuator
from repro.serving.engine import ServingEngine
from repro.serving.metrics import LatencyWindow
from repro.serving.request import Request
from repro.sim.params import default_schedule


def _denoise_runtime(rt, bucket_cost, shared):
    """Replace ``rt``'s measured fused-step wall-clock with a per-bucket
    cost table (see ``run``'s ``denoise`` docs).  ``shared`` freezes each
    (rows, width, logit-rows) bucket at the min of three back-to-back
    first-sight executions; otherwise a running min is kept."""
    orig_run_mixed = rt._run_mixed

    def _denoised(tokens, positions, n_rows, bts, last_rows):
        logits, dt = orig_run_mixed(tokens, positions, n_rows, bts,
                                    last_rows)
        key = (tokens.shape[0], bts.shape[1], last_rows.shape[0])
        if shared:
            if key not in bucket_cost:
                # freeze the bucket at the min of three back-to-back
                # executions: one unlucky first measurement would
                # otherwise replay through every later step of this
                # shape.  Re-execution is safe — the step scatters
                # the same K/V rows to the same page slots, so the
                # extra calls are idempotent
                for _ in range(2):
                    _, dt2 = orig_run_mixed(tokens, positions, n_rows,
                                            bts, last_rows)
                    dt = min(dt, dt2)
                bucket_cost[key] = dt
            dt = bucket_cost[key]
        else:
            dt = bucket_cost[key] = min(bucket_cost.get(key, dt), dt)
        return logits, dt

    rt._run_mixed = _denoised


def run(duration=1800.0, qps=1.75, seed=0, with_controller=True,
        verbose=True, compute_scale_7b=34.0, auto_calibrate=False,
        backend="dense", shared_prefix=0, prefix_cache=True,
        spec_k=0, templated=0, max_new=4, denoise=False,
        response_cache=False, tracer=None):
    """Virtual-time serving loop.  compute_scale_7b maps the reduced
    model's measured prefill compute to the 7B-on-A100 operating point.

    The fixed scale assumes the calibration host's CPU speed; on slower
    machines the measured compute (x34) alone can exceed the 200 ms SLO
    and the case study degenerates.  ``auto_calibrate=True`` instead
    derives the scale from the warm prefill measurements so the static
    operating point lands at ~120 ms virtual prefill (paper Table 2's
    232 ms p99 under queueing + interference) on any host."""
    cfg = reduced(get_config("olmo2_7b"))
    engine = ServingEngine(cfg, max_slots=8, seq_cap=128, seed=seed,
                           backend=backend, prefix_cache=prefix_cache,
                           spec_k=spec_k, response_cache=response_cache)
    rng = np.random.default_rng(seed)
    # --shared-prefix arm: every request opens with the same
    # ``shared_prefix``-token system prompt followed by a random tail, so
    # the paged prefix cache can map the common pages and skip their
    # prefill entirely (the no-sharing baseline runs the SAME workload
    # with the cache disabled)
    common = (rng.integers(0, cfg.vocab_size, shared_prefix)
              if shared_prefix else None)
    # --spec arm, repetitive/templated trace: requests draw from
    # ``templated`` distinct prompt templates.  The first completion of a
    # template is cached (the serving frontend's response cache); later
    # requests of the same template carry it as ``draft_hints``, so the
    # n-gram drafter replays the expected completion and the model merely
    # VERIFIES it in the fused ragged step — the templated-traffic regime
    # (forms, code stubs, canned agent turns) where prompt-lookup
    # speculation earns its keep.  Greedy decode makes the replay exact,
    # so stale-hint handling is exercised by the random trace instead.
    templates = (rng.integers(0, cfg.vocab_size, (templated, 64))
                 if templated else None)
    completions: dict = {}   # template id -> completion (primed off-clock)

    # ``denoise``: replace each fused step's measured wall-clock with the
    # running MINIMUM observed for its (rows, width, logit-rows) bucket —
    # the timeit-style estimate of an AOT-compiled executable's true cost.
    # A shared/noisy host's scheduling hiccups land in the top percentiles
    # of raw per-step timings, which is exactly where an ITL p99 A/B
    # reads, so without this the comparison measures the host, not the
    # serving stack.  Both arms of an A/B get the identical treatment;
    # step cost still tracks real batch shape (more verify rows = the
    # bucket genuinely costs more).  Pass a dict to SHARE the cost table
    # across runs — shared mode freezes each bucket at its FIRST
    # measurement (``setdefault``) instead of a running min: a monotone
    # min would keep improving across arms, quietly handing later arms
    # cheaper steps, whereas frozen first-sight costs make arms with
    # identical step-shape traces replay bit-identical virtual time.
    if (denoise or isinstance(denoise, dict)) and backend == "paged":
        shared = isinstance(denoise, dict)
        _denoise_runtime(engine.runtime, denoise if shared else {}, shared)

    def make_prompt(prompt_len):
        if common is None:
            return None
        tail = rng.integers(0, cfg.vocab_size, prompt_len - len(common))
        return np.concatenate([common, tail])
    fabric = FabricState()
    topo = make_p4d_cluster(2)
    now = [0.0]
    actuator = ServingActuator(engine, fabric, topo, lambda: now[0],
                               rng=np.random.default_rng(seed + 1),
                               tracer=tracer)
    ttft_window = LatencyWindow(max_samples=1 << 14, horizon_s=60.0)

    controller = None
    if with_controller:
        ccfg = ControllerConfig(policy=PolicyConfig(tau_s=0.200,
                                                    stable_obs=120))
        controller = Controller(topo, A100_MIG, actuator, ccfg,
                                tracer=tracer)
        controller.register_tenant("T1", "latency", Slot(0, "h0:g0", 0),
                                   A100_MIG["2g.20gb"])
        controller.register_tenant("T2", "background", Slot(0, "h0:g1", 0),
                                   A100_MIG["7g.80gb"])
        controller.register_tenant("T3", "background", Slot(0, "h0:g0", 1),
                                   A100_MIG["2g.20gb"])

    rng = np.random.default_rng(seed)
    schedule = default_schedule(duration)
    next_arrival = rng.exponential(1.0 / qps)
    next_sample = 1.0
    req_id = 0
    completed = 0
    shed = 0
    tpots = []              # per-request decode cadence (ITL/TPOT family)
    # warm every jit shape (3 prompt buckets + the batched decode) so
    # compile time never leaks into measured compute
    for j, pl_ in enumerate((32, 64, 96)):
        engine.submit(Request(req_id=-10 - j, tenant="T1", prompt_len=pl_,
                              max_new_tokens=2, arrival=0.0))
    while engine.has_work():
        engine.finalize_step(engine.step(), 0.0)
    if templates is not None:
        # prime each template's completion off-clock (the steady-state
        # templated regime: the response cache is warm before measured
        # traffic arrives) — this also warms the verify-row jit buckets
        for tid in range(len(templates)):
            r = Request(req_id=-100 - tid, tenant="T1",
                        prompt_len=templates.shape[1],
                        max_new_tokens=max_new, arrival=0.0,
                        prompt_tokens=templates[tid].copy())
            engine.submit(r)
            while engine.has_work():
                engine.finalize_step(engine.step(), 0.0)
            completions[tid] = list(r.output_tokens)
    if auto_calibrate:
        # measure warm PER-TOKEN prefill compute on THIS host and target
        # ~120 ms virtual prefill for the 64-token median prompt.  The
        # samples are normalised by the step's prefill tokens so the
        # calibration is backend-agnostic: the paged runtime packs several
        # prompts' chunks (plus decode rows) into one fused step, and a
        # per-STEP mean would overweight those bigger steps and hand the
        # paged backend a flattering scale
        samples = []
        for j, pl_ in enumerate((32, 64, 96)):
            engine.submit(Request(req_id=-20 - j, tenant="T1",
                                  prompt_len=pl_, max_new_tokens=2,
                                  arrival=0.0))
        while engine.has_work():
            rep = engine.step()
            if rep.prefill_tokens:
                samples.append(rep.compute_s / rep.prefill_tokens)
            engine.finalize_step(rep, 0.0)
        compute_scale_7b = (0.120 / 64.0) / float(np.mean(samples))
    # warmup, template priming and calibration all drained through the
    # same engine: drop their fabricated t=0 samples so the reported
    # metrics (ITL percentiles, accept rate, drafted/accepted totals)
    # read ONLY the measured trace
    from repro.serving.metrics import TenantMetrics
    engine.metrics = TenantMetrics()
    if engine.runtime is not None:
        # the scheduler's response-cache counters are cumulative; zero
        # them too so response_cache_hit_rate reads only measured traffic
        engine.runtime.sched.rc_lookups = 0
        engine.runtime.sched.rc_hits = 0
    # attach the flight recorder only now: warm/priming/calibration ran
    # off-clock at t=0 and must stay out of the trace like they stay out
    # of metrics (engine-only harness: timelines begin lazily at first
    # step contact, the pre-compute wait labelled sched_queued)
    engine.tracer = tracer

    def t2_active_at(t):
        return any(w.tenant == "T2" and w.start <= t < w.end
                   for w in schedule)

    while now[0] < duration:
        fabric.t2_active = t2_active_at(now[0])
        # arrivals (load-shed 503-style while the tenant is paused for a
        # reconfiguration/move — counts against throughput, not latency)
        while next_arrival <= now[0]:
            if next_arrival < actuator.pause_until:
                shed += 1
            else:
                if templates is not None:
                    tid = int(rng.integers(0, len(templates)))
                    # with the engine-side response cache the frontend
                    # sends NO hints: the scheduler primes draft_hints
                    # itself from the template's recorded completion
                    hints = (None if response_cache
                             else completions.get(tid))
                    r = Request(req_id=req_id, tenant="T1",
                                prompt_len=templates.shape[1],
                                max_new_tokens=max_new,
                                arrival=next_arrival, slo_ms=200.0,
                                prompt_tokens=templates[tid].copy(),
                                draft_hints=(np.asarray(hints)
                                             if hints else None))
                else:
                    pl_ = int(rng.choice([32, 64, 96]))
                    if common is not None:
                        pl_ = max(pl_, shared_prefix + 32)
                    r = Request(req_id=req_id, tenant="T1", prompt_len=pl_,
                                max_new_tokens=max_new,
                                arrival=next_arrival, slo_ms=200.0,
                                prompt_tokens=make_prompt(pl_))
                engine.submit(r)
                req_id += 1
            next_arrival += rng.exponential(1.0 / qps)
        # controller sampling
        if controller is not None and now[0] >= next_sample:
            t1 = TenantSignals(
                p99=ttft_window.quantile(0.99, now[0]),
                p95=ttft_window.quantile(0.95, now[0]),
                p999=ttft_window.quantile(0.999, now[0]),
                miss_rate=ttft_window.miss_rate(0.200, now[0]),
                rps=completed / max(now[0], 1.0),
                ttft_p99=ttft_window.quantile(0.99, now[0]))
            sys = SystemSignals()
            t2r = topo.root_of("h0:g1")
            for root in topo.roots():
                sys.pcie_bytes[root] = (fabric.t2_demand if
                                        fabric.t2_active and root == t2r
                                        else 1e9)
            sys.host_io[topo.numa_of("h0:g1")] = \
                2.5e9 if fabric.t2_active else 0.0
            controller.on_snapshot(Snapshot(now[0], {"T1": t1}, sys))
            next_sample = now[0] + 1.0
        def advance_to(*candidates):
            """Monotone virtual-clock jump to the next future event."""
            future = [c for c in candidates if c > now[0]]
            now[0] = min(future) if future else now[0] + 0.05

        # engine work
        if now[0] < actuator.pause_until:
            advance_to(actuator.pause_until, next_arrival, next_sample)
            continue
        rep = engine.step()
        if rep.kind == "idle":
            advance_to(next_arrival, next_sample, now[0] + 0.05)
            continue
        compute = rep.compute_s * compute_scale_7b * actuator.compute_scale
        # only the prompt share of a (possibly mixed) step pays transfer
        sbytes = rep.prefill_tokens * 1.5e6      # per-token transfer bytes
        transfer = sbytes / fabric.t1_bandwidth()
        step_start = now[0]
        now[0] += compute + transfer
        engine.finalize_step(rep, now[0], step_start)
        for pr in rep.prefilled:
            ttft_window.observe(now[0], pr.ttft, slo=0.200)
        completed += len(rep.completed)
        for cr in rep.completed:
            if cr.tpot is not None:
                tpots.append(cr.tpot)

    lats = np.array([v for _, v in ttft_window.samples])
    out = {
        "backend": backend,
        "ttft_p99_ms": float(np.quantile(lats, 0.99) * 1e3) if lats.size else 0.0,
        "ttft_p50_ms": float(np.quantile(lats, 0.50) * 1e3) if lats.size else 0.0,
        "itl_p99_ms": engine.metrics.itl.quantile(0.99) * 1e3,
        "itl_p50_ms": engine.metrics.itl.quantile(0.50) * 1e3,
        # per-request decode cadence (mean seconds/token after the first —
        # the TPOT side of the ITL/TPOT family): a speculative burst's
        # tokens all land at one step's end, so burst size divides the
        # cadence even though the emission-GAP percentiles above only see
        # the burst head
        "tpot_p99_ms": (float(np.quantile(tpots, 0.99)) * 1e3
                        if tpots else 0.0),
        "tpot_p50_ms": (float(np.quantile(tpots, 0.50)) * 1e3
                        if tpots else 0.0),
        "miss_rate": float(np.mean(lats > 0.200)) if lats.size else 0.0,
        "throughput_rps": completed / duration,
        "shed": shed,
        "kv_reserved_frac": engine.metrics.kv_utilisation(),
        "kv_used_frac": engine.metrics.kv_live_utilisation(),
        "prefix_hit_rate": engine.metrics.prefix_hit_rate(),
        "spec_k": spec_k,
        "accept_rate": engine.metrics.accept_rate(),
        "drafted_tokens": engine.metrics.drafted_tokens_total,
        "accepted_tokens": engine.metrics.accepted_tokens_total,
        "response_cache_hit_rate": engine.metrics.response_hit_rate(),
        "compute_scale_7b": compute_scale_7b,
        "actions": controller.audit.counts() if controller else {},
    }
    return out


def run_shared_prefix(duration=600.0, qps=1.75, prefix_len=64, seed=0,
                      verbose=True):
    """Prefix-cache A/B on the paged backend: the same shared-system-
    prompt workload with the prefix cache ON vs OFF (no controller — the
    comparison isolates the serving-layer effect).  Reports the hit rate
    and the TTFT/ITL p99 improvement."""
    base = run(duration=duration, qps=qps, seed=seed, with_controller=False,
               backend="paged", shared_prefix=prefix_len, prefix_cache=False)
    shared = run(duration=duration, qps=qps, seed=seed, with_controller=False,
                 backend="paged", shared_prefix=prefix_len, prefix_cache=True)
    out = {
        "workload": {"duration_s": duration, "qps": qps,
                     "prefix_len": prefix_len},
        "baseline": base,
        "prefix_cache": shared,
        "prefix_hit_rate": shared["prefix_hit_rate"],
        "ttft_p99_speedup": (base["ttft_p99_ms"] /
                             max(shared["ttft_p99_ms"], 1e-9)),
        "itl_p99_speedup": (base["itl_p99_ms"] /
                            max(shared["itl_p99_ms"], 1e-9)),
    }
    if verbose:
        print("== shared-prefix workload (paged backend) ==")
        print(f"  no sharing : TTFT p99={base['ttft_p99_ms']:7.1f}ms "
              f"ITL p99={base['itl_p99_ms']:6.1f}ms")
        print(f"  prefix hit : TTFT p99={shared['ttft_p99_ms']:7.1f}ms "
              f"ITL p99={shared['itl_p99_ms']:6.1f}ms "
              f"hit-rate={shared['prefix_hit_rate']*100:.1f}%")
        print(f"  TTFT p99 speedup: {out['ttft_p99_speedup']:.2f}x "
              f"(>= 2x expected at >= 50% hit rate)")
    return out


def run_replicas(duration=600.0, qps=2.25, replicas=2, groups=8,
                 prefix_len=96, tail_len=15, max_new=4, seed=0,
                 cache_aware=True, compute_scale_7b=34.0,
                 shared_min=None, pool_pages=48, max_slots=3):
    """One arm of the cluster-wide KV-reuse A/B: ``replicas`` paged
    engines behind one dispatcher, shared-prefix-group traffic (each
    request opens with one of ``groups`` fixed page-aligned prefixes
    plus a random tail).  ``cache_aware`` picks the dispatch policy:
    route-to-longest-held-prefix via the content-hash directory, or the
    blind least-loaded baseline.  The page pool is sized so ONE replica
    cannot hold every group's prefix — blind dispatch spreads each group
    over all replicas and thrashes every cached-page LRU, while
    cache-aware routing partitions groups across replicas so each
    replica's working set fits.  Virtual time, per-replica availability
    clocks, no controller/fabric interference — the A/B isolates the
    routing effect."""
    from repro.serving.directory import (CacheAwareRouter, PrefixDirectory,
                                         RouterConfig)
    from repro.serving.metrics import TenantMetrics
    cfg = reduced(get_config("olmo2_7b"))
    engines = [ServingEngine(cfg, max_slots=max_slots, seq_cap=128,
                             seed=seed, backend="paged",
                             pool_pages=pool_pages)
               for _ in range(replicas)]
    fabric = FabricState()
    rng = np.random.default_rng(seed)
    prefixes = rng.integers(0, cfg.vocab_size, (groups, prefix_len))
    prompt_len = prefix_len + tail_len
    # warm each replica's jit buckets off-clock, BEFORE attaching the
    # directory (warm pages stay unpublished — stale-but-safe misses)
    for eng in engines:
        eng.submit(Request(req_id=-1, tenant="T1", prompt_len=prompt_len,
                           max_new_tokens=max_new, arrival=0.0,
                           prompt_tokens=rng.integers(0, cfg.vocab_size,
                                                      prompt_len)))
        while eng.has_work():
            eng.finalize_step(eng.step(), 0.0)
        eng.metrics = TenantMetrics()
        if shared_min is not None:
            _denoise_runtime(eng.runtime, shared_min, True)
    directory = PrefixDirectory(page_size=16)
    for j, eng in enumerate(engines):
        directory.attach("T1", j, eng.kv)
    router = CacheAwareRouter(directory, "T1", RouterConfig(),
                              cache_aware=cache_aware)
    # prime each group once THROUGH THE ROUTER off-clock (the measured
    # trace reads the steady state, as in run_spec's template priming).
    # Both arms get the identical procedure: blind dispatch spreads the
    # groups least-loaded, cache-aware partitions them — each arm then
    # measures the regime its policy actually produces
    for g in range(groups):
        prompt = np.concatenate([prefixes[g],
                                 rng.integers(0, cfg.vocab_size, tail_len)])
        r = Request(req_id=-10 - g, tenant="T1", prompt_len=prompt_len,
                    max_new_tokens=max_new, arrival=0.0,
                    prompt_tokens=prompt)
        loads = [len(e.queue) + len(e.active()) for e in engines]
        eng = engines[router.route(r, loads)]
        eng.submit(r)
        while eng.has_work():
            eng.finalize_step(eng.step(), 0.0)
    for eng in engines:
        eng.metrics = TenantMetrics()
    router.stats = type(router.stats)()

    now = 0.0
    avail = [0.0] * replicas
    next_arrival = rng.exponential(1.0 / qps)
    req_id = 0
    completed = 0
    ttfts = []
    while now < duration:
        while next_arrival <= now:
            prompt = np.concatenate([
                prefixes[int(rng.integers(groups))],
                rng.integers(0, cfg.vocab_size, tail_len)])
            r = Request(req_id=req_id, tenant="T1", prompt_len=prompt_len,
                        max_new_tokens=max_new, arrival=next_arrival,
                        slo_ms=200.0, prompt_tokens=prompt)
            loads = [len(e.queue) + len(e.active()) for e in engines]
            engines[router.route(r, loads)].submit(r)
            req_id += 1
            next_arrival += rng.exponential(1.0 / qps)
        stepped = False
        for j, eng in enumerate(engines):
            if avail[j] > now or not eng.has_work():
                continue
            rep = eng.step()
            if rep.kind == "idle":
                continue
            # same cost model as ``run``: scaled compute + the prompt
            # share's fabric transfer (prefix hits skip both)
            transfer = rep.prefill_tokens * 1.5e6 / fabric.t1_bandwidth()
            end = now + rep.compute_s * compute_scale_7b + transfer
            avail[j] = end
            eng.finalize_step(rep, end)
            for pr in rep.prefilled:
                ttfts.append(pr.ttft)
            completed += len(rep.completed)
            stepped = True
        if stepped:
            continue
        horizon = [t for t in avail if t > now]
        if next_arrival > now:
            horizon.append(next_arrival)
        now = min(horizon) if horizon else now + 0.05

    lats = np.array(ttfts)
    prefill = sum(e.metrics.prefill_tokens_total for e in engines)
    hits = sum(e.metrics.prefix_hit_tokens_total for e in engines)
    return {
        "cache_aware": cache_aware,
        "ttft_p99_ms": (float(np.quantile(lats, 0.99) * 1e3)
                        if lats.size else 0.0),
        "ttft_p50_ms": (float(np.quantile(lats, 0.50) * 1e3)
                        if lats.size else 0.0),
        "prefix_hit_rate": hits / max(prefill + hits, 1),
        "throughput_rps": completed / duration,
        "routing": router.stats.as_dict(),
        "directory": directory.stats.as_dict(),
    }


def run_kv_reuse(duration=600.0, qps=2.25, replicas=2, groups=8,
                 prefix_len=96, tail_len=15, seed=0, pool_pages=48,
                 max_slots=3, verbose=True):
    """Cluster-wide KV reuse A/B at R replicas: cache-aware routing vs
    blind least-loaded dispatch on the same shared-prefix-group trace.
    Per-bucket step costs are calibrated once and FROZEN across both
    arms (see ``run``'s denoise docs), so the TTFT comparison reads
    batch shapes — prefix pages skipped vs re-prefilled — and not host
    noise."""
    shared_min: dict = {}
    cal = run(duration=5.0, qps=1.0, seed=seed, with_controller=False,
              auto_calibrate=True, backend="paged", denoise=shared_min,
              verbose=False)
    kw = dict(duration=duration, qps=qps, replicas=replicas,
              groups=groups, prefix_len=prefix_len, tail_len=tail_len,
              seed=seed, compute_scale_7b=cal["compute_scale_7b"],
              shared_min=shared_min, pool_pages=pool_pages,
              max_slots=max_slots)
    blind = run_replicas(cache_aware=False, **kw)
    aware = run_replicas(cache_aware=True, **kw)
    out = {
        "workload": {"duration_s": duration, "qps": qps,
                     "replicas": replicas, "groups": groups,
                     "prefix_len": prefix_len},
        "blind": blind,
        "aware": aware,
        "hit_rate_blind": blind["prefix_hit_rate"],
        "hit_rate_aware": aware["prefix_hit_rate"],
        "ttft_p99_ratio": (blind["ttft_p99_ms"] /
                           max(aware["ttft_p99_ms"], 1e-9)),
        "ttft_p50_ratio": (blind["ttft_p50_ms"] /
                           max(aware["ttft_p50_ms"], 1e-9)),
        "throughput_ratio": (aware["throughput_rps"] /
                             max(blind["throughput_rps"], 1e-9)),
    }
    if verbose:
        print(f"== cluster-wide KV reuse ({replicas} replicas, "
              f"{groups} prefix groups) ==")
        print(f"  blind (least-loaded): TTFT p99={blind['ttft_p99_ms']:7.1f}ms "
              f"p50={blind['ttft_p50_ms']:6.1f}ms "
              f"hit-rate={blind['prefix_hit_rate']*100:.1f}% "
              f"thr={blind['throughput_rps']:.3f}rps")
        print(f"  cache-aware routing : TTFT p99={aware['ttft_p99_ms']:7.1f}ms "
              f"p50={aware['ttft_p50_ms']:6.1f}ms "
              f"hit-rate={aware['prefix_hit_rate']*100:.1f}% "
              f"thr={aware['throughput_rps']:.3f}rps "
              f"({aware['routing']['routed_cache']} cache-routed)")
        print(f"  TTFT p99 improvement: {out['ttft_p99_ratio']:.2f}x "
              f"at x{out['throughput_ratio']:.3f} throughput "
              f"(>= 1.5x expected at equal throughput)")
    return out


def run_spec(duration=600.0, qps=1.0, seed=0, spec_k=4, max_new=32,
             templates=4, verbose=True):
    """Speculative-decode A/B on the paged backend at the calibrated
    operating point (auto-calibrated per-token compute, no controller —
    the comparison isolates the serving-layer effect), decode-heavy
    traffic (``max_new`` tokens per request) in two traces:

    * **repetitive/templated**: requests draw from a few fixed prompt
      templates; each template's first completion lands in the ENGINE'S
      response cache (primed off-clock here, so the steady state is
      measured), and later requests arrive with NO client hints — the
      scheduler primes ``draft_hints`` itself at submit, so the n-gram
      drafter proposes and the fused ragged step verifies multi-token
      bursts without any frontend cooperation.  The structural win shows in the decode
      CADENCE: per-request TPOT p99 (the ITL/TPOT family's per-token
      side) drops by the burst factor, and the emission-gap ITL p50
      collapses to ~0 (burst tails land together).  The emission-gap p99
      only sees burst heads, so it tracks per-step cost and moves with
      concurrency, not with k.
    * **random**: unique random prompts, no hints — the drafter almost
      never matches and the adaptive-k EMA keeps lanes at q_len=1, so
      spec-on must track spec-off within noise (the <=5% guardrail).

    Per-step costs are denoised to per-bucket minima (see ``run``):
    without that, both arms' p99s read the host's scheduling hiccups,
    not the serving stack.
    """
    # calibrate ONCE and share the scale AND the per-bucket min table:
    # deriving either per arm would fold each run's early-measurement
    # noise into every latency of that arm, and an A/B at the p99 reads
    # exactly that noise (with shared minima, arms whose step-shape
    # traces are identical — e.g. random spec vs no_spec once adaptive k
    # has drafts at zero — replay identical virtual costs)
    shared_min: dict = {}
    cal = run(duration=5.0, qps=qps, seed=seed, with_controller=False,
              auto_calibrate=True, backend="paged", max_new=max_new,
              denoise=shared_min, verbose=False)
    scale = cal["compute_scale_7b"]
    arms = {}
    for trace, ntempl in (("repetitive", templates), ("random", 0)):
        for label, k in (("spec", spec_k), ("no_spec", 0)):
            arms[(trace, label)] = run(
                duration=duration, qps=qps, seed=seed,
                with_controller=False, compute_scale_7b=scale,
                backend="paged", spec_k=k, templated=ntempl,
                max_new=max_new, denoise=shared_min,
                response_cache=bool(ntempl))
    rep_s, rep_n = arms[("repetitive", "spec")], \
        arms[("repetitive", "no_spec")]
    rnd_s, rnd_n = arms[("random", "spec")], arms[("random", "no_spec")]

    def ratio(a, b):
        return a / max(b, 1e-9)

    out = {
        "workload": {"duration_s": duration, "qps": qps, "spec_k": spec_k,
                     "max_new": max_new, "templates": templates},
        "repetitive": {"spec": rep_s, "no_spec": rep_n},
        "random": {"spec": rnd_s, "no_spec": rnd_n},
        "accept_rate": rep_s["accept_rate"],
        # self-priming check: every templated request should hit the
        # engine-side response cache (no client hints are sent)
        "response_cache_hit_rate": rep_s["response_cache_hit_rate"],
        # the ITL/TPOT family, both sides: per-request decode-cadence p99
        # (TPOT — a speculative burst's size divides it: the structural
        # per-token win) and emission-gap percentiles (a burst's tokens
        # land together, so the gap p99 only sees burst heads and mostly
        # tracks step cost; the p50 collapses to ~0 as bursts dominate)
        "tpot_p99_improvement": 1.0 - ratio(rep_s["tpot_p99_ms"],
                                            rep_n["tpot_p99_ms"]),
        "itl_p99_improvement": 1.0 - ratio(rep_s["itl_p99_ms"],
                                           rep_n["itl_p99_ms"]),
        "itl_p50_improvement": 1.0 - ratio(rep_s["itl_p50_ms"],
                                           rep_n["itl_p50_ms"]),
        "throughput_ratio": ratio(rep_s["throughput_rps"],
                                  rep_n["throughput_rps"]),
        # adaptive-k guardrails on the non-repetitive trace
        "random_tpot_p99_regression": ratio(rnd_s["tpot_p99_ms"],
                                            rnd_n["tpot_p99_ms"]) - 1.0,
        "random_itl_p99_regression": ratio(rnd_s["itl_p99_ms"],
                                           rnd_n["itl_p99_ms"]) - 1.0,
        "random_throughput_ratio": ratio(rnd_s["throughput_rps"],
                                         rnd_n["throughput_rps"]),
        "random_accept_rate": rnd_s["accept_rate"],
    }
    if verbose:
        print("== speculative decode A/B (paged backend, "
              f"k={spec_k}, {max_new} new tokens/req) ==")
        print(f"  repetitive no-spec: TPOT p99={rep_n['tpot_p99_ms']:6.1f}ms"
              f" ITL p99={rep_n['itl_p99_ms']:6.1f}ms "
              f"p50={rep_n['itl_p50_ms']:5.1f}ms "
              f"thr={rep_n['throughput_rps']:.3f}rps")
        print(f"  repetitive spec   : TPOT p99={rep_s['tpot_p99_ms']:6.1f}ms"
              f" ITL p99={rep_s['itl_p99_ms']:6.1f}ms "
              f"p50={rep_s['itl_p50_ms']:5.1f}ms "
              f"thr={rep_s['throughput_rps']:.3f}rps "
              f"accept={rep_s['accept_rate']*100:.1f}%")
        print(f"  -> decode cadence (TPOT) p99 "
              f"{out['tpot_p99_improvement']*100:+.1f}%  emission-gap ITL "
              f"p99 {out['itl_p99_improvement']*100:+.1f}% / "
              f"p50 {out['itl_p50_improvement']*100:+.1f}%  "
              f"throughput x{out['throughput_ratio']:.3f}")
        print(f"  random     no-spec: TPOT p99={rnd_n['tpot_p99_ms']:6.1f}ms"
              f" ITL p99={rnd_n['itl_p99_ms']:6.1f}ms "
              f"thr={rnd_n['throughput_rps']:.3f}rps")
        print(f"  random     spec   : TPOT p99={rnd_s['tpot_p99_ms']:6.1f}ms"
              f" ITL p99={rnd_s['itl_p99_ms']:6.1f}ms "
              f"thr={rnd_s['throughput_rps']:.3f}rps "
              f"(TPOT regression "
              f"{out['random_tpot_p99_regression']*100:+.1f}%; adaptive k "
              f"keeps drafts at ~0 — {rnd_s['drafted_tokens']} drafted — "
              f"so residual delta is worst-request measurement noise)")
    return out


def run_door(duration=600.0, qps=4.0, seed=0, verbose=True, slots=2,
             max_new=8, door_queue=16, deadline_s=1.5, tracer=None):
    """Front-door arm: one dense engine behind a ``serving.gateway``
    door with --listen-style backpressure (bounded queue, dispatch
    deadline, Kingman-derived rate limit), run above the engine's
    comfortable operating point so the door actually queues.

    Reports the paper-relevant split the gateway makes observable:
    **door-measured** TTFT (prefill minus front-door arrival — what a
    client experiences, door-queue wait included) vs **engine-measured**
    TTFT (prefill minus engine submit), side by side, plus the full
    verdict ledger.  Door p99 >= engine p99 by construction (arrival
    precedes submit), and the gap IS the queueing delay backpressure
    policy controls.  ``conservation_ok`` asserts the verdict ledger:
    offered == completed + rejected + shed + expired after drain.
    """
    from repro.core.admission import AdmissionConfig, RateLimiter
    from repro.core.tenancy import TenantSpec
    from repro.serving.gateway import DoorConfig, Gateway
    from repro.serving.metrics import TenantMetrics

    cfg = reduced(get_config("olmo2_7b"))
    engine = ServingEngine(cfg, max_slots=slots, seq_cap=128, seed=seed,
                           backend="dense")
    rng = np.random.default_rng(seed)
    now = [0.0]
    # warm + per-token calibration exactly as ``run`` (see there)
    samples = []
    for j, pl_ in enumerate((32, 64, 96)):
        engine.submit(Request(req_id=-10 - j, tenant="T1", prompt_len=pl_,
                              max_new_tokens=2, arrival=0.0))
    while engine.has_work():
        engine.finalize_step(engine.step(), 0.0)
    for j, pl_ in enumerate((32, 64, 96)):
        engine.submit(Request(req_id=-20 - j, tenant="T1", prompt_len=pl_,
                              max_new_tokens=2, arrival=0.0))
    while engine.has_work():
        rep = engine.step()
        if rep.prefill_tokens:
            samples.append(rep.compute_s / rep.prefill_tokens)
        engine.finalize_step(rep, 0.0)
    compute_scale = (0.120 / 64.0) / float(np.mean(samples))
    engine.metrics = TenantMetrics()     # drop the fabricated t=0 samples

    # QUEUE-with-deadline policy: a transiently-full pool holds the line
    # (effectively unbounded retries) and the DEADLINE decides expiry —
    # the 503 path; queue-full and rate-limit arrivals REJECT fast (429)
    spec = TenantSpec(name="T1", rate=qps, slo_s=0.200)
    gateway = Gateway(
        {"T1": [engine]},
        door_cfgs={"T1": DoorConfig(
            max_queue=door_queue, deadline_s=deadline_s,
            max_attempts=1_000_000,
            rate_limiter=RateLimiter.kingman(spec, AdmissionConfig()))},
        tracer=tracer)
    engine.tracer = tracer    # after warm: t=0 warm steps stay untraced

    next_arrival = rng.exponential(1.0 / qps)
    req_id = 0
    done = 0
    while now[0] < duration or engine.has_work() \
            or gateway.queued_total() > 0:
        while next_arrival <= now[0] and next_arrival < duration:
            pl_ = int(rng.choice([32, 64, 96]))
            gateway.offer(Request(req_id=req_id, tenant="T1",
                                  prompt_len=pl_, max_new_tokens=max_new,
                                  arrival=next_arrival, slo_ms=200.0),
                          now[0])
            req_id += 1
            next_arrival += rng.exponential(1.0 / qps)
        gateway.dispatch(now[0])
        rep = engine.step()
        if rep.kind == "idle":
            nxt = [t for t in (next_arrival, now[0] + 0.05)
                   if t > now[0] and (t < duration or next_arrival <= now[0]
                                      or gateway.queued_total() > 0)]
            if not nxt:
                break
            now[0] = min(nxt)
            continue
        step_start = now[0]
        now[0] += rep.compute_s * compute_scale
        gateway.finalize("T1", engine, rep, now[0], start_time=step_start)
        done += len(rep.completed)
    gateway.dispatch(now[0] + deadline_s + 1.0)   # expire any stragglers
    door = gateway.door("T1")
    conservation_ok = True
    try:
        gateway.check()
    except AssertionError:
        conservation_ok = False
    out = {
        "workload": {"duration_s": duration, "qps": qps, "slots": slots,
                     "door_queue": door_queue, "deadline_s": deadline_s},
        "door_ttft_p99_ms": engine.metrics.latency.quantile(0.99) * 1e3,
        "door_ttft_p50_ms": engine.metrics.latency.quantile(0.50) * 1e3,
        "engine_ttft_p99_ms": engine.metrics.engine_ttft.quantile(0.99) * 1e3,
        "engine_ttft_p50_ms": engine.metrics.engine_ttft.quantile(0.50) * 1e3,
        "verdicts": door.counters(),
        "reject_reasons": dict(door.reject_reasons),
        "rate_limit_rps": door.cfg.rate_limiter.rate,
        "throughput_rps": done / duration,
        "conservation_ok": conservation_ok and door.in_flight == 0,
        "prometheus": gateway.prometheus(now[0]),
    }
    if verbose:
        v = out["verdicts"]
        print("== gateway front-door arm (dense backend, "
              f"{slots} slots at {qps} qps) ==")
        print(f"  door   TTFT p99={out['door_ttft_p99_ms']:7.1f}ms "
              f"p50={out['door_ttft_p50_ms']:6.1f}ms   (arrival-relative: "
              "client view, door wait included)")
        print(f"  engine TTFT p99={out['engine_ttft_p99_ms']:7.1f}ms "
              f"p50={out['engine_ttft_p50_ms']:6.1f}ms   (submit-relative)")
        print(f"  verdicts: offered={v['offered']} completed={v['completed']}"
              f" rejected={v['rejected']} expired={v['expired']} "
              f"shed={v['shed']}  conservation="
              f"{'OK' if out['conservation_ok'] else 'VIOLATED'}")
    return out


def run_trace(duration=240.0, qps=4.0, seed=0, verbose=True,
              trace_out=None):
    """Tail-attribution arm: the per-request flight recorder decomposes
    the two p99 gaps the other arms only measure end-to-end.

    * **door-vs-engine** (gateway arm, dense backend): the recorder's
      ``door_queued`` segment is *defined* as engine-submit minus
      front-door arrival, so per request ``door_ttft - door_queued ==
      engine_ttft`` exactly — the arm recomputes the engine-measured
      TTFT p99 purely from trace segments and checks it matches the
      two-window measurement (``two_window_match``).
    * **dense-vs-paged** (controller + interference): both backends run
      the same trace with a recorder attached; the TTFT p99 gap is
      attributed segment by segment (``ttft_tail_ms``: mean first-token
      window composition of the tail exemplars) — e.g. how much of the
      dense backend's extra tail is sched_queued (head-of-line blocking
      chunked prefill removes) vs prefill compute.
    * **tracing-off parity**: the same paged workload twice — recorder
      attached vs not — under a SHARED frozen per-bucket step-cost
      table (see ``run``'s denoise docs; raw per-step wall-clock varies
      run to run, so frozen costs are what makes "identical" testable).
      TTFT/ITL p99 and throughput must be bit-identical
      (``parity_ok``): tracing never perturbs the virtual clock.

    ``trace_out`` dumps the paged arm's full Chrome/Perfetto timeline
    (request spans + controller actions — CI uploads it).
    """
    from repro.serving.trace import FlightRecorder

    rec = FlightRecorder()
    door = run_door(duration=duration, qps=qps, seed=seed, verbose=False,
                    tracer=rec)
    summaries = [s for s in rec.summaries.get("T1", ())
                 if s.verdict == "completed" and s.ttft is not None]
    door_ttft = np.array([s.ttft for s in summaries])
    # the per-request identity: engine TTFT reconstructed from segments
    eng_ttft = np.array([s.ttft - s.segs.get("door_queued", 0.0)
                         for s in summaries])
    door_p99_tr = float(np.quantile(door_ttft, 0.99)) * 1e3
    eng_p99_tr = float(np.quantile(eng_ttft, 0.99)) * 1e3
    door_part = {
        "door_ttft_p99_ms": door["door_ttft_p99_ms"],
        "engine_ttft_p99_ms": door["engine_ttft_p99_ms"],
        "p99_gap_ms": door["door_ttft_p99_ms"]
        - door["engine_ttft_p99_ms"],
        "door_queued_p99_ms": rec.segment_quantile(
            "T1", "door_queued", 0.99) * 1e3,
        "trace_door_ttft_p99_ms": door_p99_tr,
        "trace_engine_ttft_p99_ms": eng_p99_tr,
        # segments reproduce BOTH window measurements (same per-request
        # values, same quantile): the gap is fully attributed
        "two_window_match": bool(
            abs(door_p99_tr - door["door_ttft_p99_ms"]) < 1e-6
            and abs(eng_p99_tr - door["engine_ttft_p99_ms"]) < 1e-6),
        "verdicts": door["verdicts"],
        "tail_ms": rec.breakdown().get("T1", {}).get("tail_ms", {}),
    }

    # tracing-off parity: same paged workload, frozen shared step costs,
    # recorder on vs off — results must be bit-identical.
    shared_min: dict = {}
    cal = run(duration=5.0, qps=1.0, seed=seed, with_controller=False,
              auto_calibrate=True, backend="paged", denoise=shared_min,
              verbose=False)
    pkw = dict(duration=min(duration, 60.0), qps=1.75, seed=seed,
               with_controller=False, backend="paged",
               compute_scale_7b=cal["compute_scale_7b"],
               denoise=shared_min, verbose=False)
    traced = run(tracer=FlightRecorder(), **pkw)
    untraced = run(**pkw)
    parity_keys = ("ttft_p99_ms", "itl_p99_ms", "throughput_rps",
                   "shed", "miss_rate")
    parity_ok = bool(all(traced[k] == untraced[k] for k in parity_keys))
    door_part["parity_ok"] = parity_ok

    ab = {}
    recs = {}
    for b in ("dense", "paged"):
        r = FlightRecorder()
        res = run(duration=duration, qps=1.75, seed=seed,
                  with_controller=True, backend=b, auto_calibrate=True,
                  tracer=r, verbose=False)
        r.check()
        bd = r.breakdown().get("T1", {})
        ab[b] = {"ttft_p99_ms": res["ttft_p99_ms"],
                 "itl_p99_ms": res["itl_p99_ms"],
                 "actions": res["actions"],
                 "breakdown": bd}
        recs[b] = r
    segs = sorted(set(ab["dense"]["breakdown"].get("ttft_tail_ms", {}))
                  | set(ab["paged"]["breakdown"].get("ttft_tail_ms", {})))
    gap_by_segment = {
        s: ab["dense"]["breakdown"].get("ttft_tail_ms", {}).get(s, 0.0)
        - ab["paged"]["breakdown"].get("ttft_tail_ms", {}).get(s, 0.0)
        for s in segs}
    out = {
        "workload": {"duration_s": duration, "qps": qps, "seed": seed},
        "door": door_part,
        "dense": ab["dense"],
        "paged": ab["paged"],
        "dense_vs_paged_ttft_p99_gap_ms": (ab["dense"]["ttft_p99_ms"]
                                           - ab["paged"]["ttft_p99_ms"]),
        "ttft_gap_by_segment_ms": gap_by_segment,
    }
    if trace_out:
        recs["paged"].dump(trace_out)
        out["trace_out"] = trace_out
    if verbose:
        d = door_part
        print("== tail-attribution trace arm ==")
        print(f"  door vs engine TTFT p99: {d['door_ttft_p99_ms']:.1f} vs "
              f"{d['engine_ttft_p99_ms']:.1f} ms (gap "
              f"{d['p99_gap_ms']:.1f} ms; door_queued segment p99 "
              f"{d['door_queued_p99_ms']:.1f} ms)  "
              f"two-window match: {d['two_window_match']}  "
              f"untraced parity: {d['parity_ok']}")
        print(f"  dense vs paged TTFT p99: "
              f"{ab['dense']['ttft_p99_ms']:.1f} vs "
              f"{ab['paged']['ttft_p99_ms']:.1f} ms — tail gap by "
              f"segment (ms): "
              + ", ".join(f"{k}={v:+.1f}"
                          for k, v in gap_by_segment.items()))
        for b in ("dense", "paged"):
            print(f"  [{b}] {recs[b].table()}")
        if trace_out:
            print(f"  Perfetto trace written to {trace_out}")
    return out


def run_chaos(requests=48, qps=300.0, replicas=2, seed=0, verbose=True):
    """Failure-domain A/B: the SAME seeded fault schedule (a replica
    crash during the arrival burst plus a stuck decode lane on the
    survivor) replayed through the full serving stack twice — recovery
    machinery ON (redrive + watchdog requeue) vs OFF (drained work is
    shed).  Both arms run ``launch.serve``'s production path: gateway
    ledger, cache-aware routing, paged replicas, flight recorder hooks.
    The verdict ledger must conserve in BOTH arms — recovery changes
    which verdict each request gets, never whether it gets one."""
    from repro.core.faults import Fault, FaultInjector
    from repro.launch.serve import serve

    def schedule():
        # a fresh injector per arm: delivery is stateful, and the A/B
        # needs both arms to consume the identical schedule
        return FaultInjector([
            Fault(time=0.03, kind="replica_crash", tenant="T1", replica=1),
            Fault(time=0.06, kind="lane_stuck", tenant="T1", replica=0),
        ])

    kw = dict(requests=requests, qps=qps, replicas=replicas, seed=seed,
              backend="paged", with_controller=False, verbose=False,
              watchdog_timeout_s=0.3)
    on = serve(faults=schedule(), recover=True, **kw)
    off = serve(faults=schedule(), recover=False, **kw)

    def arm(res):
        d = dict(res["T1"])
        offered = max(d["offered"], 1)
        return {
            "verdicts": {k: d[k] for k in ("offered", "completed", "shed",
                                           "rejected", "expired",
                                           "redriven", "preempted")},
            "completion_rate": d["completed"] / offered,
            "conservation_ok": (d["offered"] == d["completed"] + d["shed"]
                                + d["rejected"] + d["expired"]),
            "ttft_p99_ms": d["ttft_p99_ms"],
            "faults": {k: res["faults"][k]
                       for k in ("log", "redriven", "watchdog_fired")},
        }

    a_on, a_off = arm(on), arm(off)
    out = {
        "workload": {"requests": requests, "qps": qps,
                     "replicas": replicas, "seed": seed},
        "schedule": [(f.time, f.kind, f.tenant, f.replica)
                     for f in schedule().schedule],
        "recovery_on": a_on,
        "recovery_off": a_off,
        "completion_rate_on": a_on["completion_rate"],
        "completion_rate_off": a_off["completion_rate"],
        "redriven_on": a_on["verdicts"]["redriven"],
        "shed_off": a_off["verdicts"]["shed"],
        "conservation_ok": (a_on["conservation_ok"]
                            and a_off["conservation_ok"]),
    }
    if verbose:
        print(f"== chaos A/B ({replicas} paged replicas, crash + stuck "
              f"lane, same schedule) ==")
        for label, a in (("recovery on ", a_on), ("recovery off", a_off)):
            v = a["verdicts"]
            print(f"  {label}: completed {v['completed']}/{v['offered']} "
                  f"({a['completion_rate']*100:5.1f}%) shed={v['shed']} "
                  f"redriven={v['redriven']} "
                  f"watchdog={a['faults']['watchdog_fired']} "
                  f"TTFT p99={a['ttft_p99_ms']:.1f}ms")
        print(f"  conservation: "
              f"{'OK' if out['conservation_ok'] else 'VIOLATED'} "
              f"(both arms; recovery moves verdicts, never loses one)")
    return out


def run_migrate(requests=24, qps=2000.0, replicas=2, prompt_len=112,
                max_new=8, slots=24, seed=0, verbose=True):
    """Live-migration A/B (the ``--chaos --migrate`` arm): the SAME
    fault schedule — one replica crash mid-burst — through
    ``launch.serve`` twice, recovery by verified KV-page shipping
    (``migrate=True``) vs the recompute redrive.  Both arms plus a
    fault-free reference run under ``det_timing`` AND ``exact_tokens``
    (float32 + reference attention), so each run is bit-reproducible
    and greedy output is a pure function of the prompt: the parity
    checks below are exact, not statistical.

    The crash is the only fault, so both arms' virtual schedules are
    bit-identical to the fault-free run up to the crash instant and
    they drain the SAME lane set — the redriven cohorts match and the
    TTFT comparison is over identical request ids.  A warm-shipped lane
    resumes on page bytes identical to the ones the fault-free run
    decodes over; a cold (or recompute-redriven) lane re-prefills, and
    with exact numerics re-prefill regenerates the same tokens — so
    EVERY redriven request must be TOKEN-IDENTICAL to the fault-free
    run (``token_parity_ok``), in both arms.  What differs is time: the
    recompute arm re-prefills every drained lane, so the redriven
    cohort pays re-prefill queueing the shipping arm skips —
    ``redriven_ttft_p99_improvement`` is that gap, at equal completed
    throughput.

    Two more single-arm runs demonstrate the remaining triggers: a
    planned drain (``drains=``: scale-down evacuates, sheds nothing)
    and the gray-failure path (a ``replica_slow`` window; the
    tail-based detector evacuates the degraded-but-alive replica before
    the watchdog would fire).  Those schedules diverge timing-wise from
    the reference the moment the slow window opens, so they demo the
    triggers rather than gate on parity.
    """
    from repro.core.faults import Fault, FaultInjector
    from repro.launch.serve import serve

    def schedule():
        # fresh injector per arm: delivery is stateful, the A/B needs
        # both arms to consume the identical schedule.  Crash-only (see
        # docstring: pre-crash bit-identity with the fault-free run is
        # what makes the cohorts and the token streams comparable).
        return FaultInjector([
            Fault(time=0.05, kind="replica_crash", tenant="T1", replica=1),
        ])

    kw = dict(requests=requests, qps=qps, replicas=replicas, seed=seed,
              prompt_len=prompt_len, max_new=max_new, slots=slots,
              backend="paged", with_controller=False, verbose=False,
              watchdog_timeout_s=0.5, det_timing=True,
              # fully distinct per-request prompts: the prefix directory
              # must not quietly refund the recompute arm's re-prefill
              # (templated traffic would attach nearly every page, and
              # the A/B would be measuring the directory, not shipping)
              unique_prompts=True)
    ab = dict(kw, exact_tokens=True)
    base = serve(**ab)                          # fault-free reference
    rec = serve(faults=schedule(), recover=True, migrate=False, **ab)
    mig = serve(faults=schedule(), recover=True, migrate=True, **ab)

    def arm(res):
        d = res["T1"]
        return {
            "verdicts": {k: d[k] for k in ("offered", "completed", "shed",
                                           "rejected", "expired",
                                           "redriven", "preempted")},
            "conservation_ok": (d["offered"] == d["completed"] + d["shed"]
                                + d["rejected"] + d["expired"]),
            "ttft_p99_ms": d["ttft_p99_ms"],
            "redriven_ids": d["redriven_ids"],
            "migrations": res.get("migrations", []),
        }

    a_rec, a_mig = arm(rec), arm(mig)
    # the cohort: requests either arm had to rescue.  With a crash-only
    # schedule both arms drain the same lanes, so the sets must match —
    # assert it, or the p99 comparison silently goes apples-to-oranges.
    rec_ids, mig_ids = set(a_rec["redriven_ids"]), set(a_mig["redriven_ids"])
    cohort = sorted(rec_ids | mig_ids)
    cohorts_match = rec_ids == mig_ids

    def cohort_p99(res):
        t = [res["T1"]["ttft_by_id"][i] for i in cohort
             if i in res["T1"]["ttft_by_id"]]
        return float(np.quantile(t, 0.99)) if t else 0.0

    a_rec["redriven_ttft_p99_ms"] = cohort_p99(rec)
    a_mig["redriven_ttft_p99_ms"] = cohort_p99(mig)
    # token parity: every completed request either arm redrove must
    # match the fault-free run's greedy output exactly — page shipping
    # AND recompute both land on the same tokens, only the time differs
    base_out = base["T1"]["outputs"]
    parity_mismatches = sorted(
        {rid for res in (rec, mig)
         for rid in cohort
         if rid in res["T1"]["outputs"] and rid in base_out
         and res["T1"]["outputs"][rid] != base_out[rid]})
    warm_lanes = sum(m["warm"] for m in mig.get("migrations", ()))
    imp = 1.0 - (a_mig["redriven_ttft_p99_ms"]
                 / max(a_rec["redriven_ttft_p99_ms"], 1e-9))

    # ---- remaining triggers, single-arm demos ------------------------
    drain = serve(drains=[(0.04, "T1", 1)], migrate=True, **kw)
    gray = serve(faults=FaultInjector([
        Fault(time=0.04, kind="replica_slow", tenant="T1", replica=1,
              factor=4.0, duration_s=0.8)]),
        recover=True, migrate=True, **kw)
    gray_migs = [m for m in gray.get("migrations", ())
                 if m["reason"] == "gray"]
    drain_migs = [m for m in drain.get("migrations", ())
                  if m["reason"] == "drain"]

    out = {
        "workload": {"requests": requests, "qps": qps,
                     "replicas": replicas, "prompt_len": prompt_len,
                     "max_new": max_new, "seed": seed},
        "schedule": [(f.time, f.kind, f.tenant, f.replica)
                     for f in schedule().schedule],
        "recompute": a_rec,
        "migrate": a_mig,
        "redriven_requests": len(cohort),
        "cohorts_match": cohorts_match,
        "warm_lanes": warm_lanes,
        "token_parity_ok": not parity_mismatches,
        "token_parity_mismatches": parity_mismatches,
        "redriven_ttft_p99_improvement": imp,
        "throughput_equal": (a_mig["verdicts"]["completed"]
                            == a_rec["verdicts"]["completed"]),
        "conservation_ok": (a_rec["conservation_ok"]
                            and a_mig["conservation_ok"]),
        "drain": {"migrations": drain_migs,
                  "shed": drain["T1"]["shed"],
                  "completed": drain["T1"]["completed"],
                  "offered": drain["T1"]["offered"]},
        "gray": {"migrations": gray_migs,
                 "evacuations": sum(1 for _, k, _ in
                                    gray["faults"]["log"]
                                    if k == "gray_evacuate"),
                 "completed": gray["T1"]["completed"],
                 "offered": gray["T1"]["offered"]},
    }
    if verbose:
        print(f"== live-migration A/B ({replicas} paged replicas, "
              f"crash mid-burst, same schedule) ==")
        for label, a in (("recompute", a_rec), ("page-ship", a_mig)):
            v = a["verdicts"]
            print(f"  {label:9s}: completed {v['completed']}/{v['offered']}"
                  f" redriven={v['redriven']} "
                  f"redriven-TTFT p99={a['redriven_ttft_p99_ms']:.1f}ms "
                  f"overall p99={a['ttft_p99_ms']:.1f}ms")
        print(f"  warm lanes shipped: {warm_lanes} "
              f"({sum(m['bytes'] for m in a_mig['migrations']) / 1e6:.2f}"
              f" MB, {len(a_mig['migrations'])} migration(s)) "
              f"cohorts match: {cohorts_match}")
        print(f"  token parity ({len(cohort)} redriven req(s), both arms, "
              f"vs fault-free): "
              f"{'OK' if out['token_parity_ok'] else 'VIOLATED'}")
        print(f"  redriven-TTFT p99 improvement: {imp * 100:+.1f}% "
              f"(>= 25% expected) at equal throughput: "
              f"{out['throughput_equal']}")
        print(f"  drain trigger: {len(drain_migs)} migration(s), "
              f"shed={out['drain']['shed']} (evacuate, never shed)  "
              f"gray trigger: {out['gray']['evacuations']} evacuation(s)")
    # token streams are for the parity check, not the artifact
    for res in (base, rec, mig, drain, gray):
        res["T1"].pop("outputs", None)
        res["T1"].pop("ttft_by_id", None)
    return out


def run_backend(backend="dense", verbose=True, seed=0, duration=1800.0):
    static = run(with_controller=False, seed=seed, backend=backend,
                 duration=duration)
    full = run(with_controller=True, seed=seed, backend=backend,
               duration=duration)
    norm = full["throughput_rps"] / max(static["throughput_rps"], 1e-9)
    if verbose:
        print(f"  [{backend}] static: TTFT p99={static['ttft_p99_ms']:6.1f}ms "
              f"(paper 232ms) ITL p99={static['itl_p99_ms']:5.1f}ms "
              f"miss={static['miss_rate']*100:.1f}%")
        print(f"  [{backend}] full  : TTFT p99={full['ttft_p99_ms']:6.1f}ms "
              f"(paper 199ms) ITL p99={full['itl_p99_ms']:5.1f}ms "
              f"miss={full['miss_rate']*100:.1f}% "
              f"actions={full['actions']}")
        print(f"  [{backend}] TTFT p99 reduction: "
              f"{(1 - full['ttft_p99_ms']/static['ttft_p99_ms'])*100:.1f}% "
              f"(paper ~13%)  norm throughput: {norm:.3f} (paper 0.96)")
    return {"static": static, "full": full, "norm_throughput": norm}


def _maybe_dump(out, json_path):
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(verbose=True, backend="dense", shared_prefix=False, spec=False,
         duration=1800.0, json_path=None, replicas=0, door=False,
         trace=False, trace_out=None, chaos=False, chaos_requests=48,
         migrate=False):
    if verbose:
        print("== LLM serving case study (vLLM-style, OLMo-2-7B) ==")
    if chaos and migrate:
        return _maybe_dump(run_migrate(verbose=verbose), json_path)
    if chaos:
        return _maybe_dump(run_chaos(requests=chaos_requests,
                                     verbose=verbose), json_path)
    if trace:
        return _maybe_dump(run_trace(duration=duration, verbose=verbose,
                                     trace_out=trace_out), json_path)
    if door:
        return _maybe_dump(run_door(duration=duration, verbose=verbose),
                           json_path)
    if replicas:
        return _maybe_dump(run_kv_reuse(duration=duration,
                                        replicas=replicas,
                                        verbose=verbose), json_path)
    if spec:
        return _maybe_dump(run_spec(duration=duration, verbose=verbose),
                           json_path)
    if shared_prefix:
        return _maybe_dump(run_shared_prefix(duration=duration,
                                             verbose=verbose), json_path)
    if backend != "both":
        return _maybe_dump(run_backend(backend, verbose=verbose,
                                       duration=duration), json_path)
    # A/B: the same trace + controller through both runtimes, side by side
    out = {b: run_backend(b, verbose=verbose, duration=duration)
           for b in ("dense", "paged")}
    if verbose:
        d, p = out["dense"]["full"], out["paged"]["full"]
        print(f"  A/B (full system): TTFT p99 dense {d['ttft_p99_ms']:.1f}ms "
              f"vs paged {p['ttft_p99_ms']:.1f}ms "
              f"({(1 - p['ttft_p99_ms']/max(d['ttft_p99_ms'], 1e-9))*100:+.1f}%)"
              f" | ITL p99 dense {d['itl_p99_ms']:.1f}ms "
              f"vs paged {p['itl_p99_ms']:.1f}ms")
    return _maybe_dump(out, json_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("dense", "paged", "both"),
                    default="dense",
                    help="engine backend; 'both' emits the dense-vs-paged "
                         "TTFT/ITL A/B side by side")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="prefix-cache workload arm (paged backend): "
                         "shared-system-prompt traffic, cache on vs off, "
                         "reporting hit rate and TTFT/ITL p99 speedups")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decode A/B arm (paged backend): "
                         "repetitive/templated vs random decode-heavy "
                         "traces, spec on vs off, reporting accept rate "
                         "plus ITL p99 and throughput deltas")
    ap.add_argument("--replicas", type=int, default=0,
                    help="cluster-wide KV reuse A/B arm: N paged replicas "
                         "behind one dispatcher, cache-aware routing vs "
                         "blind least-loaded on the same shared-prefix-"
                         "group trace (0 = off)")
    ap.add_argument("--door", action="store_true",
                    help="gateway front-door arm: a dense engine behind a "
                         "bounded backpressure door, reporting door- vs "
                         "engine-measured TTFT p99 side by side plus the "
                         "verdict-conservation ledger")
    ap.add_argument("--trace", action="store_true",
                    help="tail-attribution arm: per-request flight-"
                         "recorder traces decompose the door-vs-engine "
                         "and dense-vs-paged TTFT p99 gaps by named "
                         "segment, with conservation + untraced-parity "
                         "checks")
    ap.add_argument("--chaos", action="store_true",
                    help="failure-domain A/B arm: the same seeded fault "
                         "schedule (replica crash + stuck lane) through "
                         "the full serving stack with recovery on vs "
                         "off, reporting completion rates and the "
                         "conservation verdict")
    ap.add_argument("--chaos-requests", type=int, default=48,
                    help="--chaos: requests per arm")
    ap.add_argument("--migrate", action="store_true",
                    help="with --chaos: live-migration A/B — the same "
                         "fault schedule recovered by verified KV-page "
                         "shipping vs recompute redrive, with exact "
                         "token-parity and redriven-TTFT asserts "
                         "(deterministic timing model)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="--trace: write the paged arm's Chrome/Perfetto "
                         "trace_event JSON here")
    ap.add_argument("--duration", type=float, default=1800.0,
                    help="virtual-time seconds per run (CI uses a short "
                         "duration)")
    ap.add_argument("--json", default=None,
                    help="write the result dict to this JSON file")
    args = ap.parse_args()
    main(backend=args.backend, shared_prefix=args.shared_prefix,
         spec=args.spec, duration=args.duration, json_path=args.json,
         replicas=args.replicas, door=args.door, trace=args.trace,
         trace_out=args.trace_out, chaos=args.chaos,
         chaos_requests=args.chaos_requests, migrate=args.migrate)
