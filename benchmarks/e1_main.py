"""E1 — Main experiment (paper §3.3.1, Figures 3/4): full controller vs
static MIG + naive placement under toggling T2/T3 interference."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_config, summarise


def run(seeds=range(7), duration=3600.0, verbose=True):
    static = run_config("static", seeds, duration)
    full = run_config("full", seeds, duration)
    s, f = summarise(static), summarise(full)
    miss_reduction = 1 - f["miss"] / max(s["miss"], 1e-9)
    p99_reduction = 1 - f["p99"] / max(s["p99"], 1e-9)
    thr_cost = 1 - f["thr"] / max(s["thr"], 1e-9)
    out = {
        "static": s, "full": f,
        "miss_reduction": miss_reduction,
        "p99_reduction": p99_reduction,
        "throughput_cost": thr_cost,
        # Fig 3a analogue: the escalation timeline of one run
        "timeline": [(round(t, 1), a) for t, a in
                     run_config("full", [0], duration)[0].timeline],
    }
    if verbose:
        print("== E1: full controller vs static MIG ==")
        print(f"  static : miss={s['miss']:5.2f}+-{s['miss_ci']:.2f}% "
              f"p99={s['p99']:5.2f}+-{s['p99_ci']:.2f}ms thr={s['thr']:.2f}rps")
        print(f"  full   : miss={f['miss']:5.2f}+-{f['miss_ci']:.2f}% "
              f"p99={f['p99']:5.2f}+-{f['p99_ci']:.2f}ms thr={f['thr']:.2f}rps")
        print(f"  SLO miss-rate reduction: {miss_reduction*100:.1f}% "
              f"(paper: ~32%, ~1.5x)")
        print(f"  p99 reduction:           {p99_reduction*100:.1f}% "
              f"(paper: ~15%)")
        print(f"  throughput cost:         {thr_cost*100:.1f}% "
              f"(paper: <=5%)")
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=7)
    ap.add_argument("--duration", type=float, default=3600.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 2 seeds x 600 s")
    args = ap.parse_args()
    if args.smoke:
        run(seeds=range(2), duration=600.0)
    else:
        run(seeds=range(args.seeds), duration=args.duration)


if __name__ == "__main__":
    main()
