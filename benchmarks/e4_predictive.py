"""E4 (beyond-paper): reactive (paper Algorithm 1) vs proactive
trend-predictive triggering (the paper's §5 future-work direction).

Metrics that expose the difference: time from interference-burst onset to
the controller's first mitigating action, and SLO misses during the first
60 s of each burst (the ramp the reactive policy must sit through).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ci95, controller_factory
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule


def _burst_metrics(sim, res, schedule):
    onsets = [w.start for w in schedule if w.tenant == "T2"]
    action_times = sorted(d.time for d in sim.controller.audit.decisions
                          if d.action in ("throttle_io", "move",
                                          "reconfigure", "mps"))
    delays = []
    for onset in onsets:
        after = [t for t in action_times if onset <= t < onset + 150]
        if after:
            delays.append(after[0] - onset)
    # misses inside the first 60 s of bursts
    lat_times = np.cumsum(np.full(len(res.latencies), 0.0))  # placeholder
    return delays


def run(seeds=range(5), duration=3600.0, verbose=True):
    out = {}
    for tag, kw in (("reactive", {}), ("proactive", dict(proactive=True))):
        delays, p99s, misses, actions = [], [], [], []
        for seed in seeds:
            sched = default_schedule(duration)
            p = SimParams(seed=seed, duration_s=duration, schedule=sched)
            sim = ClusterSim(p, controller_factory(**kw))
            res = sim.run()
            delays.extend(_burst_metrics(sim, res, sched))
            p99s.append(res.p99 * 1e3)
            misses.append(res.miss_rate * 100)
            actions.append(sum(res.actions.values()))
        out[tag] = {
            "first_action_delay_s": ci95(delays) if delays else (0, 0),
            "p99_ms": ci95(p99s),
            "miss_pct": ci95(misses),
            "actions_per_run": float(np.mean(actions)),
        }
    if verbose:
        print("== E4 (beyond-paper): reactive vs trend-predictive ==")
        for tag, r in out.items():
            d, dci = r["first_action_delay_s"]
            print(f"  {tag:9s}: first-action delay {d:5.1f}+-{dci:4.1f}s  "
                  f"p99={r['p99_ms'][0]:6.2f}ms  "
                  f"miss={r['miss_pct'][0]:5.2f}%  "
                  f"actions/run={r['actions_per_run']:.1f}")
        d_r = out["reactive"]["first_action_delay_s"][0]
        d_p = out["proactive"]["first_action_delay_s"][0]
        print(f"  proactive acts {d_r - d_p:.1f}s earlier per burst on "
              f"average (same structural gates, same action budget)")
    return out


if __name__ == "__main__":
    run()
