"""E5 — Multi-tenant scaling (beyond-paper): N competing SLO tenants x R
replicas each, driven through the same controller + shared MIG arbiter.

The paper evaluates one latency-sensitive tenant against two interferers;
this experiment sweeps 2-8 latency tenants (each with R >= 1 batched
replicas, least-loaded dispatch) co-located with the same ETL/training
interferer classes, and reports per-tenant miss-rate/p99 plus aggregate
throughput for static-MIG vs controlled.  The arbiter audit proves the
per-GPU compute-unit budget (7) is never exceeded while lanes compete for
upgrades (the MIG-serving / ParvaGPU regime).

``--churn`` adds an admission-churn arm per cell: a seeded stream of
late-arriving tenants (safe / fabric-saturating / rho-violating classes)
is pushed through the registry-driven AdmissionController against the
fleet's DeviceLedger, with periodic departures freeing capacity so QUEUE'd
tenants re-admit; per-verdict counts are reported alongside the arbiter
audit and the ledger invariants are asserted at the end.

``--engine-backend dense|paged|both`` adds a real-engine arm (once per
run, not per cell): a small multi-tenant trace served by live JAX engines
via ``repro.launch.serve`` on the selected KV backend(s), so the sweep's
JSON also tracks the serving runtime the simulator abstracts.

``--hosts 4`` swaps the fleet onto the scaled 4-host p4d topology
(``make_p4d_fleet``) and every cell reports controller wall-clock per
decision tick beside the arbiter audit — the first "scale the fleet"
measurement (Table 4's controller-CPU% analogue at fleet size).

    PYTHONPATH=src:. python benchmarks/e5_multitenant.py \
        [--tenants 2,4,8] [--replicas 1,2] [--duration 900] [--seed 0] \
        [--hosts 4] [--churn] [--engine-backend both] [--out e5.json] \
        [--smoke]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.admission import (AdmissionConfig, AdmissionController,
                                  AdmissionVerdict)
from repro.core.controller import Controller, ControllerConfig
from repro.core.ledger import DeviceLedger
from repro.core.profiles import A100_MIG
from repro.core.tenancy import BACKGROUND, TenantRegistry, TenantSpec
from repro.core.topology import make_p4d_fleet
from repro.sim.cluster import ClusterSim
from repro.sim.params import InterferenceWindow, SimParams


def fleet_schedule(duration: float) -> tuple:
    """The paper's toggling-interference cadence, addressed to the fleet's
    interferer names (ETL / TRAIN)."""
    out = []
    t = 60.0
    while t + 230 < duration:
        out.append(InterferenceWindow("ETL", t, t + 150))
        out.append(InterferenceWindow("TRAIN", t + 75, t + 225))
        t += 300.0
    return tuple(out)


def make_params(n_tenants: int, replicas: int, duration: float,
                seed: int) -> SimParams:
    reg = TenantRegistry.slo_fleet(n_tenants, replicas)
    return SimParams(seed=seed, duration_s=duration,
                     schedule=fleet_schedule(duration),
                     tenants=tuple(reg))


def controlled_factory(sim, tracer=None):
    c = Controller(sim.topo, sim.lattice, sim, ControllerConfig(),
                   tracer=tracer)
    sim.register_tenants(c)
    return c


def pause_correlation(sim, tracer) -> dict:
    """Correlate controller pause windows with per-tenant tail spikes.

    Every reconfigure/move lands on the tracer's ``controller`` track as
    a span covering its pause window; each latency tenant's window keeps
    (completion-time, latency) samples.  A pause's damage shows both
    inside the window and in the backlog drain right after it, so each
    window is extended by one pause-length of recovery.  Reports the
    per-tenant p99 of samples inside vs outside, and their ratio — the
    "reconfig pauses ARE the tail spikes" attribution E5 previously
    could only eyeball from the timeline."""
    windows = [(ev.ts, ev.ts + 2 * ev.dur)
               for ev in tracer.actions if ev.dur > 0]
    out = {}
    for name, lt in sim.lat.items():
        inside, outside = [], []
        for t, v in lt.window.samples:
            hit = any(a <= t <= b for a, b in windows)
            (inside if hit else outside).append(v)
        rec = {"pauses": len(windows), "samples_in": len(inside),
               "samples_out": len(outside)}
        if inside:
            rec["p99_in_pause_ms"] = round(
                float(np.quantile(inside, 0.99)) * 1e3, 3)
        if outside:
            rec["p99_outside_ms"] = round(
                float(np.quantile(outside, 0.99)) * 1e3, 3)
        if inside and outside:
            rec["tail_spike_x"] = round(
                rec["p99_in_pause_ms"] / max(rec["p99_outside_ms"], 1e-9),
                3)
        out[name] = rec
    return out


def tenant_rows(res) -> dict:
    return {name: {
        "miss_rate": round(t.miss_rate, 5),
        "p99_ms": round(t.p99 * 1e3, 3),
        "p95_ms": round(t.p95 * 1e3, 3),
        "completed": t.completed,
        "dropped": t.dropped,
        "throughput_rps": round(t.throughput_rps, 3),
        "replicas": t.replicas,
    } for name, t in res.tenants.items()}


def churn_spec(kind: str, idx: int) -> TenantSpec:
    """One late-arriving tenant of a given admission class."""
    sizes = ((0.75, 12e6), (0.20, 24e6), (0.05, 32e6))
    if kind == "safe":
        return TenantSpec(name=f"C{idx}", rate=4.0, slo_s=0.015,
                          sizes=sizes)
    if kind == "fabric":        # Claim-1-bound: over half a root's
        # capacity, so no two such streams (or one plus the ETL) share a
        # root complex — they queue until a departure frees a fabric
        return TenantSpec(name=f"C{idx}", role=BACKGROUND,
                          pcie_demand=13e9, ps_weight=4.0)
    # rho-violating: its own utilisation bound breaks at any share
    return TenantSpec(name=f"C{idx}", rate=400.0, slo_s=0.015, sizes=sizes)


def run_churn(n_tenants: int, replicas: int, seed: int,
              arrivals: int = 24, hosts: int = 2) -> dict:
    """Admission-churn arm: stream late tenants through the registry-
    driven admission controller over the fleet's shared ledger; every 4th
    arrival an admitted tenant departs, so QUEUE'd tenants re-admit."""
    reg = TenantRegistry.slo_fleet(n_tenants, replicas)
    topo = make_p4d_fleet(hosts)
    ledger = DeviceLedger.from_registry(topo, reg, A100_MIG,
                                        home_devices=("h0:g0",),
                                        ambient_units=3)
    adm = AdmissionController(topo, reg, ledger, AdmissionConfig())
    rng = np.random.default_rng(seed)
    # fabric-heavy mix: the 13e9 streams saturate the 7 quiet roots
    # (Claim-1) partway through the stream, so the QUEUE->retry->ADMIT
    # path is exercised, not just the terminal verdicts
    kinds = ("safe", "fabric", "fabric", "hot")
    admitted = []                          # (name, kind), admission order
    readmitted = 0
    for k in range(arrivals):
        kind = kinds[int(rng.integers(0, 4))]
        verdict, _slots = adm.decide(churn_spec(kind, k), now=float(k))
        if verdict == AdmissionVerdict.ADMIT:
            admitted.append((f"C{k}", kind))
        if k % 4 == 3 and admitted:
            # churn: a tenant departs — ETL-style fabric streams finish
            # first (they are the short-lived class), freeing their root
            # so a QUEUE'd tenant can land on retry
            idx = next((i for i, (_, kd) in enumerate(admitted)
                        if kd == "fabric"), 0)
            adm.release(admitted.pop(idx)[0], now=float(k))
            readmitted += len(adm.retry_queued(now=float(k)))
    ledger.check()
    return {
        "arrivals": arrivals,
        "verdicts": adm.counts(),
        "readmitted_after_free": readmitted,
        "still_queued": len(adm.queue),
        "ledger_ok": ledger.check_ok(),
    }


def run_cell(n_tenants: int, replicas: int, duration: float,
             seed: int, churn: bool = False, hosts: int = 2) -> dict:
    p = make_params(n_tenants, replicas, duration, seed)
    topo = make_p4d_fleet(hosts)
    static = ClusterSim(p, topo=topo).run()
    # the controlled run carries a tracer: every actuator action lands
    # on the shared timeline, so reconfig pause windows can be
    # correlated with per-tenant latency samples after the run
    from repro.core.obs import Tracer
    tracer = Tracer()
    csim = ClusterSim(p, lambda s: controlled_factory(s, tracer),
                      topo=topo, tracer=tracer)
    controlled = csim.run()
    improved = sum(
        1 for name in controlled.tenants
        if controlled.tenants[name].miss_rate
        <= static.tenants[name].miss_rate)
    out = {
        "tenants": n_tenants,
        "replicas": replicas,
        "static": {"per_tenant": tenant_rows(static),
                   "aggregate_rps": round(static.aggregate_rps, 3)},
        "controlled": {"per_tenant": tenant_rows(controlled),
                       "aggregate_rps": round(controlled.aggregate_rps, 3),
                       "actions": controlled.actions,
                       "pause_correlation": pause_correlation(csim,
                                                              tracer)},
        "arbiter": {
            "max_units_per_gpu": controlled.arbiter_max_units,
            "budget": controlled.arbiter_budget,
            "ok": controlled.arbiter_max_units <= controlled.arbiter_budget,
        },
        # controller wall-clock per decision tick (Table 4's controller
        # CPU% analogue at fleet scale — the "scale the fleet" signal the
        # --hosts sweep tracks)
        "controller": {
            "hosts": hosts,
            "devices": len(topo.devices()),
            "ticks": controlled.controller_ticks,
            "tick_ms_mean": round(controlled.controller_tick_ms_mean, 3),
            "tick_ms_max": round(controlled.controller_tick_ms_max, 3),
            "cpu_frac": round(controlled.controller_cpu_frac, 6),
        },
        "tenants_not_worse": improved,
    }
    if churn:
        out["churn"] = run_churn(n_tenants, replicas, seed, hosts=hosts)
    return out


def run_engine_arm(backend: str, seed: int) -> dict:
    """Small real-engine multi-tenant trace on the selected backend(s)."""
    from repro.launch.serve import serve
    backends = ("dense", "paged") if backend == "both" else (backend,)
    arm = {}
    for b in backends:
        res = serve(arch="stablelm_3b", requests=6, qps=4.0, prompt_len=32,
                    max_new=4, slots=2, num_tenants=2, replicas=1,
                    with_controller=False, seed=seed, verbose=False,
                    backend=b)
        arm[b] = {name: {k: stats[k] for k in
                         ("completed", "preempted", "ttft_p99_ms",
                          "itl_p99_ms")}
                  for name, stats in res.items()
                  if isinstance(stats, dict) and "completed" in stats}
    return arm


def run(tenant_counts=(2, 4, 8), replica_counts=(1, 2), duration=900.0,
        seed=0, verbose=True, churn=False, engine_backend=None,
        hosts=2) -> dict:
    sweep = []
    for n in tenant_counts:
        for r in replica_counts:
            cell = run_cell(n, r, duration, seed, churn=churn, hosts=hosts)
            sweep.append(cell)
            if verbose:
                ctl = cell["controlled"]["per_tenant"]
                worst = max(v["miss_rate"] for v in ctl.values())
                tick = cell["controller"]
                print(f"  N={n} R={r}: aggregate "
                      f"{cell['static']['aggregate_rps']:.1f} -> "
                      f"{cell['controlled']['aggregate_rps']:.1f} rps, "
                      f"worst controlled miss={worst*100:.2f}%, "
                      f"{cell['tenants_not_worse']}/{n} tenants not worse, "
                      f"arbiter peak {cell['arbiter']['max_units_per_gpu']}"
                      f"/{cell['arbiter']['budget']}u "
                      f"(ok={cell['arbiter']['ok']}), "
                      f"ctl tick {tick['tick_ms_mean']:.2f}ms mean / "
                      f"{tick['tick_ms_max']:.2f}ms max "
                      f"({tick['hosts']} hosts)")
                if churn:
                    ch = cell["churn"]
                    print(f"           churn: verdicts {ch['verdicts']} "
                          f"(+{ch['readmitted_after_free']} re-admitted "
                          f"after departures, {ch['still_queued']} queued, "
                          f"ledger_ok={ch['ledger_ok']})")
    out = {
        "experiment": "e5_multitenant",
        "duration_s": duration,
        "seed": seed,
        "hosts": hosts,
        "sweep": sweep,
        "budget_respected": all(c["arbiter"]["ok"] for c in sweep),
    }
    if engine_backend:
        out["engine_arm"] = run_engine_arm(engine_backend, seed)
        if verbose:
            for b, tenants in out["engine_arm"].items():
                done = sum(t["completed"] for t in tenants.values())
                worst = max((t["ttft_p99_ms"] for t in tenants.values()),
                            default=0.0)
                print(f"  engine arm [{b}]: {done} completed, "
                      f"worst TTFT p99 {worst:.1f}ms")
    if verbose:
        print(f"  per-GPU unit budget respected everywhere: "
              f"{out['budget_respected']}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", default="2,4,8",
                    help="comma-separated latency-tenant counts")
    ap.add_argument("--replicas", default="1,2",
                    help="comma-separated replica counts")
    ap.add_argument("--duration", type=float, default=900.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=2,
                    help="p4d hosts in the fleet topology (the paper's "
                         "testbed is 2; --hosts 4 runs the scaled-fleet "
                         "variant and the controller tick wall-clock "
                         "tracks the cost of the bigger placement graph)")
    ap.add_argument("--churn", action="store_true",
                    help="add the admission-churn arm (per-verdict counts "
                         "alongside the arbiter audit)")
    ap.add_argument("--engine-backend", default=None,
                    choices=("dense", "paged", "both"),
                    help="add a real-engine serving arm on the selected "
                         "KV backend(s)")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 4 tenants x 2 replicas, 240 s")
    args = ap.parse_args()
    if args.smoke:
        tenant_counts, replica_counts = (4,), (2,)
        duration = 240.0
    else:
        try:
            tenant_counts = tuple(int(x) for x in args.tenants.split(","))
            replica_counts = tuple(int(x) for x in args.replicas.split(","))
        except ValueError:
            ap.error("--tenants/--replicas take comma-separated integers, "
                     f"e.g. --tenants 2,4,8 (got {args.tenants!r} / "
                     f"{args.replicas!r})")
        duration = args.duration
    print("== E5: multi-tenant scaling (N SLO tenants x R replicas) ==")
    out = run(tenant_counts, replica_counts, duration, args.seed,
              churn=args.churn, engine_backend=args.engine_backend,
              hosts=args.hosts)
    payload = json.dumps(out, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
        print(f"wrote {args.out}")
    else:
        print(payload)


if __name__ == "__main__":
    main()
