"""E5 — Multi-tenant scaling (beyond-paper): N competing SLO tenants x R
replicas each, driven through the same controller + shared MIG arbiter.

The paper evaluates one latency-sensitive tenant against two interferers;
this experiment sweeps 2-8 latency tenants (each with R >= 1 batched
replicas, least-loaded dispatch) co-located with the same ETL/training
interferer classes, and reports per-tenant miss-rate/p99 plus aggregate
throughput for static-MIG vs controlled.  The arbiter audit proves the
per-GPU compute-unit budget (7) is never exceeded while lanes compete for
upgrades (the MIG-serving / ParvaGPU regime).

    PYTHONPATH=src:. python benchmarks/e5_multitenant.py \
        [--tenants 2,4,8] [--replicas 1,2] [--duration 900] [--seed 0] \
        [--out e5.json] [--smoke]
"""
from __future__ import annotations

import argparse
import json

from repro.core.controller import Controller, ControllerConfig
from repro.core.tenancy import TenantRegistry
from repro.sim.cluster import ClusterSim
from repro.sim.params import InterferenceWindow, SimParams


def fleet_schedule(duration: float) -> tuple:
    """The paper's toggling-interference cadence, addressed to the fleet's
    interferer names (ETL / TRAIN)."""
    out = []
    t = 60.0
    while t + 230 < duration:
        out.append(InterferenceWindow("ETL", t, t + 150))
        out.append(InterferenceWindow("TRAIN", t + 75, t + 225))
        t += 300.0
    return tuple(out)


def make_params(n_tenants: int, replicas: int, duration: float,
                seed: int) -> SimParams:
    reg = TenantRegistry.slo_fleet(n_tenants, replicas)
    return SimParams(seed=seed, duration_s=duration,
                     schedule=fleet_schedule(duration),
                     tenants=tuple(reg))


def controlled_factory(sim):
    c = Controller(sim.topo, sim.lattice, sim, ControllerConfig())
    sim.register_tenants(c)
    return c


def tenant_rows(res) -> dict:
    return {name: {
        "miss_rate": round(t.miss_rate, 5),
        "p99_ms": round(t.p99 * 1e3, 3),
        "p95_ms": round(t.p95 * 1e3, 3),
        "completed": t.completed,
        "dropped": t.dropped,
        "throughput_rps": round(t.throughput_rps, 3),
        "replicas": t.replicas,
    } for name, t in res.tenants.items()}


def run_cell(n_tenants: int, replicas: int, duration: float,
             seed: int) -> dict:
    p = make_params(n_tenants, replicas, duration, seed)
    static = ClusterSim(p).run()
    controlled = ClusterSim(p, controlled_factory).run()
    improved = sum(
        1 for name in controlled.tenants
        if controlled.tenants[name].miss_rate
        <= static.tenants[name].miss_rate)
    return {
        "tenants": n_tenants,
        "replicas": replicas,
        "static": {"per_tenant": tenant_rows(static),
                   "aggregate_rps": round(static.aggregate_rps, 3)},
        "controlled": {"per_tenant": tenant_rows(controlled),
                       "aggregate_rps": round(controlled.aggregate_rps, 3),
                       "actions": controlled.actions},
        "arbiter": {
            "max_units_per_gpu": controlled.arbiter_max_units,
            "budget": controlled.arbiter_budget,
            "ok": controlled.arbiter_max_units <= controlled.arbiter_budget,
        },
        "tenants_not_worse": improved,
    }


def run(tenant_counts=(2, 4, 8), replica_counts=(1, 2), duration=900.0,
        seed=0, verbose=True) -> dict:
    sweep = []
    for n in tenant_counts:
        for r in replica_counts:
            cell = run_cell(n, r, duration, seed)
            sweep.append(cell)
            if verbose:
                ctl = cell["controlled"]["per_tenant"]
                worst = max(v["miss_rate"] for v in ctl.values())
                print(f"  N={n} R={r}: aggregate "
                      f"{cell['static']['aggregate_rps']:.1f} -> "
                      f"{cell['controlled']['aggregate_rps']:.1f} rps, "
                      f"worst controlled miss={worst*100:.2f}%, "
                      f"{cell['tenants_not_worse']}/{n} tenants not worse, "
                      f"arbiter peak {cell['arbiter']['max_units_per_gpu']}"
                      f"/{cell['arbiter']['budget']}u "
                      f"(ok={cell['arbiter']['ok']})")
    out = {
        "experiment": "e5_multitenant",
        "duration_s": duration,
        "seed": seed,
        "sweep": sweep,
        "budget_respected": all(c["arbiter"]["ok"] for c in sweep),
    }
    if verbose:
        print(f"  per-GPU unit budget respected everywhere: "
              f"{out['budget_respected']}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", default="2,4,8",
                    help="comma-separated latency-tenant counts")
    ap.add_argument("--replicas", default="1,2",
                    help="comma-separated replica counts")
    ap.add_argument("--duration", type=float, default=900.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 4 tenants x 2 replicas, 240 s")
    args = ap.parse_args()
    if args.smoke:
        tenant_counts, replica_counts = (4,), (2,)
        duration = 240.0
    else:
        try:
            tenant_counts = tuple(int(x) for x in args.tenants.split(","))
            replica_counts = tuple(int(x) for x in args.replicas.split(","))
        except ValueError:
            ap.error("--tenants/--replicas take comma-separated integers, "
                     f"e.g. --tenants 2,4,8 (got {args.tenants!r} / "
                     f"{args.replicas!r})")
        duration = args.duration
    print("== E5: multi-tenant scaling (N SLO tenants x R replicas) ==")
    out = run(tenant_counts, replica_counts, duration, args.seed)
    payload = json.dumps(out, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
        print(f"wrote {args.out}")
    else:
        print(payload)


if __name__ == "__main__":
    main()
