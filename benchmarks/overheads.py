"""Controller overheads (paper Table 4): reconfig time, move frequency,
controller CPU."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ci95, run_config


def run(seeds=range(5), duration=3600.0, verbose=True):
    res = run_config("full", seeds, duration)
    reconfigs = [t for r in res for t in r.reconfig_times]
    moves_per_hr = [
        (r.actions.get("reconfigure", 0) + r.actions.get("move", 0))
        / (duration / 3600.0) for r in res]
    cpu = [r.controller_cpu_frac * 100 for r in res]
    m_rc, ci_rc = ci95(reconfigs) if reconfigs else (0.0, 0.0)
    m_mv, ci_mv = ci95(moves_per_hr)
    m_cpu, _ = ci95(cpu)
    out = {"reconfig_s": (m_rc, ci_rc), "moves_per_hr": (m_mv, ci_mv),
           "controller_cpu_pct": m_cpu}
    if verbose:
        print("== Overheads (paper Table 4) ==")
        print(f"  MIG reconfig time: {m_rc:5.1f}+-{ci_rc:.1f}s "
              f"(paper 18+-6 s)")
        print(f"  Move frequency:    {m_mv:5.2f}/hr (paper < 5/hr)")
        print(f"  Controller CPU:    {m_cpu:5.2f}% (paper < 2%)")
    return out


if __name__ == "__main__":
    run()
