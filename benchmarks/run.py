"""Benchmark entry point: one experiment per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

E1  main experiment (Fig 3/4)        — controller vs static
E2  ablation (Table 3)               — component contributions
E3  sensitivity (§3.3.3)             — tau / Y / guardrail bounds
E5  multi-tenant scaling             — N SLO tenants x R replicas + arbiter
LLM TTFT case study (Table 2)        — real engine + PS fabric
Overheads (Table 4)                  — reconfig s, moves/hr, CPU%
Kernels                              — Pallas microbench (interpret)
Roofline                             — from dry-run artifacts if present
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 seeds / shorter runs (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma list: e1,e2,e3,e4,e5,llm,overheads,"
                         "kernels,roofline")
    args = ap.parse_args()
    seeds = range(3) if args.quick else range(7)
    duration = 1800.0 if args.quick else 3600.0
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    from benchmarks import (e1_main, e2_ablation, e3_sensitivity,
                            e4_predictive, e5_multitenant, kernel_bench,
                            llm_ttft, overheads, roofline)

    if want("e1"):
        e1_main.run(seeds=seeds, duration=duration)
        print()
    if want("e2"):
        e2_ablation.run(seeds=seeds, duration=duration)
        print()
    if want("e3"):
        e3_sensitivity.run(seeds=range(2) if args.quick else range(3),
                           duration=min(duration, 2400.0))
        print()
    if want("e4"):
        e4_predictive.run(seeds=range(3) if args.quick else range(5),
                          duration=min(duration, 2400.0))
        print()
    if want("e5"):
        print("== E5: multi-tenant scaling ==")
        e5_multitenant.run(
            tenant_counts=(2, 4) if args.quick else (2, 4, 8),
            replica_counts=(1, 2),
            duration=600.0 if args.quick else 900.0)
        print()
    if want("llm"):
        llm_ttft.main()
        print()
    if want("overheads"):
        overheads.run(seeds=range(3) if args.quick else range(5),
                      duration=duration)
        print()
    if want("kernels"):
        kernel_bench.run()
        print()
    if want("roofline"):
        if os.path.isdir("results/dryrun") and os.listdir("results/dryrun"):
            roofline.run()
        else:
            print("(roofline: no results/dryrun artifacts — run "
                  "PYTHONPATH=src python -m repro.launch.dryrun first)")
    print(f"\nbenchmarks completed in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
