"""Pallas kernel microbenchmarks.

On this CPU container the kernels execute in interpret mode, so absolute
microseconds are NOT TPU numbers — the benchmark's role here is (a) a
regression harness for kernel call overheads and (b) the oracle-vs-kernel
speed sanity check.  On a real TPU the same harness times the Mosaic
binaries.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.selective_scan.ops import selective_scan


def timeit(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _paged_decode_bench() -> float:
    """End-to-end paged serving decode path (the runtime the paged backend
    drives each step: scatter new KV into the page pool + block-table
    attention + FFN), measured as warm us per decoded token on a reduced
    model.  Tracks the serving hot spot, not just the bare kernel."""
    from repro.configs.base import get_config, reduced
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request
    cfg = reduced(get_config("stablelm_3b"))
    eng = ServingEngine(cfg, max_slots=4, seq_cap=128, page_size=16, seed=0,
                        backend="paged", attn_impl="auto")
    for i in range(4):
        eng.submit(Request(req_id=i, tenant="T1", prompt_len=32,
                           max_new_tokens=18, arrival=0.0))
    decode_s, counted, seen = 0.0, 0, 0
    while eng.has_work():
        rep = eng.step()
        if rep.kind == "decode" and rep.decode_tokens:
            # pure-decode steps only (mixed steps are benched separately);
            # skip the first decodes so bucket compile time stays out
            if seen >= 8:
                decode_s += rep.compute_s
                counted += rep.decode_tokens
            seen += rep.decode_tokens
        eng.finalize_step(rep, 0.0)
    return decode_s / max(counted, 1) * 1e6


def _mixed_step_bench() -> float:
    """Fused mixed prefill+decode step (the continuous-batching hot path):
    a long prompt's chunks ride in the same jitted call as the running
    decode lanes.  Reported as warm us per token (prefill + decode tokens)
    over the mixed steps only; the same admission pattern runs twice so
    the second pass hits a warm jit cache."""
    from repro.configs.base import get_config, reduced
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request
    cfg = reduced(get_config("stablelm_3b"))
    eng = ServingEngine(cfg, max_slots=4, seq_cap=128, page_size=16, seed=0,
                        backend="paged", attn_impl="auto",
                        prefix_cache=False)

    def one_pass(base_id):
        mixed_s, mixed_tokens = 0.0, 0
        eng.submit(Request(req_id=base_id, tenant="T1", prompt_len=16,
                           max_new_tokens=24, arrival=0.0))
        # admit a long prompt once the first request is decoding, so its
        # chunks fuse with live decode lanes
        admitted = False
        steps = 0
        while eng.has_work():
            if not admitted and eng.active():
                eng.submit(Request(req_id=base_id + 1, tenant="T1",
                                   prompt_len=96, max_new_tokens=8,
                                   arrival=0.0))
                admitted = True
            rep = eng.step()
            if rep.kind == "mixed":
                mixed_s += rep.compute_s
                mixed_tokens += rep.tokens
            eng.finalize_step(rep, float(steps))
            steps += 1
        return mixed_s, mixed_tokens

    one_pass(0)                       # warm the mixed-step jit shapes
    mixed_s, mixed_tokens = one_pass(10)
    return mixed_s / max(mixed_tokens, 1) * 1e6


def _spec_step_bench() -> float:
    """Speculative verify step (the multi-token decode-lane hot path):
    a request is served cold to record its completion, then replayed
    with exact draft hints so every fused step verifies a k-token draft
    through the ragged kernel and commits the burst.  Reported as warm
    us per ACCEPTED+committed token over the verify steps — directly
    comparable to ``paged_decode_us_per_token`` (the same path at
    q_len=1): the gap between the two is the per-step fixed cost the
    speculation amortises."""
    from repro.configs.base import get_config, reduced
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request
    cfg = reduced(get_config("stablelm_3b"))

    def serve(hints, spec_k, measure):
        eng = ServingEngine(cfg, max_slots=4, seq_cap=128, page_size=16,
                            seed=0, backend="paged", attn_impl="auto",
                            spec_k=spec_k)
        req = Request(req_id=0, tenant="T1", prompt_len=32,
                      max_new_tokens=26, arrival=0.0,
                      prompt_tokens=np.arange(32) % cfg.vocab_size,
                      draft_hints=hints)
        eng.submit(req)
        spec_s, committed, seen = 0.0, 0, 0
        while eng.has_work():
            rep = eng.step()
            if measure and rep.kind == "decode" and rep.decode_tokens:
                if seen >= 2:       # skip warmup steps (bucket compiles
                    spec_s += rep.compute_s       # happen AOT anyway)
                    committed += rep.decode_tokens
                seen += 1
            eng.finalize_step(rep, 0.0)
        return req, spec_s, committed

    cold, _, _ = serve(None, 0, False)
    # replay pass 1 warms the verify-row jit buckets; pass 2 is measured
    serve(np.asarray(cold.output_tokens), 4, False)
    _, spec_s, committed = serve(np.asarray(cold.output_tokens), 4, True)
    return spec_s / max(committed, 1) * 1e6


def run(verbose=True):
    rng = np.random.default_rng(0)
    rows = []
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    rows.append(("flash_attention_interp",
                 timeit(flash_attention, q, k, v)))
    rows.append(("flash_attention_ref",
                 timeit(jax.jit(flash_attention_ref), q, k, v)))

    qd = jnp.asarray(rng.standard_normal((4, 4, 64)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((16, 128, 2, 64)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((16, 128, 2, 64)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, 16, (4, 4)), jnp.int32)
    ln = jnp.asarray([300, 400, 128, 512], jnp.int32)
    rows.append(("paged_attention_interp",
                 timeit(paged_attention, qd, kp, vp, bt, ln, impl="kernel")))
    rows.append(("paged_attention_ref",
                 timeit(jax.jit(paged_attention_ref), qd, kp, vp, bt, ln)))
    rows.append(("paged_decode_us_per_token", _paged_decode_bench()))
    rows.append(("mixed_step_us_per_token", _mixed_step_bench()))
    rows.append(("spec_step_us_per_accepted_token", _spec_step_bench()))

    x = jnp.asarray(rng.standard_normal((1, 128, 128)) * 0.3, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((1, 128, 128))) * 0.1,
                     jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((128, 16))) - 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 128, 16)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((1, 128, 16)) * 0.3, jnp.float32)
    d = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    rows.append(("selective_scan_interp",
                 timeit(selective_scan, x, dt, a, b, c, d)))

    r = jnp.asarray(rng.standard_normal((1, 128, 4, 32)) * 0.3, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 128, 4, 32)) * 0.3, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 128, 4, 32)) * 0.3, jnp.float32)
    w = jnp.asarray(np.full((1, 128, 4, 32), 0.9), jnp.float32)
    u = jnp.asarray(rng.standard_normal((4, 32)) * 0.3, jnp.float32)
    rows.append(("rwkv6_scan_interp", timeit(rwkv6_scan, r, kk, vv, w, u)))

    if verbose:
        print("== kernel microbench (interpret mode on CPU) ==")
        for name, us in rows:
            print(f"{name},{us:.0f},us_per_call")
    return dict(rows)


if __name__ == "__main__":
    run()
