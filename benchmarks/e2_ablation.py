"""E2 — Ablation study (paper Table 3): full system vs each component alone.

Paper reference values (mean +- 95% CI):
    Static MIG      16.4%+-1.5  20.0+-1.2 ms  1.00
    Guards-only     14.5%+-1.4  19.0+-1.0 ms  0.99
    Placement-only  13.0%+-1.2  17.8+-0.9 ms  0.98
    MIG-only        12.2%+-1.1  17.2+-0.8 ms  0.98
    Full            11.1%+-1.0  16.5+-0.7 ms  0.97
"""
from __future__ import annotations

from benchmarks.common import ABLATIONS, run_config, summarise

PAPER = {
    "static": (16.4, 20.0, 1.00),
    "guards_only": (14.5, 19.0, 0.99),
    "placement_only": (13.0, 17.8, 0.98),
    "mig_only": (12.2, 17.2, 0.98),
    "full": (11.1, 16.5, 0.97),
}


def run(seeds=range(7), duration=3600.0, verbose=True):
    rows = {}
    base_thr = None
    for name in ABLATIONS:
        res = run_config(name, seeds, duration)
        rows[name] = summarise(res)
        if name == "static":
            base_thr = rows[name]["thr"]
    for name, r in rows.items():
        r["norm_thr"] = r["thr"] / base_thr
    if verbose:
        print("== E2: ablation (paper Table 3) ==")
        print(f"{'config':16s} {'miss%':>12s} {'p99 ms':>12s} "
              f"{'norm thr':>9s}   paper(miss/p99/thr)")
        for name, r in rows.items():
            pm, pp, pt = PAPER[name]
            print(f"{name:16s} {r['miss']:5.2f}+-{r['miss_ci']:4.2f} "
                  f"{r['p99']:7.2f}+-{r['p99_ci']:4.2f} "
                  f"{r['norm_thr']:9.3f}   {pm}%/{pp}ms/{pt}")
        # ordering check (the paper's qualitative claim)
        order = sorted(rows, key=lambda n: rows[n]["p99"])
        print(f"  p99 ordering: {' < '.join(order)}")
        ok = (rows['full']['p99'] <= rows['mig_only']['p99'] <=
              rows['placement_only']['p99'] + 1.0 and
              rows['placement_only']['p99'] <= rows['guards_only']['p99']
              and rows['guards_only']['p99'] <= rows['static']['p99'])
        print(f"  paper ordering reproduced: {ok}")
    return rows


if __name__ == "__main__":
    run()
