"""Roofline table from dry-run artifacts (deliverable g).

Reads results/dryrun/*.json produced by ``repro.launch.dryrun`` and reports
per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS = 6 N D (train) / 2 N_active D (inference), and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPS.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

from repro.configs.base import INPUT_SHAPES, get_config
from repro.models.model import model_plan
from repro.models.params import count_params


def active_params(arch: str) -> int:
    """Parameters touched per token (MoE: shared + top-k routed experts)."""
    cfg = get_config(arch)
    total = count_params(model_plan(cfg))
    inactive = 0
    for layer in cfg.layer_specs():
        f = cfg.ffn_spec_for(layer)
        if layer.ffn == "moe" and f.num_experts:
            per_expert = 3 * cfg.d_model * f.d_ff   # gate+up+down
            inactive += (f.num_experts - f.top_k) * per_expert
    return total - inactive


def model_flops(arch: str, shape_name: str) -> float:
    shape = INPUT_SHAPES[shape_name]
    n = active_params(arch)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def load_results(out_dir: str = "results/dryrun") -> Dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            out[os.path.basename(path)[:-5]] = json.load(f)
    return out


def run(out_dir: str = "results/dryrun", verbose: bool = True):
    results = load_results(out_dir)
    rows = []
    for tag, r in results.items():
        mf = model_flops(r["arch"], r["shape"])
        # analytic terms (exact architecture math) are the metric of
        # record; fall back to HLO-derived terms for older artifacts
        tc = r.get("t_compute_analytic", r["t_compute"])
        tm = r.get("t_memory_analytic", r["t_memory"])
        fl = r.get("flops_analytic", r["hlo_flops"])
        ratio = mf / fl if fl else 0.0
        dom = {"tc": "compute", "tm": "memory", "tx": "collective"}[
            max((("tc", tc), ("tm", tm), ("tx", r["t_collective"])),
                key=lambda kv: kv[1])[0]]
        rows.append({
            "tag": tag, "arch": r["arch"], "shape": r["shape"],
            "mesh": "multi" if r["multi_pod"] else "single",
            "t_compute_ms": tc * 1e3,
            "t_memory_ms": tm * 1e3,
            "t_collective_ms": r["t_collective"] * 1e3,
            "hlo_t_compute_ms": r["t_compute"] * 1e3,
            "hlo_t_memory_ms": r["t_memory"] * 1e3,
            "dominant": dom,
            "model_flops": mf,
            "useful_ratio": min(ratio, 1.0),
            "peak_gib": r["bytes_per_device"]["total_peak"] / 2**30,
            "tpu_est_gib": r.get("analytic_memory", {}).get("total", 0)
            / 2**30,
        })
    if verbose:
        print("== roofline (from dry-run artifacts) ==")
        print(f"{'arch':22s} {'shape':12s} {'mesh':6s} "
              f"{'Tc ms':>9s} {'Tm ms':>9s} {'Tx ms':>9s} "
              f"{'dominant':>12s} {'useful':>7s} {'est GiB':>8s}")
        for row in sorted(rows, key=lambda x: (x["arch"], x["shape"],
                                               x["mesh"])):
            print(f"{row['arch']:22s} {row['shape']:12s} {row['mesh']:6s} "
                  f"{row['t_compute_ms']:9.3f} {row['t_memory_ms']:9.3f} "
                  f"{row['t_collective_ms']:9.3f} {row['dominant']:>12s} "
                  f"{row['useful_ratio']:7.3f} {row['tpu_est_gib']:8.2f}")
    return rows


if __name__ == "__main__":
    run()
