"""E3 — Sensitivity analysis (paper §3.3.3): vary tau, persistence Y, and
the guardrail bounds; measure responsiveness (actions) vs stability."""
from __future__ import annotations

from benchmarks.common import run_config, summarise


def run(seeds=range(3), duration=2400.0, verbose=True):
    out = {}
    if verbose:
        print("== E3: sensitivity (tau, Y, guardrail bounds) ==")

    for tau_ms in (12.0, 15.0, 20.0):
        res = run_config("full", seeds, duration,
                         policy_overrides={"tau_s": tau_ms / 1e3})
        r = summarise(res)
        actions = sum(sum(x.actions.values()) for x in res) / len(res)
        out[f"tau_{tau_ms}"] = {**r, "actions": actions}
        if verbose:
            print(f"  tau={tau_ms:5.1f}ms -> p99={r['p99']:6.2f}ms "
                  f"miss={r['miss']:5.2f}% actions/run={actions:.1f}")

    for y in (1, 3, 6):
        res = run_config("full", seeds, duration,
                         policy_overrides={"persistence": y})
        r = summarise(res)
        actions = sum(sum(x.actions.values()) for x in res) / len(res)
        out[f"Y_{y}"] = {**r, "actions": actions}
        if verbose:
            print(f"  Y={y}        -> p99={r['p99']:6.2f}ms "
                  f"miss={r['miss']:5.2f}% actions/run={actions:.1f}")

    for cap_mb in (100, 300, 500):
        # bound both ends of the throttle range at cap_mb
        from repro.core.guardrails import GuardrailBounds
        from repro.sim.cluster import ClusterSim
        from repro.sim.params import SimParams, default_schedule
        vals = []
        for seed in seeds:
            p = SimParams(seed=seed, duration_s=duration,
                          schedule=default_schedule(duration))
            def fac(sim, cap_mb=cap_mb):
                from repro.core.controller import (Controller,
                                                   ControllerConfig)
                cfg = ControllerConfig(
                    enable_mig=False, enable_placement=False,
                    enable_guardrails=True,
                    bounds=GuardrailBounds(
                        io_throttle=(cap_mb * 1e6, cap_mb * 1e6)))
                c = Controller(sim.topo, sim.lattice, sim, cfg)
                sim.register_tenants(c)
                return c
            vals.append(ClusterSim(p, fac).run())
        r = summarise(vals)
        out[f"iocap_{cap_mb}MB"] = r
        if verbose:
            print(f"  io.max={cap_mb}MB/s -> p99={r['p99']:6.2f}ms "
                  f"miss={r['miss']:5.2f}%")
    return out


if __name__ == "__main__":
    run()
