"""Shared benchmark utilities: sim config runners and confidence intervals."""
from __future__ import annotations

import numpy as np

from repro.core.controller import Controller, ControllerConfig
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule

T95 = {3: 3.182, 5: 2.776, 7: 2.447}     # two-sided t for n-1 dof


def ci95(xs) -> tuple:
    xs = np.asarray(xs, float)
    n = len(xs)
    t = T95.get(n, 1.96)
    half = t * xs.std(ddof=1) / np.sqrt(n) if n > 1 else 0.0
    return float(xs.mean()), float(half)


def controller_factory(policy_overrides=None, **flags):
    def make(sim):
        kwargs = dict(flags)
        if policy_overrides:
            from repro.core.policy import PolicyConfig
            kwargs["policy"] = PolicyConfig(**policy_overrides)
        cfg = ControllerConfig(**kwargs)
        c = Controller(sim.topo, sim.lattice, sim, cfg)
        sim.register_tenants(c)
        return c
    return make


ABLATIONS = {
    "static": None,
    "guards_only": dict(enable_mig=False, enable_placement=False,
                        enable_guardrails=True),
    "placement_only": dict(enable_mig=False, enable_placement=True,
                           enable_guardrails=False),
    "mig_only": dict(enable_mig=True, enable_placement=False,
                     enable_guardrails=False),
    "full": dict(enable_mig=True, enable_placement=True,
                 enable_guardrails=True),
}


def run_config(name: str, seeds=range(7), duration: float = 3600.0,
               policy_overrides=None, params_overrides=None):
    """Run one configuration over seeds; returns list of SimResult."""
    flags = ABLATIONS[name]
    results = []
    for seed in seeds:
        overrides = dict(params_overrides or {})
        overrides.setdefault("schedule", default_schedule(duration))
        p = SimParams(seed=seed, duration_s=duration, **overrides)
        factory = (controller_factory(policy_overrides, **flags)
                   if flags is not None else None)
        results.append(ClusterSim(p, factory).run())
    return results


def summarise(results):
    miss, half_m = ci95([r.miss_rate * 100 for r in results])
    p99, half_p = ci95([r.p99 * 1e3 for r in results])
    thr, half_t = ci95([r.throughput_rps for r in results])
    return {"miss": miss, "miss_ci": half_m, "p99": p99, "p99_ci": half_p,
            "thr": thr, "thr_ci": half_t}
