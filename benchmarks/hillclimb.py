"""§Perf hillclimbing driver: baseline vs optimization variants for the
three selected (arch x shape) pairs, hypothesis -> change -> measure.

    PYTHONPATH=src python -m benchmarks.hillclimb [--pair P]

Pairs (chosen per the selection rule):
  H1 deepseek_v2_236b x train_4k   — worst roofline fraction (Tm 2.9 s) and
                                     over HBM budget (est 50.7 GiB/dev)
  H2 mixtral_8x7b x prefill_32k    — most representative of the paper's
                                     serving technique (TTFT-critical path)
  H3 jamba_v0_1_52b x train_4k     — most collective-bound (Tx/Tc ~ 10)

Variants are expressed as policy overrides; results land in results/perf/.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse   # noqa: E402
import json       # noqa: E402

from repro.configs.base import INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.dryrun import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shardings import make_policy  # noqa: E402


def measure(arch, shape, policy=None, tag="baseline"):
    res = dryrun(arch, shape, verbose=False, policy_override=policy)
    row = {
        "tag": tag,
        "Tc_ms": res["t_compute"] * 1e3,
        "Tm_ms": res["t_memory"] * 1e3,
        "Tx_ms": res["t_collective"] * 1e3,
        "peak_gib": res["bytes_per_device"]["total_peak"] / 2**30,
        "est_gib": res["analytic_memory"]["total"] / 2**30,
        "coll_gb": {k: round(v / 2**30, 2)
                    for k, v in res["collectives"].items() if v},
    }
    print(f"  {tag:28s} Tc={row['Tc_ms']:9.2f} Tm={row['Tm_ms']:9.2f} "
          f"Tx={row['Tx_ms']:8.2f} peak={row['peak_gib']:7.1f} "
          f"est={row['est_gib']:6.2f} GiB")
    return row


def seq_parallel_policy(arch, shape_name):
    mesh = make_production_mesh()
    return make_policy(get_config(arch), INPUT_SHAPES[shape_name], mesh,
                       seq_parallel=True)


def run_pair(arch, shape_name, variants):
    print(f"== {arch} x {shape_name} ==")
    rows = [measure(arch, shape_name)]
    for tag, policy_fn in variants:
        rows.append(measure(arch, shape_name, policy=policy_fn(), tag=tag))
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{arch}__{shape_name}.json", "w") as f:
        json.dump(rows, f, indent=2)
    return rows


# NOTE: the repository baseline already contains the confirmed §Perf wins
# (in-place cache carry, moe_buf divisibility, int-scatter MoE dispatch/
# combine, window clipping, chunked MLA prefill) — the full
# hypothesis->change->measure history with before/after numbers lives in
# EXPERIMENTS.md §Perf.  The variants below reproduce the remaining
# policy-level lever (sequence parallelism) against today's baseline.
PAIRS = {
    "h1": ("deepseek_v2_236b", "train_4k",
           [("seq_parallel", lambda: seq_parallel_policy(
               "deepseek_v2_236b", "train_4k"))]),
    "h2": ("mixtral_8x7b", "prefill_32k",
           [("seq_parallel", lambda: seq_parallel_policy(
               "mixtral_8x7b", "prefill_32k"))]),
    "h3": ("jamba_v0_1_52b", "train_4k",
           [("seq_parallel", lambda: seq_parallel_policy(
               "jamba_v0_1_52b", "train_4k"))]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=list(PAIRS))
    args = ap.parse_args()
    pairs = [args.pair] if args.pair else list(PAIRS)
    for p in pairs:
        arch, shape, variants = PAIRS[p]
        run_pair(arch, shape, variants)


if __name__ == "__main__":
    main()


def with_env(key, value, fn):
    os.environ[key] = str(value)
    try:
        return fn()
    finally:
        os.environ.pop(key, None)
