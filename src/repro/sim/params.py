"""Simulation parameters — calibrated so the *static MIG + naive placement*
baseline reproduces the paper's operating point (p99 ~ 20 ms, miss ~ 16%,
SLO 15 ms), after which controller-induced deltas are the experiment.

Modelling choices (documented in DESIGN.md §8):
  * During a MIG reconfiguration / tenant move, arriving requests are
    load-shed (503-style) rather than queued — they count against
    throughput, not latency.  This is how the paper can report both
    "18 +- 6 s reconfig" and improved p99 with <= 5% throughput cost.
  * Other cluster slots carry *ambient* tenants (PCIe traffic per root,
    HBM pressure per device): the cluster is shared, so placement finds a
    less-bad slot, not a perfect one.  Without this, placement-only would
    dominate MIG-only, contradicting Table 3.
  * An io.max throttle on T2 removes only part of its PCIe demand
    (page-cache residual) — guardrails give the smallest single-component
    gain, as in Table 3.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.tenancy import TenantSpec


@dataclass(frozen=True)
class InterferenceWindow:
    tenant: str          # "T2" | "T3"
    start: float
    end: float


def default_schedule(duration: float = 3600.0) -> Tuple[InterferenceWindow, ...]:
    """Toggling interference (paper §3.3.1): alternating/overlapping bursts."""
    out = []
    t = 60.0
    while t + 230 < duration:
        out.append(InterferenceWindow("T2", t, t + 150))
        out.append(InterferenceWindow("T3", t + 75, t + 225))
        t += 300.0
    return tuple(out)


def _default_ambient_pcie() -> Tuple[Tuple[str, float], ...]:
    # bytes/s of unmodelled tenants per PCIe root complex (h0:r0 hosts T2)
    return (
        ("h0:r1", 12.0e9), ("h0:r2", 14.0e9), ("h0:r3", 16.0e9),
        ("h1:r0", 13.0e9), ("h1:r1", 15.0e9), ("h1:r2", 13.5e9),
        ("h1:r3", 17.0e9),
    )


@dataclass(frozen=True)
class SimParams:
    duration_s: float = 3600.0
    seed: int = 0
    # fabric
    pcie_capacity: float = 25e9          # bytes/s per root complex
    # T1 — latency-sensitive inference tenant (batch 1, 15 ms p99 SLO)
    t1_rate: float = 12.0                # Poisson arrivals /s
    t1_slo_s: float = 0.015
    t1_sizes: Tuple[Tuple[float, float], ...] = (
        (0.75, 12e6), (0.20, 24e6), (0.05, 32e6))   # (prob, bytes) mixture
    t1_c0_s: float = 0.007               # compute at the reference profile
    t1_ref_units: int = 2                # static baseline: 2g.20gb
    t1_gamma: float = 0.35               # compute ~ (ref/units)^gamma
    hbm_interference: float = 0.45       # T3-induced inflation at small slices
    noise_mu_s: float = 0.0006
    noise_sigma: float = 0.85             # lognormal shape
    irq_noise_mult: float = 1.6          # unpinned CPU during T2 bursts
    # T2 — bandwidth-heavy ETL tenant
    t2_pcie_demand: float = 20e9
    t2_ps_weight: float = 4.0            # multiple DMA queues/streams
    t2_io_demand: float = 2.5e9
    t2_throttle_residual: float = 0.70   # PCIe demand fraction surviving io.max
    # T3 — compute-heavy training tenant
    t3_sm_util: float = 0.95
    t3_units: int = 2
    # ambient (unmodelled) multi-tenancy on the rest of the cluster
    ambient_pcie: Tuple[Tuple[str, float], ...] = field(
        default_factory=_default_ambient_pcie)
    ambient_hbm: float = 0.35            # HBM inflation on non-home devices
    ambient_units: int = 3               # occupied compute units on non-home devices
    # reconfiguration costs (paper Table 4: 18 +- 6 s)
    mig_reconfig_mean_s: float = 18.0
    mig_reconfig_std_s: float = 3.0
    mig_reconfig_min_s: float = 8.0
    move_pause_s: float = 2.0
    # live lane migration: KV-page shipping is far cheaper than a MIG
    # re-slice or a replica move — only the victim's lanes stall
    migrate_pause_s: float = 0.25
    # controller sampling
    sample_period_s: float = 1.0
    schedule: Tuple[InterferenceWindow, ...] = field(
        default_factory=default_schedule)
    # --- tenant model -------------------------------------------------
    # Devices with no ambient co-tenants (the scenario's "home" GPUs);
    # everything else carries ambient_pcie/ambient_hbm/ambient_units.
    home_devices: Tuple[str, ...] = ("h0:g0",)
    # The tenant set.  None -> the paper's 3-tenant scenario built from
    # the t1_*/t2_*/t3_* calibration fields above
    # (TenantRegistry.paper_default).  Any number of latency tenants with
    # R >= 1 replicas each, plus background interferers, is allowed.
    tenants: Optional[Tuple[TenantSpec, ...]] = None
