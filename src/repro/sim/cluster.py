"""Discrete-event cluster simulator — registry-driven multi-tenant model.

Implements the paper's evaluation environment generalized to N
latency-sensitive tenants with R >= 1 replicas each: a p4d-style cluster
topology, background interferers (bandwidth-heavy ETL, compute-heavy
training) toggled by an interference schedule, and the PS-fabric latency
law from §2.5.1 applied per replica on its PCIe root complex:

    L = wait_in_queue + c(profile, batch, compute-contention) + s / b(t) + eps

The tenant set is data (`TenantRegistry`), not code: the paper's exact
3-tenant scenario is `TenantRegistry.paper_default(params)` (the default
when `SimParams.tenants` is None), so E1/E2 calibration is unchanged,
while `benchmarks/e5_multitenant.py` instantiates 2-8 competing SLO
tenants through the same machinery.

The simulator implements the controller's Actuator protocol, so the *same*
Controller object that manages the JAX serving stack drives the simulation:
moves and MIG reconfigurations pause the affected tenant (requests
load-shed), throttles change a background tenant's effective fabric
demand, MPS quotas scale compute interference.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import psmodel
from repro.core.faults import ActuatorFault
from repro.core.ledger import DeviceLedger
from repro.core.profiles import A100_MIG, ProfileLattice, SliceProfile
from repro.core.signals import Snapshot, SystemSignals, TenantSignals
from repro.core.tenancy import TenantRegistry, TenantSpec
from repro.core.topology import ClusterTopology, Slot, make_p4d_cluster
from repro.serving.metrics import LatencyWindow
from repro.sim.params import SimParams


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


@dataclass
class _Replica:
    """One serving instance of a latency tenant."""
    slot: Slot
    queue: Deque[Tuple[float, float]] = field(default_factory=deque)
    in_service: int = 0

    @property
    def load(self) -> int:
        return self.in_service + len(self.queue)


@dataclass
class _LatencyTenant:
    """Runtime state of a latency-sensitive tenant (spec + replicas)."""
    spec: TenantSpec
    profile: SliceProfile
    replicas: List[_Replica]
    window: LatencyWindow
    all_latencies: List[float] = field(default_factory=list)
    completions: Deque[float] = field(default_factory=lambda: deque(
        maxlen=4096))
    completed: int = 0
    offered: int = 0
    dropped: int = 0
    paused_until: float = 0.0
    pinned: bool = False
    pause_total: float = 0.0
    _size_probs: Optional[np.ndarray] = None
    _size_vals: Optional[np.ndarray] = None

    def __post_init__(self):
        probs = np.array([p for p, _ in self.spec.sizes])
        self._size_probs = probs / probs.sum()
        self._size_vals = np.array([s for _, s in self.spec.sizes])

    def in_flight(self) -> int:
        return sum(r.load for r in self.replicas)


@dataclass
class _BackgroundTenant:
    """Runtime state of a background interferer."""
    spec: TenantSpec
    slot: Slot
    active: bool = False
    io_throttle: Optional[float] = None
    mps_quota: float = 1.0


@dataclass
class TenantSimResult:
    """Per-tenant outcome of one simulation run."""
    latencies: np.ndarray
    miss_rate: float
    p95: float
    p99: float
    p999: float
    completed: int
    offered: int
    dropped: int
    throughput_rps: float
    slo_s: float
    replicas: int


@dataclass
class SimResult:
    """Top-level fields describe the *primary* (first latency) tenant so
    the seed's E1/E2 readers keep working; ``tenants`` carries every
    latency tenant's numbers."""
    latencies: np.ndarray                 # primary tenant latencies (s)
    miss_rate: float
    p95: float
    p99: float
    p999: float
    completed: int
    offered: int
    dropped: int
    throughput_rps: float
    actions: Dict[str, int]
    reconfig_times: List[float]
    controller_cpu_frac: float
    timeline: List[Tuple[float, str]]     # (time, action) for Fig-3 plots
    tenants: Dict[str, TenantSimResult] = field(default_factory=dict)
    aggregate_rps: float = 0.0            # all latency tenants combined
    arbiter_max_units: int = 0            # peak per-GPU units (audit)
    arbiter_budget: int = 7
    # controller wall-clock per sample tick (the fleet-scaling signal:
    # Table 4's controller CPU% analogue, measured per decision round)
    controller_ticks: int = 0
    controller_tick_ms_mean: float = 0.0
    controller_tick_ms_max: float = 0.0


class ClusterSim:
    """Event-driven simulation implementing the controller Actuator."""

    def __init__(self, params: SimParams, controller_factory=None,
                 topo: Optional[ClusterTopology] = None,
                 lattice: ProfileLattice = A100_MIG,
                 tracer=None, faults=None):
        self.p = params
        self.rng = np.random.default_rng(params.seed)
        self.topo = topo or make_p4d_cluster(2)
        self.lattice = lattice
        self.now = 0.0
        # core.obs.Tracer (or None): the sim implements the same
        # one-trace-event-per-actuator-method contract as ServingActuator
        self.tracer = tracer
        # core.faults.FaultInjector (or None): armed actuator failures
        # make the sim's Actuator methods raise ActuatorFault before any
        # state changes — wrap the sim in a RetryingActuator to recover
        self.faults = faults
        self._eseq = itertools.count()
        self.events: List[_Event] = []
        # --- tenant model (registry-driven) ---
        self.registry = (TenantRegistry(params.tenants)
                         if params.tenants is not None
                         else TenantRegistry.paper_default(params))
        placements = self.registry.resolve_placements(self.topo)
        self.lat: Dict[str, _LatencyTenant] = {}
        self.bg: Dict[str, _BackgroundTenant] = {}
        for spec in self.registry:
            slots = placements[spec.name]
            if spec.is_latency:
                self.lat[spec.name] = _LatencyTenant(
                    spec=spec,
                    profile=self._initial_profile(spec),
                    replicas=[_Replica(slot=s) for s in slots],
                    window=LatencyWindow(max_samples=1 << 16, horizon_s=30.0))
            else:
                self.bg[spec.name] = _BackgroundTenant(spec=spec,
                                                       slot=slots[0])
        if not self.lat:
            raise ValueError("registry has no latency tenant")
        self.primary = next(iter(self.lat))
        # shared placement/budget bookkeeping: slot occupancy, per-GPU
        # unit use and per-root fabric demand all live in the ledger (the
        # serving actuator builds the identical view — see the parity
        # suite).  Ambient co-tenants on non-home devices reduce headroom
        # exactly as the old inline scan did.
        self.ledger = DeviceLedger.from_registry(
            self.topo, self.registry, self.lattice, placements,
            home_devices=params.home_devices,
            ambient_units=params.ambient_units)
        # --- run state ---
        self.reconfig_times: List[float] = []
        self.controller = None
        self._controller_factory = controller_factory
        self.timeline: List[Tuple[float, str]] = []

    def _initial_profile(self, spec: TenantSpec) -> SliceProfile:
        try:
            return self.lattice[spec.profile]
        except KeyError:      # non-MIG lattice (e.g. TPU slices): 2nd rung
            return self.lattice.profiles[min(1, len(self.lattice) - 1)]

    # ------------------------------------------------------------- access
    def tenant(self, name: str) -> _LatencyTenant:
        return self.lat[name]

    def background(self, name: str) -> _BackgroundTenant:
        return self.bg[name]

    def in_flight(self, name: str) -> int:
        return self.lat[name].in_flight()

    def placements(self, tenant: str) -> List[Slot]:
        if tenant in self.lat:
            return [r.slot for r in self.lat[tenant].replicas]
        return [self.bg[tenant].slot]

    def register_tenants(self, controller) -> None:
        """Register every tenant of this sim's registry (with the sim's
        resolved placements and live profiles) into a Controller."""
        for spec in self.registry:
            if spec.is_latency:
                lt = self.lat[spec.name]
                slots = [r.slot for r in lt.replicas]
                controller.register_tenant(
                    spec.name, "latency", slots[0], lt.profile,
                    priority=spec.priority, slo_s=spec.slo_s,
                    replicas=slots)
            else:
                bg = self.bg[spec.name]
                controller.register_tenant(
                    spec.name, "background", bg.slot,
                    self._initial_profile(spec))

    # ---------------------------------------------------------- Actuator
    def _trace(self, name: str, tenant: str, dur: float = 0.0,
               **args) -> None:
        if self.tracer is not None:
            self.tracer.action(name, self.now, tenant, dur=dur, **args)

    def _maybe_fault(self, method: str) -> None:
        """Injected actuator failure: raise BEFORE any state changes so
        a failed call is a clean no-op the retry wrapper can repeat."""
        if self.faults is not None and \
                self.faults.actuator_fault(method, self.now) is not None:
            raise ActuatorFault(
                f"injected {method} failure at t={self.now:.3f}")

    def reconfigure(self, tenant: str, profile: SliceProfile) -> float:
        self._maybe_fault("reconfigure")
        lt = self.lat[tenant]
        pause = max(self.p.mig_reconfig_min_s,
                    self.rng.normal(self.p.mig_reconfig_mean_s,
                                    self.p.mig_reconfig_std_s))
        self.ledger.set_units(tenant, profile.compute_units)
        lt.profile = profile
        self._pause(tenant, pause)
        self.reconfig_times.append(pause)
        self.timeline.append((self.now, f"mig:{tenant}:{profile.name}"))
        self._trace("reconfigure", tenant, dur=pause, profile=profile.name,
                    units=profile.compute_units)
        return pause

    def move(self, tenant: str, slot: Slot) -> float:
        """Relocate the tenant's primary replica (the controller's
        placement lever steers one replica per decision)."""
        self._maybe_fault("move")
        lt = self.lat[tenant]
        self.ledger.move(tenant, 0, slot)
        lt.replicas[0].slot = slot
        self._pause(tenant, self.p.move_pause_s)
        self.timeline.append((self.now, f"move:{tenant}:{slot.key}"))
        self._trace("move", tenant, dur=self.p.move_pause_s, slot=slot.key)
        return self.p.move_pause_s

    def set_io_throttle(self, tenant: str, bytes_per_s: Optional[float]) -> None:
        self._maybe_fault("set_io_throttle")
        bg = self.bg.get(tenant)
        if bg is not None:
            bg.io_throttle = bytes_per_s
            self.timeline.append(
                (self.now, f"throttle:{tenant}:{bytes_per_s or 'off'}"))
        self._trace("set_io_throttle", tenant, bytes_per_s=bytes_per_s)

    def set_mps_quota(self, tenant: str, frac: float) -> None:
        self._maybe_fault("set_mps_quota")
        bg = self.bg.get(tenant)
        if bg is not None:
            bg.mps_quota = frac
            self.timeline.append((self.now, f"mps:{tenant}:{frac:.2f}"))
        self._trace("set_mps_quota", tenant, frac=frac)

    def pin_cpu_away_from_irq(self, tenant: str) -> None:
        self._maybe_fault("pin_cpu_away_from_irq")
        self.lat[tenant].pinned = True
        self._trace("pin_cpu_away_from_irq", tenant)

    def free_slots(self) -> List[Slot]:
        self._maybe_fault("free_slots")
        self._trace("query_free_slots", "")
        return self.ledger.free_slots()

    def headroom_units(self, device: str) -> int:
        """Free compute units on a device (budget per A100 minus all
        occupants, the asking tenant's own slice included —
        greedy_upgrade asks for the *extra*), read from the ledger."""
        self._maybe_fault("headroom_units")
        self._trace("query_headroom_units", "", device=device)
        return self.ledger.headroom_units(device)

    def migrate(self, tenant: str, replica_from: int,
                replica_to: int) -> float:
        """Live lane migration: ship the source replica's *queued* work
        to the destination (jobs already in service finish where they
        started — their completion events are scheduled).  In the
        discrete-event model the KV transfer collapses to a short pause;
        the serving stack prices it against fabric demand for real."""
        self._maybe_fault("migrate")
        lt = self.lat[tenant]
        src = lt.replicas[replica_from]
        dst = lt.replicas[replica_to]
        moved = len(src.queue)
        dst.queue.extend(src.queue)
        src.queue.clear()
        self._pause(tenant, self.p.migrate_pause_s)
        self.timeline.append(
            (self.now, f"migrate:{tenant}:r{replica_from}->r{replica_to}"))
        self._trace("migrate", tenant, dur=self.p.migrate_pause_s,
                    replica_from=replica_from, replica_to=replica_to,
                    moved=moved)
        return self.p.migrate_pause_s

    # -------------------------------------------------------- fabric state
    def _bg_effective_pcie(self, bg: _BackgroundTenant) -> float:
        if not bg.active or bg.spec.pcie_demand <= 0:
            return 0.0
        if bg.io_throttle is None:
            return bg.spec.pcie_demand
        # io.max caps the NVMe->host stage; page-cache hits keep part of the
        # host->GPU stream alive (residual), so relief is partial (§4:
        # guardrails give the smallest single-component gain).
        return (bg.spec.pcie_demand * bg.spec.throttle_residual
                + bg.io_throttle)

    def _ambient_pcie(self, root: str) -> float:
        for r, v in self.p.ambient_pcie:
            if r == root:
                return v
        return 0.0

    def _bandwidth(self, name: str, replica: _Replica) -> float:
        """This replica's PS-fabric share on its PCIe root complex."""
        device = replica.slot.device
        root = self.topo.root_of(device)
        demands = {name: psmodel.Demand(weight=1.0)}
        for bname, bg in self.bg.items():
            if bg.active and bg.spec.pcie_demand > 0 and \
                    self.topo.same_root(bg.slot.device, device):
                demands[bname] = psmodel.Demand(
                    weight=bg.spec.ps_weight,
                    throttle=self._bg_effective_pcie(bg))
        # competing latency tenants' replicas on this root contribute
        # their average offered demand (they are mostly-idle DMA streams,
        # not saturating ones — model them as throttled flows)
        for oname, olt in self.lat.items():
            per_rep = (olt.spec.rate * olt.spec.mean_size /
                       max(1, len(olt.replicas)))
            for j, orep in enumerate(olt.replicas):
                if orep is replica:
                    continue
                if self.topo.same_root(orep.slot.device, device):
                    demands[f"{oname}/r{j}"] = psmodel.Demand(
                        weight=1.0, throttle=per_rep)
        amb = self._ambient_pcie(root)
        if amb > 0:
            demands["ambient"] = psmodel.Demand(weight=1.0, throttle=amb)
        return psmodel.ps_shares_waterfill(demands,
                                           self.p.pcie_capacity)[name]

    def _compute(self, lt: _LatencyTenant, replica: _Replica) -> float:
        units = lt.profile.compute_units
        spec = lt.spec
        c = spec.c0_s * (spec.ref_units / units) ** spec.gamma
        # MIG isolates SMs but HBM bandwidth is partially shared; bigger
        # slices own more of the HBM and suffer less.
        sensitivity = max(0.0, 1.0 - units / 7.0)
        device = replica.slot.device
        hot = [bg for bg in self.bg.values()
               if bg.active and bg.spec.sm_util > 0
               and bg.slot.device == device]
        if hot:
            quota = max(bg.mps_quota for bg in hot)
            c *= 1.0 + self.p.hbm_interference * quota * sensitivity
        elif device not in self.p.home_devices:
            # ambient co-tenants on the rest of the shared cluster
            c *= 1.0 + self.p.ambient_hbm * sensitivity
        return c

    def _irq_noise(self) -> bool:
        return any(bg.active and bg.spec.io_demand > 0
                   for bg in self.bg.values())

    def _service_time(self, name: str, replica: _Replica,
                      size: float) -> float:
        lt = self.lat[name]
        b = self._bandwidth(name, replica)
        c = self._compute(lt, replica)
        # batch-aware: extra in-flight requests on this replica inflate the
        # per-request compute component (continuous-batching slowdown)
        c *= 1.0 + lt.spec.batch_penalty * max(0, replica.in_service - 1)
        eps = self.rng.lognormal(math.log(self.p.noise_mu_s),
                                 self.p.noise_sigma)
        if not lt.pinned and self._irq_noise():
            eps *= self.p.irq_noise_mult   # IRQ jitter until pinned away
        return psmodel.latency(c, size, b, eps)

    # ------------------------------------------------------------- events
    def _push(self, time: float, kind: str, **payload) -> None:
        heapq.heappush(self.events,
                       _Event(time, next(self._eseq), kind, payload))

    def _pause(self, tenant: str, pause: float) -> None:
        lt = self.lat[tenant]
        lt.paused_until = max(lt.paused_until, self.now + pause)
        lt.pause_total += pause
        self._push(lt.paused_until, "resume", tenant=tenant)

    def _draw_size(self, lt: _LatencyTenant) -> float:
        return float(self.rng.choice(lt._size_vals, p=lt._size_probs))

    def _start_service(self, name: str, ridx: int, arrival: float,
                       size: float) -> None:
        replica = self.lat[name].replicas[ridx]
        replica.in_service += 1
        dur = self._service_time(name, replica, size)
        self._push(self.now + dur, "complete", tenant=name, replica=ridx,
                   arrival=arrival)

    def _drain(self, name: str, ridx: int) -> None:
        lt = self.lat[name]
        if self.now < lt.paused_until:
            return
        replica = lt.replicas[ridx]
        while replica.queue and replica.in_service < lt.spec.max_batch:
            arrival, size = replica.queue.popleft()
            self._start_service(name, ridx, arrival, size)

    def _dispatch(self, name: str, size: float) -> None:
        """Least-loaded replica dispatch."""
        lt = self.lat[name]
        ridx = min(range(len(lt.replicas)),
                   key=lambda i: (lt.replicas[i].load, i))
        replica = lt.replicas[ridx]
        if replica.in_service < lt.spec.max_batch and not replica.queue:
            self._start_service(name, ridx, self.now, size)
        else:
            replica.queue.append((self.now, size))

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Snapshot:
        tenants: Dict[str, TenantSignals] = {}
        for name, lt in self.lat.items():
            tenants[name] = TenantSignals(
                p95=lt.window.quantile(0.95, self.now),
                p99=lt.window.quantile(0.99, self.now),
                p999=lt.window.quantile(0.999, self.now),
                miss_rate=lt.window.miss_rate(lt.spec.slo_s, self.now),
                rps=sum(1 for t in lt.completions
                        if t >= self.now - 10.0) / 10.0,
            )
        sys = SystemSignals()
        for root in self.topo.roots():
            v = self._ambient_pcie(root)
            for bg in self.bg.values():
                if self.topo.root_of(bg.slot.device) == root:
                    v += self._bg_effective_pcie(bg)
            for lt in self.lat.values():
                per_rep = (lt.spec.rate * lt.spec.mean_size /
                           max(1, len(lt.replicas)))
                v += per_rep * sum(
                    1 for r in lt.replicas
                    if self.topo.root_of(r.slot.device) == root)
            sys.pcie_bytes[root] = v
        for numa in self.topo.numas():
            total = 0.0
            for bg in self.bg.values():
                if self.topo.numa_of(bg.slot.device) != numa:
                    continue
                io = bg.spec.io_demand if bg.active else 0.0
                if bg.io_throttle is not None and bg.active:
                    io = min(io, bg.io_throttle)
                total += io
            sys.host_io[numa] = total
        for dev in self.topo.devices():
            util = [bg.spec.sm_util * bg.mps_quota for bg in self.bg.values()
                    if bg.active and bg.spec.sm_util > 0
                    and bg.slot.device == dev]
            sys.sm_util[dev] = max(util) if util else 0.1
        for bg in self.bg.values():
            if bg.spec.io_demand > 0:
                host = f"h{self.topo.host_of(bg.slot.device)}"
                rate = 30_000.0 if bg.active else 500.0
                sys.irq_rate[host] = max(sys.irq_rate.get(host, 0.0), rate)
        return Snapshot(self.now, tenants, sys)

    # ----------------------------------------------------------------- run
    def run(self) -> SimResult:
        import time as _time
        p = self.p
        if self._controller_factory is not None:
            self.controller = self._controller_factory(self)
        # seed arrivals / schedule / sampling
        for name, lt in self.lat.items():
            self._push(self.rng.exponential(1.0 / lt.spec.rate), "arrival",
                       tenant=name)
        for w in p.schedule:
            self._push(w.start, "toggle", tenant=w.tenant, on=True)
            self._push(w.end, "toggle", tenant=w.tenant, on=False)
        if self.controller is not None:
            self._push(p.sample_period_s, "sample")
        ctl_cpu = 0.0
        tick_s: List[float] = []    # controller wall-clock per sample tick

        while self.events:
            ev = heapq.heappop(self.events)
            if ev.time > p.duration_s:
                break
            self.now = ev.time
            if ev.kind == "arrival":
                name = ev.payload["tenant"]
                lt = self.lat[name]
                lt.offered += 1
                size = self._draw_size(lt)
                if self.now < lt.paused_until:
                    # load-shed during reconfig/move (503-style): counts
                    # against throughput, not latency
                    lt.dropped += 1
                else:
                    self._dispatch(name, size)
                self._push(self.now +
                           self.rng.exponential(1.0 / lt.spec.rate),
                           "arrival", tenant=name)
            elif ev.kind == "complete":
                name = ev.payload["tenant"]
                ridx = ev.payload["replica"]
                lt = self.lat[name]
                lat = self.now - ev.payload["arrival"]
                lt.window.observe(self.now, lat, slo=lt.spec.slo_s)
                lt.all_latencies.append(lat)
                lt.completions.append(self.now)
                lt.completed += 1
                lt.replicas[ridx].in_service -= 1
                self._drain(name, ridx)
            elif ev.kind == "resume":
                name = ev.payload["tenant"]
                for i in range(len(self.lat[name].replicas)):
                    self._drain(name, i)
            elif ev.kind == "toggle":
                bg = self.bg.get(ev.payload["tenant"])
                if bg is not None:
                    bg.active = ev.payload["on"]
            elif ev.kind == "sample":
                t0 = _time.perf_counter()
                self.controller.on_snapshot(self.snapshot())
                dt = _time.perf_counter() - t0
                ctl_cpu += dt
                tick_s.append(dt)
                self._push(self.now + p.sample_period_s, "sample")

        per_tenant: Dict[str, TenantSimResult] = {}
        for name, lt in self.lat.items():
            lats = np.asarray(lt.all_latencies)
            per_tenant[name] = TenantSimResult(
                latencies=lats,
                miss_rate=(float(np.mean(lats > lt.spec.slo_s))
                           if lats.size else 0.0),
                p95=float(np.quantile(lats, 0.95)) if lats.size else 0.0,
                p99=float(np.quantile(lats, 0.99)) if lats.size else 0.0,
                p999=float(np.quantile(lats, 0.999)) if lats.size else 0.0,
                completed=lt.completed,
                offered=lt.offered,
                dropped=lt.dropped,
                throughput_rps=lt.completed / p.duration_s,
                slo_s=lt.spec.slo_s,
                replicas=len(lt.replicas),
            )
        prim = per_tenant[self.primary]
        actions = (self.controller.audit.counts()
                   if self.controller is not None else {})
        arb = getattr(self.controller, "arbiter", None)
        return SimResult(
            latencies=prim.latencies,
            miss_rate=prim.miss_rate,
            p95=prim.p95,
            p99=prim.p99,
            p999=prim.p999,
            completed=prim.completed,
            offered=prim.offered,
            dropped=prim.dropped,
            throughput_rps=prim.throughput_rps,
            actions=actions,
            reconfig_times=self.reconfig_times,
            controller_cpu_frac=ctl_cpu / p.duration_s,
            timeline=self.timeline,
            tenants=per_tenant,
            aggregate_rps=sum(t.throughput_rps for t in per_tenant.values()),
            arbiter_max_units=arb.max_used() if arb is not None else 0,
            arbiter_budget=arb.budget if arb is not None else 7,
            controller_ticks=len(tick_s),
            controller_tick_ms_mean=(float(np.mean(tick_s)) * 1e3
                                     if tick_s else 0.0),
            controller_tick_ms_max=(float(np.max(tick_s)) * 1e3
                                    if tick_s else 0.0),
        )
