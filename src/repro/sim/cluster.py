"""Discrete-event cluster simulator.

Implements the paper's evaluation environment: a p4d-style cluster topology,
three co-located tenants (T1 latency-sensitive inference, T2 bandwidth-heavy
ETL, T3 compute-heavy training), an interference schedule toggling T2/T3,
and the PS-fabric latency law from §2.5.1:

    L = wait_in_queue + c(profile, compute-contention) + s / b(t) + eps

The simulator implements the controller's Actuator protocol, so the *same*
Controller object that manages the JAX serving stack drives the simulation:
moves and MIG reconfigurations pause T1 (requests queue), throttles change
T2's effective fabric demand, MPS quotas scale T3's interference.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import psmodel
from repro.core.profiles import A100_MIG, ProfileLattice, SliceProfile
from repro.core.signals import Snapshot, SystemSignals, TenantSignals
from repro.core.topology import ClusterTopology, Slot, make_p4d_cluster
from repro.serving.metrics import LatencyWindow
from repro.sim.params import SimParams


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


@dataclass
class SimResult:
    latencies: np.ndarray                 # T1 request latencies (s)
    miss_rate: float
    p95: float
    p99: float
    p999: float
    completed: int
    offered: int
    dropped: int
    throughput_rps: float
    actions: Dict[str, int]
    reconfig_times: List[float]
    controller_cpu_frac: float
    timeline: List[Tuple[float, str]]     # (time, action) for Fig-3 plots


class ClusterSim:
    """Event-driven simulation implementing the controller Actuator."""

    def __init__(self, params: SimParams, controller_factory=None,
                 topo: Optional[ClusterTopology] = None,
                 lattice: ProfileLattice = A100_MIG):
        self.p = params
        self.rng = np.random.default_rng(params.seed)
        self.topo = topo or make_p4d_cluster(2)
        self.lattice = lattice
        self.now = 0.0
        self._eseq = itertools.count()
        self.events: List[_Event] = []
        # --- placements (naive baseline: everything piled on h0:g0/r0) ---
        self.t1_slot = Slot(0, "h0:g0", 0)
        self.t2_slot = Slot(0, "h0:g1", 0)      # same root complex as T1
        self.t3_slot = Slot(0, "h0:g0", 1)      # same GPU as T1
        self.t1_profile: SliceProfile = lattice.profiles[
            min(1, len(lattice.profiles) - 1)]   # 2g.20gb static baseline
        self.t3_mps_quota = 1.0
        self.t2_io_throttle: Optional[float] = None
        self.t1_pinned = False
        # --- runtime state ---
        self.t2_active = False
        self.t3_active = False
        self.t1_paused_until = 0.0
        self.t1_busy = False
        self.t1_queue: List[Tuple[float, float]] = []   # (arrival, size)
        self.window = LatencyWindow(max_samples=1 << 16, horizon_s=30.0)
        self.all_latencies: List[float] = []
        self.completed = 0
        self.offered = 0
        self.dropped = 0
        self.reconfig_times: List[float] = []
        self.pause_total = 0.0
        self.controller = None
        self._controller_factory = controller_factory
        self.timeline: List[Tuple[float, str]] = []
        self._completions_window: List[float] = []

    # ---------------------------------------------------------- Actuator
    def reconfigure(self, tenant: str, profile: SliceProfile) -> float:
        assert tenant == "T1"
        pause = max(self.p.mig_reconfig_min_s,
                    self.rng.normal(self.p.mig_reconfig_mean_s,
                                    self.p.mig_reconfig_std_s))
        self.t1_profile = profile
        self._pause_t1(pause)
        self.reconfig_times.append(pause)
        self.timeline.append((self.now, f"mig:{profile.name}"))
        return pause

    def move(self, tenant: str, slot: Slot) -> float:
        assert tenant == "T1"
        self.t1_slot = slot
        self._pause_t1(self.p.move_pause_s)
        self.timeline.append((self.now, f"move:{slot.key}"))
        return self.p.move_pause_s

    def set_io_throttle(self, tenant: str, bytes_per_s: Optional[float]) -> None:
        if tenant == "T2":
            self.t2_io_throttle = bytes_per_s
            self.timeline.append(
                (self.now, f"throttle:{bytes_per_s or 'off'}"))

    def set_mps_quota(self, tenant: str, frac: float) -> None:
        if tenant == "T3":
            self.t3_mps_quota = frac
            self.timeline.append((self.now, f"mps:{frac:.2f}"))

    def pin_cpu_away_from_irq(self, tenant: str) -> None:
        self.t1_pinned = True

    def free_slots(self) -> List[Slot]:
        occupied = {self.t1_slot.key, self.t2_slot.key, self.t3_slot.key}
        return [s for s in self.topo.slots() if s.key not in occupied]

    def headroom_units(self, device: str) -> int:
        """Free compute units on a device (7 per A100 minus all occupants,
        T1's own slice included — greedy_upgrade asks for the *extra*)."""
        used = 0
        if self.t1_slot.device == device:
            used += self.t1_profile.compute_units
        if self.t3_slot.device == device:
            used += self.p.t3_units   # T3 occupies a training slice
        if device != "h0:g0":
            used += self.p.ambient_units   # ambient co-tenants elsewhere
        return max(0, 7 - used)

    # -------------------------------------------------------- fabric state
    def _t2_effective_pcie(self) -> float:
        if not self.t2_active:
            return 0.0
        if self.t2_io_throttle is None:
            return self.p.t2_pcie_demand
        # io.max caps the NVMe->host stage; page-cache hits keep part of the
        # host->GPU stream alive (residual), so relief is partial (§4:
        # guardrails give the smallest single-component gain).
        return (self.p.t2_pcie_demand * self.p.t2_throttle_residual
                + self.t2_io_throttle)

    def _ambient_pcie(self, root: str) -> float:
        for r, v in self.p.ambient_pcie:
            if r == root:
                return v
        return 0.0

    def _t1_bandwidth(self) -> float:
        root = self.topo.root_of(self.t1_slot.device)
        demands = {"T1": psmodel.Demand(weight=1.0)}
        if self.t2_active and self.topo.same_root(self.t1_slot.device,
                                                  self.t2_slot.device):
            t2 = self._t2_effective_pcie()
            # T2 competes with several DMA streams, capped at its demand
            demands["T2"] = psmodel.Demand(weight=self.p.t2_ps_weight,
                                           throttle=t2)
        amb = self._ambient_pcie(root)
        if amb > 0:
            demands["ambient"] = psmodel.Demand(weight=1.0, throttle=amb)
        shares = psmodel.ps_shares_waterfill(demands, self.p.pcie_capacity)
        return shares["T1"]

    def _t1_compute(self) -> float:
        units = self.t1_profile.compute_units
        c = self.p.t1_c0_s * (self.p.t1_ref_units / units) ** self.p.t1_gamma
        # MIG isolates SMs but HBM bandwidth is partially shared; bigger
        # slices own more of the HBM and suffer less.
        sensitivity = max(0.0, 1.0 - units / 7.0)
        if self.t3_active and self.t3_slot.device == self.t1_slot.device:
            c *= 1.0 + self.p.hbm_interference * self.t3_mps_quota * sensitivity
        elif self.t1_slot.device != "h0:g0":
            # ambient co-tenants on the rest of the shared cluster
            c *= 1.0 + self.p.ambient_hbm * sensitivity
        return c

    def _service_time(self, size: float) -> float:
        b = self._t1_bandwidth()
        c = self._t1_compute()
        eps = self.rng.lognormal(math.log(self.p.noise_mu_s),
                                 self.p.noise_sigma)
        if not self.t1_pinned and self.t2_active:
            eps *= self.p.irq_noise_mult   # IRQ jitter until pinned away
        return psmodel.latency(c, size, b, eps)

    # ------------------------------------------------------------- events
    def _push(self, time: float, kind: str, **payload) -> None:
        heapq.heappush(self.events,
                       _Event(time, next(self._eseq), kind, payload))

    def _pause_t1(self, pause: float) -> None:
        self.t1_paused_until = max(self.t1_paused_until, self.now + pause)
        self.pause_total += pause
        self._push(self.t1_paused_until, "resume")

    def _draw_size(self) -> float:
        probs = np.array([p for p, _ in self.p.t1_sizes])
        sizes = np.array([s for _, s in self.p.t1_sizes])
        return float(self.rng.choice(sizes, p=probs / probs.sum()))

    def _start_service(self, arrival: float, size: float) -> None:
        self.t1_busy = True
        dur = self._service_time(size)
        self._push(self.now + dur, "complete", arrival=arrival)

    def _maybe_dequeue(self) -> None:
        if (not self.t1_busy and self.t1_queue
                and self.now >= self.t1_paused_until):
            arrival, size = self.t1_queue.pop(0)
            self._start_service(arrival, size)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Snapshot:
        t1 = TenantSignals(
            p95=self.window.quantile(0.95, self.now),
            p99=self.window.quantile(0.99, self.now),
            p999=self.window.quantile(0.999, self.now),
            miss_rate=self.window.miss_rate(self.p.t1_slo_s, self.now),
            rps=len([t for t in self._completions_window
                     if t >= self.now - 10.0]) / 10.0,
        )
        sys = SystemSignals()
        t1_root = self.topo.root_of(self.t1_slot.device)
        t2_root = self.topo.root_of(self.t2_slot.device)
        t2_pcie = self._t2_effective_pcie()
        t1_avg_demand = self.p.t1_rate * sum(
            p * s for p, s in self.p.t1_sizes)
        for root in self.topo.roots():
            v = self._ambient_pcie(root)
            if root == t2_root:
                v += t2_pcie
            if root == t1_root:
                v += t1_avg_demand
            sys.pcie_bytes[root] = v
        io = self.p.t2_io_demand if self.t2_active else 0.0
        if self.t2_io_throttle is not None and self.t2_active:
            io = min(io, self.t2_io_throttle)
        for numa in self.topo.numas():
            sys.host_io[numa] = io if numa == self.topo.numa_of(
                self.t2_slot.device) else 0.0
        for dev in self.topo.devices():
            sys.sm_util[dev] = (self.p.t3_sm_util * self.t3_mps_quota
                                if self.t3_active
                                and dev == self.t3_slot.device else 0.1)
        sys.irq_rate[f"h{self.topo.host_of(self.t2_slot.device)}"] = \
            30_000.0 if self.t2_active else 500.0
        return Snapshot(self.now, {"T1": t1}, sys)

    # ----------------------------------------------------------------- run
    def run(self) -> SimResult:
        import time as _time
        p = self.p
        if self._controller_factory is not None:
            self.controller = self._controller_factory(self)
        # seed arrivals / schedule / sampling
        self._push(self.rng.exponential(1.0 / p.t1_rate), "arrival")
        for w in p.schedule:
            self._push(w.start, "toggle", tenant=w.tenant, on=True)
            self._push(w.end, "toggle", tenant=w.tenant, on=False)
        if self.controller is not None:
            self._push(p.sample_period_s, "sample")
        ctl_cpu = 0.0

        while self.events:
            ev = heapq.heappop(self.events)
            if ev.time > p.duration_s:
                break
            self.now = ev.time
            if ev.kind == "arrival":
                self.offered += 1
                size = self._draw_size()
                if self.now < self.t1_paused_until:
                    # load-shed during reconfig/move (503-style): counts
                    # against throughput, not latency
                    self.dropped += 1
                elif self.t1_busy:
                    self.t1_queue.append((self.now, size))
                else:
                    self._start_service(self.now, size)
                self._push(self.now + self.rng.exponential(1.0 / p.t1_rate),
                           "arrival")
            elif ev.kind == "complete":
                lat = self.now - ev.payload["arrival"]
                self.window.observe(self.now, lat, slo=p.t1_slo_s)
                self.all_latencies.append(lat)
                self._completions_window.append(self.now)
                if len(self._completions_window) > 4096:
                    self._completions_window = self._completions_window[-2048:]
                self.completed += 1
                self.t1_busy = False
                self._maybe_dequeue()
            elif ev.kind == "resume":
                self._maybe_dequeue()
            elif ev.kind == "toggle":
                if ev.payload["tenant"] == "T2":
                    self.t2_active = ev.payload["on"]
                else:
                    self.t3_active = ev.payload["on"]
            elif ev.kind == "sample":
                t0 = _time.perf_counter()
                self.controller.on_snapshot(self.snapshot())
                ctl_cpu += _time.perf_counter() - t0
                self._push(self.now + p.sample_period_s, "sample")

        lats = np.asarray(self.all_latencies)
        actions = (self.controller.audit.counts()
                   if self.controller is not None else {})
        return SimResult(
            latencies=lats,
            miss_rate=float(np.mean(lats > p.t1_slo_s)) if lats.size else 0.0,
            p95=float(np.quantile(lats, 0.95)) if lats.size else 0.0,
            p99=float(np.quantile(lats, 0.99)) if lats.size else 0.0,
            p999=float(np.quantile(lats, 0.999)) if lats.size else 0.0,
            completed=self.completed,
            offered=self.offered,
            dropped=self.dropped,
            throughput_rps=self.completed / p.duration_s,
            actions=actions,
            reconfig_times=self.reconfig_times,
            controller_cpu_frac=ctl_cpu / p.duration_s,
            timeline=self.timeline,
        )
