"""Processor-sharing model of the shared fabric (paper §2.5.1).

    b_i(t) = min( B * w_i / sum_{j active} w_j ,  g_i )

plus the latency decomposition  L_i = c_i + s_i / b_i + eps  and the
stability condition of Claim 1 (sum_j g_j < B).

The same model describes the PCIe root complex on a GPU host and an ICI
link / host-DMA path on a TPU pod — only the capacity constant changes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple


@dataclass(frozen=True)
class Demand:
    weight: float = 1.0
    throttle: Optional[float] = None     # g_i in bytes/s (None = uncapped)


def ps_shares(demands: Mapping[str, Demand], capacity: float
              ) -> Dict[str, float]:
    """Paper-faithful share: b_i = min(B*w_i/sum w_j, g_i)."""
    total_w = sum(d.weight for d in demands.values())
    if total_w <= 0:
        return {k: 0.0 for k in demands}
    out = {}
    for k, d in demands.items():
        fair = capacity * d.weight / total_w
        out[k] = min(fair, d.throttle) if d.throttle is not None else fair
    return out


def ps_shares_waterfill(demands: Mapping[str, Demand], capacity: float,
                        iters: int = 8) -> Dict[str, float]:
    """Beyond-paper refinement: redistribute capacity unused by throttled
    flows to the remaining flows (max-min water-filling).  The paper's
    formula leaves b_i at the fair share even when other tenants are capped
    below theirs; real PCIe arbitration gives the slack back."""
    remaining = dict(demands)
    alloc: Dict[str, float] = {}
    cap_left = capacity
    for _ in range(iters):
        if not remaining:
            break
        total_w = sum(d.weight for d in remaining.values())
        capped = {k: d for k, d in remaining.items()
                  if d.throttle is not None
                  and d.throttle < cap_left * d.weight / total_w}
        if not capped:
            for k, d in remaining.items():
                alloc[k] = cap_left * d.weight / total_w
            remaining = {}
            break
        for k, d in capped.items():
            alloc[k] = d.throttle
            cap_left -= d.throttle
            del remaining[k]
    return alloc


def transfer_time(size_bytes: float, bandwidth: float) -> float:
    if bandwidth <= 0:
        return math.inf
    return size_bytes / bandwidth


def latency(compute_s: float, size_bytes: float, bandwidth: float,
            noise_s: float = 0.0) -> float:
    """L_i = c_i + s_i/b_i + eps  (paper §2.5.1)."""
    return compute_s + transfer_time(size_bytes, bandwidth) + noise_s


def stable_under_throttles(throttles: Mapping[str, float],
                           capacity: float) -> bool:
    """Claim 1 condition (iii): aggregate offered load sum_j g_j < B."""
    return sum(throttles.values()) < capacity


def utilisation(throttles: Mapping[str, float], capacity: float) -> float:
    return sum(throttles.values()) / capacity
