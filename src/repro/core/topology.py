"""Cluster topology graph: hosts, NUMA domains, PCIe root complexes,
accelerators — and the TPU-pod analogue (hosts, DMA paths, ICI mesh).

The paper queries topology via DCGM/NVML + lspci/NUMA maps (§2.2.1); here
the same queries run against an explicit networkx graph so the placement
scorer is testable and the simulator and dry-run share one source of truth.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import networkx as nx


@dataclass(frozen=True)
class Slot:
    """A placement slot: one MIG-instance position (GPU) or slice anchor
    (TPU) on a device."""
    host: int
    device: str            # e.g. "h0:g3"
    index: int             # slot position on the device

    @property
    def key(self) -> str:
        return f"{self.device}:s{self.index}"


class ClusterTopology:
    def __init__(self, num_hosts: int = 2, devices_per_host: int = 8,
                 devices_per_root: int = 2, numa_per_host: int = 2,
                 slots_per_device: int = 2, kind: str = "gpu"):
        self.kind = kind
        self.num_hosts = num_hosts
        self.devices_per_host = devices_per_host
        self.slots_per_device = slots_per_device
        self.g = nx.Graph()
        self._root_of: Dict[str, str] = {}
        self._numa_of: Dict[str, str] = {}
        self._host_of: Dict[str, int] = {}
        for h in range(num_hosts):
            host = f"h{h}"
            self.g.add_node(host, kind="host")
            numas = [f"{host}:n{i}" for i in range(numa_per_host)]
            for n in numas:
                self.g.add_node(n, kind="numa")
                self.g.add_edge(host, n)
            roots_per_host = devices_per_host // devices_per_root
            for r in range(roots_per_host):
                root = f"{host}:r{r}"
                numa = numas[r * numa_per_host // roots_per_host]
                self.g.add_node(root, kind="root")
                self.g.add_edge(numa, root)
                for d in range(devices_per_root):
                    dev = f"{host}:g{r * devices_per_root + d}"
                    self.g.add_node(dev, kind="device")
                    self.g.add_edge(root, dev)
                    self._root_of[dev] = root
                    self._numa_of[dev] = numa
                    self._host_of[dev] = h

    # ------------------------------------------------------------- queries
    def devices(self, host: Optional[int] = None) -> List[str]:
        devs = [n for n, d in self.g.nodes(data=True) if d["kind"] == "device"]
        if host is not None:
            devs = [d for d in devs if self._host_of[d] == host]
        return sorted(devs)

    def roots(self) -> List[str]:
        return sorted(n for n, d in self.g.nodes(data=True)
                      if d["kind"] == "root")

    def numas(self) -> List[str]:
        return sorted(n for n, d in self.g.nodes(data=True)
                      if d["kind"] == "numa")

    def root_of(self, device: str) -> str:
        return self._root_of[device]

    def numa_of(self, device: str) -> str:
        return self._numa_of[device]

    def host_of(self, device: str) -> int:
        return self._host_of[device]

    def same_root(self, a: str, b: str) -> bool:
        return self._root_of[a] == self._root_of[b]

    def same_numa(self, a: str, b: str) -> bool:
        return self._numa_of[a] == self._numa_of[b]

    def slots(self, device: Optional[str] = None) -> List[Slot]:
        devs = [device] if device else self.devices()
        return [Slot(self._host_of[d], d, i)
                for d in devs for i in range(self.slots_per_device)]

    def siblings(self, device: str) -> List[str]:
        """Devices sharing this device's PCIe root complex."""
        return sorted(d for d, r in self._root_of.items()
                      if r == self._root_of[device] and d != device)


def make_p4d_cluster(num_hosts: int = 2) -> ClusterTopology:
    """The paper's testbed: p4d.24xlarge x2 — 8xA100 per host, 4 PCIe root
    complexes (2 GPUs each), 2 NUMA domains."""
    if num_hosts < 1:
        raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
    return ClusterTopology(num_hosts=num_hosts, devices_per_host=8,
                           devices_per_root=2, numa_per_host=2,
                           slots_per_device=2, kind="gpu")


def make_p4d_fleet(num_hosts: int = 4) -> ClusterTopology:
    """The scaled fleet: the paper's p4d node type grown past the 2-host
    testbed (first step of the ROADMAP's "scale the fleet" item — the E5
    ``--hosts 4`` arm measures controller wall-clock per tick against this
    topology)."""
    return make_p4d_cluster(num_hosts)


# Named catalog of the built-in testbeds (today exercised by the topology
# test suite; e5 --hosts builds p4d fleets by host count via
# make_p4d_fleet — a config-file/CLI name-based selector can resolve
# through here when one grows a consumer).
BUILTIN_TOPOLOGIES = {
    "p4d-2host": lambda: make_p4d_cluster(2),     # the paper's testbed
    "p4d-4host": lambda: make_p4d_fleet(4),       # scaled fleet variant
    "tpu-v5e-pod": lambda: make_tpu_pod_hosts(1),
}


def builtin_topology(name: str) -> ClusterTopology:
    """Instantiate a named built-in topology."""
    try:
        return BUILTIN_TOPOLOGIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r} (have "
            f"{sorted(BUILTIN_TOPOLOGIES)})") from None


def make_tpu_pod_hosts(num_pods: int = 1, chips_per_host: int = 4,
                       hosts_per_pod: int = 64) -> ClusterTopology:
    """TPU v5e pod viewed host-wise: each host's PCIe/DMA path feeds
    ``chips_per_host`` chips — that shared path is the PS server."""
    return ClusterTopology(num_hosts=num_pods * hosts_per_pod,
                           devices_per_host=chips_per_host,
                           devices_per_root=chips_per_host, numa_per_host=1,
                           slots_per_device=1, kind="tpu")
