"""Observability substrate: virtual-clock trace events + Chrome export.

This module is the layer-neutral half of the tracing subsystem (the
serving-specific per-request flight recorder lives in
``serving/trace.py``).  Core modules — the controller, the admission
controller, the cluster simulator — accept an optional :class:`Tracer`
and emit *instant* or *span* events onto a shared virtual-clock
timeline; the serving layer subclasses it to accrue per-request span
timelines with a conservation invariant.

Design contract (the reason this file exists at all):

* **Timestamps are always caller-provided virtual-clock seconds.**
  Nothing in here reads a wall clock — tracing must never perturb the
  harness's virtual time, and a trace recorded under the virtual clock
  replays bit-identically.
* **Disabled tracing is free.**  Every call site is guarded
  (``if tracer is not None``): with no tracer attached, zero objects
  are allocated and zero branches beyond the guard run.
* **Export is Chrome ``trace_event`` JSON** (the format Perfetto /
  ``chrome://tracing`` load directly): tracks map to pids, lanes to
  tids, seconds to microseconds.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class TraceEvent:
    """One event on the shared timeline.

    ``ph`` follows the trace_event phase vocabulary: ``"X"`` is a
    complete span (``ts`` + ``dur``), ``"i"`` an instant.  ``track``
    groups events into a Perfetto process row (a tenant, or the
    ``"controller"`` track all actuator/controller events share);
    ``lane`` is the thread row within it (a request id, an actor name).
    """
    name: str
    ph: str                       # "X" complete span | "i" instant
    ts: float                     # virtual-clock seconds
    dur: float = 0.0              # seconds ("X" only)
    track: str = ""
    lane: str = ""
    args: Dict[str, Any] = field(default_factory=dict)


class Tracer:
    """Event collector every instrumented layer can write to.

    The base class just accumulates :class:`TraceEvent` objects —
    enough for the controller/actuator/admission call sites, the
    actuator lint test, and the e5 pause-correlation analysis.  The
    serving flight recorder (``serving/trace.py``) extends it with
    per-request timelines and retention policy.

    ``actions`` additionally indexes every :meth:`action` event (the
    controller-plane subset) so request timelines can be checked for
    overlap with reconfigure pause windows without scanning the full
    event list.
    """

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []
        self.actions: List[TraceEvent] = []

    # ------------------------------------------------------------ emission
    def instant(self, name: str, t: float, track: str = "",
                lane: str = "", **args: Any) -> TraceEvent:
        ev = TraceEvent(name, "i", t, 0.0, track, lane, args)
        self.events.append(ev)
        return ev

    def span(self, name: str, t0: float, t1: float, track: str = "",
             lane: str = "", **args: Any) -> TraceEvent:
        ev = TraceEvent(name, "X", t0, max(0.0, t1 - t0), track, lane, args)
        self.events.append(ev)
        return ev

    def action(self, name: str, t: float, tenant: str, dur: float = 0.0,
               **args: Any) -> TraceEvent:
        """A controller/actuator action.  ``dur > 0`` records the pause
        window it imposes (a MIG reconfigure's re-lower, a move) as a
        span on the shared ``controller`` track; instantaneous knob
        turns (io throttle, MPS quota) land as instants."""
        args = {"tenant": tenant, **args}
        if dur > 0:
            ev = self.span(name, t, t + dur, track="controller",
                           lane=tenant, **args)
        else:
            ev = self.instant(name, t, track="controller", lane=tenant,
                              **args)
        self.actions.append(ev)
        return ev

    # ------------------------------------------------------------- queries
    def actions_overlapping(self, t0: float, t1: float,
                            tenant: Optional[str] = None
                            ) -> List[TraceEvent]:
        """Controller actions whose [ts, ts+dur] intersects [t0, t1].
        ``tenant`` restricts to actions aimed at that tenant; pass None
        for all (a reconfigure pauses one tenant but its fabric /
        arbiter side effects are cluster-wide, so callers often want
        every overlapping action)."""
        out = []
        for ev in self.actions:
            if tenant is not None and ev.args.get("tenant") != tenant:
                continue
            if ev.ts <= t1 and ev.ts + ev.dur >= t0:
                out.append(ev)
        return out


def chrome_trace(events: List[TraceEvent]) -> Dict[str, Any]:
    """Render events as a Chrome/Perfetto ``trace_event`` JSON object.

    Tracks become processes and lanes become threads (named via ``"M"``
    metadata records); virtual seconds become microseconds.  The result
    is ``json.dump``-able and loads directly in Perfetto's UI or
    ``chrome://tracing``.
    """
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    out: List[Dict[str, Any]] = []
    for ev in events:
        track = ev.track or "default"
        lane = ev.lane or "-"
        if track not in pids:
            pids[track] = len(pids) + 1
            out.append({"name": "process_name", "ph": "M",
                        "pid": pids[track], "tid": 0,
                        "args": {"name": track}})
        key = (track, lane)
        if key not in tids:
            tids[key] = len(tids) + 1
            out.append({"name": "thread_name", "ph": "M",
                        "pid": pids[track], "tid": tids[key],
                        "args": {"name": lane}})
        rec: Dict[str, Any] = {
            "name": ev.name, "ph": ev.ph, "ts": ev.ts * 1e6,
            "pid": pids[track], "tid": tids[key], "cat": track,
            "args": ev.args}
        if ev.ph == "X":
            rec["dur"] = ev.dur * 1e6
        else:
            rec["s"] = "t"        # instant scope: thread
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def dump_chrome_trace(events: List[TraceEvent], path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f)
