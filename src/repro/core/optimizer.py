"""Greedy isolation-upgrade heuristic (paper §2.5.2).

The allocation problem (min E[SLO miss] over MIG configs x placements
subject to throughput >= 0.95 T_base) is NP-hard; the paper's greedy step
upgrades m_i to maximise  delta_mu = mu(m') - mu(m)  when p99 persists
above tau, with finite termination because each upgrade strictly increases
isolation (at most |M|-1 upgrades).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.profiles import ProfileLattice, SliceProfile


@dataclass(frozen=True)
class UpgradeChoice:
    profile: SliceProfile
    delta_mu: float


def candidate_upgrades(lattice: ProfileLattice, current: SliceProfile,
                       headroom_units: int) -> List[UpgradeChoice]:
    """All stronger profiles that fit within the device's free capacity."""
    out = []
    for p in lattice.profiles[lattice.index(current) + 1:]:
        extra = p.compute_units - current.compute_units
        if extra <= headroom_units:
            out.append(UpgradeChoice(p, p.mu() - current.mu()))
    return out


def greedy_upgrade(lattice: ProfileLattice, current: SliceProfile,
                   headroom_units: int) -> Optional[SliceProfile]:
    """Pick the upgrade maximising delta_mu (the paper's greedy step).

    Maximising delta_mu over the feasible set selects the *largest* profile
    that fits — consistent with the paper's "upgrade m_i to maximise
    delta_mu_i" — and terminates after at most |M|-1 upgrades.
    """
    cands = candidate_upgrades(lattice, current, headroom_units)
    if not cands:
        return None
    return max(cands, key=lambda c: c.delta_mu).profile


def relax_step(lattice: ProfileLattice, current: SliceProfile
               ) -> Optional[SliceProfile]:
    """One-step relaxation (conservative: never jump multiple levels down)."""
    return lattice.relax(current)


def upgrades_remaining(lattice: ProfileLattice, current: SliceProfile) -> int:
    """Finite-termination bound from §2.5.2."""
    return lattice.max_upgrades_from(current)
