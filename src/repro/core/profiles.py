"""Isolation profiles — the dynamic-reconfiguration lattice (paper §2.2).

Two lattices:
  * ``A100_MIG`` — the paper's exact profiles (1g.10gb … 7g.80gb).  Used by
    the faithful-reproduction simulator.
  * ``TPU_SLICE`` — the TPU-native analogue: sub-meshes of a pod assigned
    per tenant.  "Upgrading isolation" re-shards the tenant onto a larger
    slice (pjit re-lower + weight move), which like a MIG change requires a
    brief tenant pause.

Both expose the same ordered interface, so the controller (policy.py,
optimizer.py) is lattice-agnostic.  mu(m) — the service-capacity proxy the
greedy optimizer maximises (paper §2.5.2: "mu(m) proportional to SM cores
and memory in profile m") — is ``compute_units``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class SliceProfile:
    name: str
    compute_units: int        # GPU: GPCs ("g"); TPU: chips x cores
    memory_gb: float
    chips: int = 1            # TPU slices span chips; MIG profiles stay at 1

    def mu(self) -> float:
        """Service-capacity proxy (paper: proportional to SMs + memory)."""
        return float(self.compute_units)


class ProfileLattice:
    """Totally-ordered isolation lattice with upgrade/relax moves."""

    def __init__(self, profiles: Sequence[SliceProfile]):
        self.profiles: Tuple[SliceProfile, ...] = tuple(
            sorted(profiles, key=lambda p: (p.compute_units, p.memory_gb)))
        self._index = {p.name: i for i, p in enumerate(self.profiles)}

    def __len__(self) -> int:
        return len(self.profiles)

    def __getitem__(self, name: str) -> SliceProfile:
        return self.profiles[self._index[name]]

    def index(self, p: SliceProfile) -> int:
        return self._index[p.name]

    def upgrade(self, p: SliceProfile) -> Optional[SliceProfile]:
        """Next-stronger profile, or None at the top (finite termination:
        at most len(lattice)-1 upgrades, paper §2.5.2)."""
        i = self.index(p)
        return self.profiles[i + 1] if i + 1 < len(self.profiles) else None

    def relax(self, p: SliceProfile) -> Optional[SliceProfile]:
        i = self.index(p)
        return self.profiles[i - 1] if i > 0 else None

    def max_upgrades_from(self, p: SliceProfile) -> int:
        return len(self.profiles) - 1 - self.index(p)

    @property
    def top(self) -> SliceProfile:
        return self.profiles[-1]

    @property
    def bottom(self) -> SliceProfile:
        return self.profiles[0]


# The paper's A100-80GB MIG profile set.
A100_MIG = ProfileLattice([
    SliceProfile("1g.10gb", 1, 10.0),
    SliceProfile("2g.20gb", 2, 20.0),
    SliceProfile("3g.40gb", 3, 40.0),
    SliceProfile("4g.40gb", 4, 40.0),
    SliceProfile("7g.80gb", 7, 80.0),
])

# TPU v5e slice lattice (compute_units = chips; 16 GB HBM per chip).
TPU_SLICE = ProfileLattice([
    SliceProfile("1x1", 1, 16.0, chips=1),
    SliceProfile("2x1", 2, 32.0, chips=2),
    SliceProfile("2x2", 4, 64.0, chips=4),
    SliceProfile("4x2", 8, 128.0, chips=8),
    SliceProfile("4x4", 16, 256.0, chips=16),
    SliceProfile("8x4", 32, 512.0, chips=32),
])
