"""Tenant identity as data: TenantSpec / TenantRegistry, and the shared
placement/MIG arbiter that resolves conflicting isolation upgrades under a
cluster-wide per-GPU compute-unit budget.

The seed reproduction hard-coded the paper's evaluation shape — exactly one
latency-sensitive tenant ("T1") against two fixed interferers — into the
simulator's attributes and the controller's assumptions.  This module makes
the tenant set a first-class value: any number of latency-sensitive SLO
tenants, each with R >= 1 batched replicas, plus any number of background
interferers, all described by specs and driven through the same controller.
This is the regime studied by MIG-serving (arXiv:2109.11067) and ParvaGPU
(arXiv:2409.14447), where reconfiguration must arbitrate *between*
competing SLO tenants rather than shield a single one.

Layout of a slot key: ``"h0:g3:s1"`` = host 0, device g3, slot index 1 —
the same string `Slot.key` produces.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.core.profiles import ProfileLattice, SliceProfile
from repro.core.topology import ClusterTopology, Slot

LATENCY = "latency"
BACKGROUND = "background"


@dataclass(frozen=True)
class TenantSpec:
    """Everything the stack needs to know about one tenant.

    Latency tenants use the workload block (rate/SLO/size mix/compute law)
    and run ``replicas`` serving instances, each on its own placement slot
    with up to ``max_batch`` requests in flight.  Background tenants use
    the interference block (PCIe/IO/SM demands) and are the targets of the
    controller's guardrails.
    """
    name: str
    role: str = LATENCY
    priority: float = 1.0          # arbiter weight (higher wins conflicts)
    replicas: int = 1
    # --- workload (latency tenants) ---
    rate: float = 12.0             # Poisson arrivals /s (tenant aggregate)
    slo_s: float = 0.015
    sizes: Tuple[Tuple[float, float], ...] = ((1.0, 12e6),)  # (prob, bytes)
    c0_s: float = 0.007            # compute at the reference profile
    ref_units: int = 2
    gamma: float = 0.35            # compute ~ (ref/units)^gamma
    profile: str = "2g.20gb"       # initial isolation profile
    max_batch: int = 1             # per-replica concurrent requests
    batch_penalty: float = 0.20    # service inflation per extra in-flight req
    # --- interference (background tenants) ---
    pcie_demand: float = 0.0       # bytes/s on the root complex when active
    ps_weight: float = 1.0         # PS-fabric weight (DMA queues/streams)
    io_demand: float = 0.0         # host block-I/O bytes/s when active
    sm_util: float = 0.0           # SM occupancy on its device when active
    units: int = 0                 # compute units it pins on its device
    throttle_residual: float = 0.7  # PCIe demand surviving an io.max cap
    # --- placement (slot keys; empty = auto-placed) ---
    placement: Tuple[str, ...] = ()

    @property
    def is_latency(self) -> bool:
        return self.role == LATENCY

    @property
    def mean_size(self) -> float:
        return sum(p * s for p, s in self.sizes)

    def with_(self, **kw) -> "TenantSpec":
        return replace(self, **kw)


def parse_slot_key(topo: ClusterTopology, key: str) -> Slot:
    """Inverse of Slot.key: "h0:g3:s1" -> Slot(0, "h0:g3", 1)."""
    device, _, sidx = key.rpartition(":s")
    return Slot(topo.host_of(device), device, int(sidx))


class TenantRegistry:
    """Ordered, named collection of TenantSpecs + placement resolution."""

    def __init__(self, specs: Iterable[TenantSpec] = ()):
        self._specs: Dict[str, TenantSpec] = {}
        for s in specs:
            self.add(s)

    # ----------------------------------------------------------- container
    def add(self, spec: TenantSpec) -> "TenantRegistry":
        if spec.name in self._specs:
            raise ValueError(f"duplicate tenant {spec.name!r}")
        self._specs[spec.name] = spec
        return self

    def remove(self, name: str) -> TenantSpec:
        """Retire a tenant (admission churn: departures free their slots)."""
        return self._specs.pop(name)

    def __iter__(self) -> Iterator[TenantSpec]:
        return iter(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __getitem__(self, name: str) -> TenantSpec:
        return self._specs[name]

    def names(self) -> List[str]:
        return list(self._specs)

    def latency(self) -> List[TenantSpec]:
        return [s for s in self if s.is_latency]

    def background(self) -> List[TenantSpec]:
        return [s for s in self if not s.is_latency]

    # ---------------------------------------------------------- placement
    def resolve_placements(self, topo: ClusterTopology
                           ) -> Dict[str, List[Slot]]:
        """Fixed placements first, then deterministic auto-placement of the
        remaining replicas: spread across PCIe roots/devices round-robin so
        co-tenancy (the thing the controller manages) isn't accidental."""
        out: Dict[str, List[Slot]] = {}
        taken = set()
        todo: List[Tuple[TenantSpec, int]] = []   # (spec, replicas to place)
        for spec in self:
            want = spec.replicas if spec.is_latency else max(
                1, len(spec.placement))
            slots = [parse_slot_key(topo, k) for k in spec.placement[:want]]
            for s in slots:
                if s.key in taken:
                    raise ValueError(f"slot {s.key} double-assigned "
                                     f"(tenant {spec.name})")
                taken.add(s.key)
            out[spec.name] = slots
            if len(slots) < want:
                todo.append((spec, want - len(slots)))
        if todo:
            # interleave devices across roots: r0 of each host, r1, ...
            devices = sorted(topo.devices(),
                             key=lambda d: (topo.root_of(d), d))
            by_root: Dict[str, List[str]] = {}
            for d in devices:
                by_root.setdefault(topo.root_of(d), []).append(d)
            roots = sorted(by_root)
            order: List[Slot] = []
            for idx in range(topo.slots_per_device):
                for pos in range(max(len(v) for v in by_root.values())):
                    for r in roots:
                        devs = by_root[r]
                        if pos < len(devs):
                            d = devs[pos]
                            order.append(Slot(topo.host_of(d), d, idx))
            free = iter([s for s in order if s.key not in taken])
            for spec, n in todo:
                for _ in range(n):
                    try:
                        s = next(free)
                    except StopIteration:
                        raise ValueError(
                            f"cluster out of slots placing {spec.name}")
                    taken.add(s.key)
                    out[spec.name].append(s)
        return out

    # ----------------------------------------------------------- builders
    @classmethod
    def paper_default(cls, params) -> "TenantRegistry":
        """The paper's 3-tenant evaluation scenario (§3.3.1) expressed as
        data: one latency-sensitive inference tenant against a
        bandwidth-heavy ETL tenant on its PCIe root and a compute-heavy
        trainer on its GPU.  Field values come from SimParams so the E1/E2
        calibration is unchanged."""
        return cls([
            TenantSpec(
                name="T1", role=LATENCY, priority=1.0, replicas=1,
                rate=params.t1_rate, slo_s=params.t1_slo_s,
                sizes=tuple(params.t1_sizes), c0_s=params.t1_c0_s,
                ref_units=params.t1_ref_units, gamma=params.t1_gamma,
                profile="2g.20gb", max_batch=1,
                placement=("h0:g0:s0",)),
            TenantSpec(
                name="T2", role=BACKGROUND, profile="7g.80gb",
                pcie_demand=params.t2_pcie_demand,
                ps_weight=params.t2_ps_weight,
                io_demand=params.t2_io_demand,
                throttle_residual=params.t2_throttle_residual,
                units=0,                      # folded into the device model
                placement=("h0:g1:s0",)),
            TenantSpec(
                name="T3", role=BACKGROUND, profile="2g.20gb",
                sm_util=params.t3_sm_util, units=params.t3_units,
                placement=("h0:g0:s1",)),
        ])

    @classmethod
    def slo_fleet(cls, n_tenants: int, replicas: int = 1, *,
                  base_rate: float = 6.0, slo_s: float = 0.015,
                  profile: str = "2g.20gb", max_batch: int = 1,
                  priorities: Optional[Sequence[float]] = None,
                  with_interferers: bool = True,
                  etl_demand: float = 20e9, trainer_sm: float = 0.95,
                  ) -> "TenantRegistry":
        """N competing SLO tenants (the multi-tenant regime), optionally
        with the paper's two interferer classes.  Priorities default to a
        mild gradient so arbitration order is exercised."""
        reg = cls()
        for i in range(n_tenants):
            pr = (priorities[i] if priorities is not None
                  else 1.0 + 0.25 * (n_tenants - 1 - i))
            reg.add(TenantSpec(
                name=f"L{i}", role=LATENCY, priority=pr, replicas=replicas,
                rate=base_rate, slo_s=slo_s, profile=profile,
                max_batch=max_batch,
                sizes=((0.75, 12e6), (0.20, 24e6), (0.05, 32e6))))
        if with_interferers:
            reg.add(TenantSpec(
                name="ETL", role=BACKGROUND, profile="7g.80gb",
                pcie_demand=etl_demand, ps_weight=4.0, io_demand=2.5e9,
                units=0, placement=("h0:g1:s0",)))
            reg.add(TenantSpec(
                name="TRAIN", role=BACKGROUND, profile="2g.20gb",
                sm_util=trainer_sm, units=2, placement=("h0:g0:s1",)))
        return reg


# ======================================================================
# The shared placement/MIG arbiter
# ======================================================================
@dataclass
class ArbiterEntry:
    """One line of the arbiter's audit trail.  ``used_after`` is the
    arbiter's accounting of compute units on ``device`` after the action —
    the e5 budget check asserts used_after <= budget on every entry."""
    time: float
    action: str                    # register|release|grant|deny|move
    tenant: str
    device: str
    units: int                     # units requested / registered / moved
    used_after: int
    budget: int


def lane_weight(priority: float, miss_rate: float) -> float:
    """Priority-weighted urgency of a tenant lane: highest-miss-rate-first
    within a priority class, higher priority classes first overall.  The
    single source for both the controller's mitigation order and the
    arbiter's request ranking."""
    return priority * (1.0 + miss_rate)


@dataclass(frozen=True)
class UpgradeRequest:
    """A tenant lane asking for a bigger slice on its replica devices."""
    tenant: str
    priority: float
    miss_rate: float
    devices: Tuple[str, ...]
    current: SliceProfile
    target: SliceProfile

    @property
    def weight(self) -> float:
        return lane_weight(self.priority, self.miss_rate)


class ComputeArbiter:
    """Cluster-wide compute-unit bookkeeping for latency tenants.

    Each A100-class device exposes ``budget`` (7) compute units.  Every
    latency replica occupies its tenant's profile units on its device; an
    isolation upgrade asks for the delta on *every* device hosting one of
    the tenant's replicas.  When several lanes breach in the same control
    round, `rank()` orders them priority-weighted highest-miss-first and
    grants greedily — the rest are denied (and logged) rather than
    oversubscribing a GPU.
    """

    def __init__(self, lattice: ProfileLattice, budget_per_gpu: int = 7):
        self.lattice = lattice
        self.budget = budget_per_gpu
        self._used: Dict[str, Dict[str, int]] = {}   # device -> owner -> units
        self.log: List[ArbiterEntry] = []

    # -------------------------------------------------------- bookkeeping
    def used(self, device: str) -> int:
        return sum(self._used.get(device, {}).values())

    def headroom(self, device: str) -> int:
        return self.budget - self.used(device)

    def owners(self, device: str) -> Dict[str, int]:
        return dict(self._used.get(device, {}))

    def _log(self, time: float, action: str, tenant: str, device: str,
             units: int) -> None:
        self.log.append(ArbiterEntry(time, action, tenant, device, units,
                                     self.used(device), self.budget))

    def occupy(self, tenant: str, device: str, units: int,
               time: float = 0.0, replica: int = 0) -> None:
        owner = f"{tenant}/r{replica}"
        dev = self._used.setdefault(device, {})
        # check before mutating so a rejected registration leaves the
        # accounting table untouched
        would_use = self.used(device) - dev.get(owner, 0) + units
        if would_use > self.budget:
            raise ValueError(
                f"registering {owner} ({units}u) oversubscribes {device}: "
                f"{would_use}/{self.budget}")
        dev[owner] = units
        self._log(time, "register", tenant, device, units)

    def vacate(self, tenant: str, device: str, time: float = 0.0,
               replica: int = 0) -> None:
        owner = f"{tenant}/r{replica}"
        dev = self._used.get(device, {})
        if owner in dev:
            units = dev.pop(owner)
            self._log(time, "release", tenant, device, units)

    def move(self, tenant: str, src_device: str, dst_device: str,
             units: int, time: float = 0.0, replica: int = 0) -> None:
        self.vacate(tenant, src_device, time, replica)
        owner = f"{tenant}/r{replica}"
        self._used.setdefault(dst_device, {})[owner] = units
        self._log(time, "move", tenant, dst_device, units)

    # -------------------------------------------------------- arbitration
    @staticmethod
    def rank(requests: Sequence[UpgradeRequest]) -> List[UpgradeRequest]:
        return sorted(requests, key=lambda r: (-r.weight, r.tenant))

    def grant(self, req: UpgradeRequest, time: float = 0.0,
              external_headroom: Optional[Dict[str, int]] = None) -> bool:
        """Atomically grant (or deny) an upgrade across all replica
        devices.  ``external_headroom`` lets the caller fold in occupancy
        the arbiter cannot see (ambient co-tenants, background slices) —
        the effective headroom per device is min(arbiter, external)."""
        extra = req.target.compute_units - req.current.compute_units
        if extra <= 0:
            return False
        prefix = f"{req.tenant}/"
        for dev in set(req.devices):
            n_here = sum(1 for o in self._used.get(dev, {})
                         if o.startswith(prefix))
            need = extra * max(1, n_here)
            have = self.headroom(dev)
            if external_headroom is not None and dev in external_headroom:
                have = min(have, external_headroom[dev])
            if need > have:
                self._log(time, "deny", req.tenant, dev, extra)
                return False
        for dev in set(req.devices):
            for owner in list(self._used.get(dev, {})):
                if owner.startswith(prefix):
                    self._used[dev][owner] = req.target.compute_units
            self._log(time, "grant", req.tenant, dev, extra)
        return True

    def set_profile(self, tenant: str, units: int, time: float = 0.0,
                    action: str = "register") -> None:
        """Resync every replica of ``tenant`` to ``units`` (relax path)."""
        for dev, owners in self._used.items():
            hit = False
            for owner in owners:
                if owner.startswith(f"{tenant}/"):
                    owners[owner] = units
                    hit = True
            if hit:
                self._log(time, action, tenant, dev, units)

    # ------------------------------------------------------------- checks
    def max_used(self) -> int:
        """Peak per-GPU occupancy over the whole audit trail."""
        return max((e.used_after for e in self.log), default=0)

    def audit_ok(self) -> bool:
        return all(e.used_after <= e.budget for e in self.log)
