"""Decision FSM (paper Algorithm 1 + §2.3): breach persistence, dwell,
cool-down, stability detection, and post-change validation windows."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class PolicyConfig:
    """Paper Table 1 defaults."""
    tau_s: float = 0.015             # tail threshold (15 ms p99)
    persistence: int = 3             # Y consecutive windows above tau
    dwell_obs: int = 256             # min observations between actions
    cooldown_obs: int = 128          # grace period after recovery
    stable_obs: int = 64             # windows well inside SLO before relax
    stable_margin: float = 0.7       # "well within": p99 < margin * tau
    validation_obs: int = 45         # post-change validation window
    throughput_budget: float = 0.95  # T_i >= 0.95 T_base


class Phase(enum.Enum):
    MONITOR = "monitor"
    VALIDATE = "validate"


class Trigger(enum.Enum):
    NONE = "none"
    BREACH = "breach"        # p99 > tau for Y consecutive windows
    STABLE = "stable"        # sustained headroom -> consider relaxing


class DecisionFSM:
    """Counts observation windows; gates actions exactly as Algorithm 1:

        if not at_reconfig_boundary() or is_cooling_down(): return
        if p99 > tau for Y consecutive windows: UpgradeIsolation
        elif tail_is_stable() and throughput_ok(): RelaxIsolation
    """

    def __init__(self, cfg: PolicyConfig):
        self.cfg = cfg
        self.phase = Phase.MONITOR
        self.breach_streak = 0
        self.stable_streak = 0
        self.obs_since_action = cfg.dwell_obs      # allow an initial action
        self.cooldown_left = 0
        self.validate_left = 0
        self._baseline_p99: Optional[float] = None  # pre-change p99 (rollback)

    # ------------------------------------------------------------- queries
    def at_reconfig_boundary(self) -> bool:
        return self.obs_since_action >= self.cfg.dwell_obs

    def is_cooling_down(self) -> bool:
        return self.cooldown_left > 0

    # ------------------------------------------------------------- updates
    def observe(self, p99: float, throughput_ok: bool = True) -> Trigger:
        """One observation window.  Returns the gated trigger."""
        self.obs_since_action += 1
        if self.cooldown_left > 0:
            self.cooldown_left -= 1

        if p99 > self.cfg.tau_s:
            self.breach_streak += 1
            self.stable_streak = 0
        else:
            self.breach_streak = 0
            if p99 < self.cfg.stable_margin * self.cfg.tau_s:
                self.stable_streak += 1
            else:
                self.stable_streak = 0

        if self.phase == Phase.VALIDATE:
            self.validate_left -= 1
            return Trigger.NONE    # actions gated during validation

        # Raw persistence triggers.  Lightweight guardrails may act on a
        # BREACH immediately; *structural* actions (move / reconfigure /
        # relax) are additionally gated by at_reconfig_boundary() and
        # is_cooling_down() in the controller — exactly Algorithm 1's
        # "if not at_reconfig_boundary() or is_cooling_down(): return".
        if self.breach_streak >= self.cfg.persistence:
            return Trigger.BREACH
        if self.stable_streak >= self.cfg.stable_obs and throughput_ok:
            return Trigger.STABLE
        return Trigger.NONE

    def action_taken(self, pre_change_p99: float) -> None:
        """Start dwell + post-change validation (paper §2.4: rollback if
        post-change p99 worsens within a short validation window)."""
        self.obs_since_action = 0
        self.cooldown_left = self.cfg.cooldown_obs
        self.breach_streak = 0
        self.stable_streak = 0
        self.phase = Phase.VALIDATE
        self.validate_left = self.cfg.validation_obs
        self._baseline_p99 = pre_change_p99

    def validation_result(self, current_p99: float) -> Optional[bool]:
        """Returns None while validating, else True (keep) / False (rollback)."""
        if self.phase != Phase.VALIDATE:
            return None
        if self.validate_left > 0:
            return None
        self.phase = Phase.MONITOR
        # generous margin: the pre-change baseline is often captured while
        # the interference burst (and the EMA) is still ramping, so a small
        # post-change excess is not evidence the action hurt
        ok = (self._baseline_p99 is None
              or current_p99 <= self._baseline_p99 * 1.25)
        self._baseline_p99 = None
        return ok
