# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Shared placement/budget state lives in core.ledger.DeviceLedger (one
# source of truth for both actuators and admission); import it from here
# for convenience.
from repro.core.ledger import DeviceLedger, LedgerEntry, LedgerError  # noqa: F401
