"""Deterministic fault injection + the recovery-side actuator wrapper.

Failure-domain machinery for the serving stack.  Three pieces:

- ``FaultInjector`` — a seeded, virtual-clock fault schedule (replica
  crashes, actuator-call failures/timeouts, transient fabric
  degradation, stuck decode lanes).  The schedule is a pure function of
  its seed and plan arguments, every delivery is appended to ``log``,
  and no wall-clock or unseeded randomness is consulted anywhere — so a
  chaos run replays bit-identically from the same seed (property-tested
  in ``tests/test_faults.py``).

- ``RetryingActuator`` — wraps any ``Actuator`` (ServingActuator or
  ClusterSim) with bounded retries, virtual-time exponential backoff
  (backoff is *charged to the returned pause* for pause-returning
  methods — retrying is downtime, not free), and rollback to the last
  known-good setting when retries exhaust.  Retry cycles are gated by
  the controller's dwell/cooldown FSM: once a (method, tenant) pair
  exhausts, further cycles are refused for a cooldown window, and a
  cooling-down FSM stops a cycle after its first failed attempt — the
  wrapper can never thrash an actuator the control law already decided
  to leave alone.

- ``StuckLaneWatchdog`` — observes per-lane token progress and reports
  lanes that have made none for longer than a timeout; the caller
  requeues them through the scheduler's refcount-safe preemption path.

Crash recovery itself (redrive, directory retraction, ledger release)
lives with the dispatcher in ``launch/serve.py``; this module only
decides *when* things break and how actuation heals.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class ActuatorFault(RuntimeError):
    """An injected (or real) failure of a single actuator call."""


@dataclass(frozen=True)
class Fault:
    """One scheduled fault.  ``kind`` selects which fields matter:

    - ``replica_crash``: tenant, replica
    - ``actuator_fail``: method, count (consecutive failing calls),
      timeout_s (virtual time each failed call burns before erroring)
    - ``fabric_degrade``: factor (>1 inflates step durations), duration_s
    - ``lane_stuck``: tenant, replica (the harness picks the victim lane
      deterministically; the lane stays stuck until recovered)
    - ``replica_slow``: tenant, replica, factor (>1 inflates that one
      replica's step durations), duration_s — a gray failure: the
      replica keeps answering, just slowly, so the crash detector and
      the watchdog both stay quiet while the tail degrades
    """
    time: float
    kind: str
    tenant: str = ""
    replica: int = -1
    method: str = ""
    count: int = 1
    timeout_s: float = 0.0
    factor: float = 1.0
    duration_s: float = 0.0


class FaultInjector:
    """Seeded virtual-clock fault schedule every layer consults.

    The harness drains ``due(now)`` each loop iteration and handles
    ``replica_crash`` / ``lane_stuck`` events itself; ``actuator_fail``
    and ``fabric_degrade`` events arm injector-internal state that the
    :class:`RetryingActuator` and the step loop query.  All queries are
    pure functions of (schedule, query times), so two runs driving the
    same virtual clock produce identical ``log`` contents.
    """

    def __init__(self, schedule: Sequence[Fault] = ()):
        self.schedule: List[Fault] = sorted(schedule,
                                            key=lambda f: (f.time, f.kind,
                                                           f.tenant,
                                                           f.replica,
                                                           f.method))
        self._cursor = 0
        # armed state from delivered events
        self._armed_fail: Dict[str, int] = {}       # method -> calls left
        self._fail_timeout: Dict[str, float] = {}   # method -> timeout_s
        self._fabric: List[Tuple[float, float, float]] = []  # (t0, t1, fac)
        # (t0, t1, tenant, replica, factor) gray-failure windows
        self._slow: List[Tuple[float, float, str, int, float]] = []
        # replay-identity record: (time, kind, detail)
        self.log: List[Tuple[float, str, str]] = []

    # ---------------------------------------------------------- planning
    @classmethod
    def plan(cls, seed: int, duration_s: float, *,
             tenants: Sequence[str], replicas: int,
             crashes: int = 1, actuator_failures: int = 2,
             stuck_lanes: int = 1, fabric_windows: int = 0,
             methods: Sequence[str] = ("reconfigure", "move"),
             fail_count: int = 2, fail_timeout_s: float = 0.5,
             fabric_factor: float = 2.0,
             fabric_duration_s: float = 5.0,
             slow_replicas: int = 0,
             slow_factor: float = 4.0,
             slow_duration_s: float = 5.0) -> "FaultInjector":
        """Generate a schedule deterministically from ``seed`` and the
        plan arguments — no other entropy source exists."""
        rng = np.random.default_rng(seed)
        tenants = list(tenants)
        events: List[Fault] = []
        for _ in range(crashes):
            events.append(Fault(
                time=float(rng.uniform(0.25, 0.65) * duration_s),
                kind="replica_crash",
                tenant=tenants[int(rng.integers(len(tenants)))],
                replica=int(rng.integers(replicas))))
        for _ in range(actuator_failures):
            events.append(Fault(
                time=float(rng.uniform(0.1, 0.9) * duration_s),
                kind="actuator_fail",
                method=str(methods[int(rng.integers(len(methods)))]),
                count=fail_count, timeout_s=fail_timeout_s))
        for _ in range(stuck_lanes):
            events.append(Fault(
                time=float(rng.uniform(0.15, 0.75) * duration_s),
                kind="lane_stuck",
                tenant=tenants[int(rng.integers(len(tenants)))],
                replica=int(rng.integers(replicas))))
        for _ in range(fabric_windows):
            events.append(Fault(
                time=float(rng.uniform(0.1, 0.8) * duration_s),
                kind="fabric_degrade",
                factor=fabric_factor, duration_s=fabric_duration_s))
        for _ in range(slow_replicas):
            events.append(Fault(
                time=float(rng.uniform(0.15, 0.6) * duration_s),
                kind="replica_slow",
                tenant=tenants[int(rng.integers(len(tenants)))],
                replica=int(rng.integers(replicas)),
                factor=slow_factor, duration_s=slow_duration_s))
        return cls(events)

    # ---------------------------------------------------------- delivery
    def due(self, now: float) -> List[Fault]:
        """Deliver every scheduled fault with ``time <= now`` (in
        schedule order), arming internal state for the armed kinds."""
        out: List[Fault] = []
        while self._cursor < len(self.schedule) and \
                self.schedule[self._cursor].time <= now:
            f = self.schedule[self._cursor]
            self._cursor += 1
            if f.kind == "actuator_fail":
                self._armed_fail[f.method] = \
                    self._armed_fail.get(f.method, 0) + f.count
                self._fail_timeout[f.method] = f.timeout_s
            elif f.kind == "fabric_degrade":
                self._fabric.append((f.time, f.time + f.duration_s,
                                     f.factor))
            elif f.kind == "replica_slow":
                self._slow.append((f.time, f.time + f.duration_s,
                                   f.tenant, f.replica, f.factor))
            self.log.append((f.time, f.kind,
                             f"{f.tenant}/{f.replica}/{f.method}"))
            out.append(f)
        return out

    def pending(self) -> int:
        return len(self.schedule) - self._cursor

    # ------------------------------------------------------- armed kinds
    def actuator_fault(self, method: str, now: float) -> Optional[Fault]:
        """Consume one armed failure for ``method`` (None if healthy)."""
        left = self._armed_fail.get(method, 0)
        if left <= 0:
            return None
        self._armed_fail[method] = left - 1
        timeout = self._fail_timeout.get(method, 0.0)
        self.log.append((now, "actuator_fault_delivered", method))
        return Fault(time=now, kind="actuator_fail", method=method,
                     timeout_s=timeout)

    def fabric_factor(self, now: float) -> float:
        """Step-duration multiplier from any active degradation window
        (windows multiply if they overlap)."""
        factor = 1.0
        for t0, t1, fac in self._fabric:
            if t0 <= now < t1:
                factor *= fac
        return factor

    def replica_factor(self, tenant: str, replica: int,
                       now: float) -> float:
        """Step-duration multiplier for one replica from any active
        ``replica_slow`` window (overlapping windows multiply), on top
        of the global :meth:`fabric_factor`."""
        factor = 1.0
        for t0, t1, ten, rep, fac in self._slow:
            if ten == tenant and rep == replica and t0 <= now < t1:
                factor *= fac
        return factor

    # ------------------------------------------------------------ replay
    def replay_key(self) -> Tuple[Tuple[float, str, str], ...]:
        """Canonical record of every delivery, for determinism asserts."""
        return tuple(self.log)


class StuckLaneWatchdog:
    """Detects lanes that stopped emitting tokens.

    The harness feeds it every active lane's ``generated`` counter after
    each engine step; ``stale(now)`` returns the keys that have made no
    progress for longer than ``timeout_s`` so the caller can requeue
    them through the scheduler's refcount-safe preemption path.
    """

    def __init__(self, timeout_s: float = 1.0):
        self.timeout_s = timeout_s
        self._progress: Dict[object, Tuple[int, float]] = {}
        self.fired: int = 0

    def observe(self, key, generated: int, now: float) -> None:
        prev = self._progress.get(key)
        if prev is None or generated > prev[0]:
            self._progress[key] = (generated, now)

    def forget(self, key) -> None:
        self._progress.pop(key, None)

    def prune(self, live_keys) -> None:
        """Drop tracking for every lane not in ``live_keys`` — lanes
        that completed, preempted or drained must never be reported
        stale just because they stopped appearing."""
        live = set(live_keys)
        for k in [k for k in self._progress if k not in live]:
            del self._progress[k]

    def stale(self, now: float) -> List[object]:
        out = [k for k, (_, since) in self._progress.items()
               if now - since >= self.timeout_s]
        if out:
            self.fired += len(out)
            for k in out:
                self._progress.pop(k, None)
        return out


@dataclass(frozen=True)
class RetryConfig:
    max_attempts: int = 3            # total tries per call (1 + retries)
    base_backoff_s: float = 0.05     # virtual-time delay before retry 1
    backoff_mult: float = 2.0        # exponential growth per retry
    exhaustion_cooldown_s: float = 10.0   # refuse new cycles this long


class RetryingActuator:
    """Bounded-retry wrapper over the controller's ``Actuator`` protocol.

    Implements every protocol method (lint-enforced over
    ``vars(Actuator)`` in ``tests/test_faults.py``) by delegating to the
    wrapped actuator through one retry loop:

    - each attempt first consults the :class:`FaultInjector` (and also
      treats an :class:`ActuatorFault` raised by the inner actuator as a
      failure), backing off exponentially in *virtual* time;
    - backoff + injected timeouts are charged to the returned pause for
      pause-returning methods (``reconfigure`` / ``move``) — a retried
      reconfigure pauses the tenant longer, it is not free;
    - on exhaustion the wrapper rolls the (method, tenant) pair back to
      its last known-good setting (recorded on every success) and gates
      further retry cycles for ``exhaustion_cooldown_s``;
    - a cooling-down :class:`~repro.core.policy.DecisionFSM` (via
      ``fsm_for``) stops a cycle after its first failed attempt, so
      retries never thrash a lane the control law is holding still.
    """

    def __init__(self, inner, clock: Callable[[], float],
                 faults: Optional[FaultInjector] = None,
                 cfg: RetryConfig = RetryConfig(),
                 fsm_for: Optional[Callable[[str], object]] = None,
                 tracer=None):
        self.inner = inner
        self.clock = clock
        self.faults = faults
        self.cfg = cfg
        self.fsm_for = fsm_for
        self.tracer = tracer
        self._last_good: Dict[Tuple[str, str], tuple] = {}
        self._gate_until: Dict[Tuple[str, str], float] = {}
        self.stats: Dict[str, int] = {
            "calls": 0, "faults": 0, "retried_calls": 0,
            "exhausted": 0, "rollbacks": 0, "rollback_failed": 0,
            "gated": 0,
        }
        self.time_lost_s: float = 0.0

    # ------------------------------------------------------------ helpers
    def _trace(self, name: str, tenant: str, **args) -> None:
        if self.tracer is not None:
            self.tracer.action(name, self.clock(), tenant, **args)

    def _fsm_cooling(self, tenant: str) -> bool:
        if self.fsm_for is None or not tenant:
            return False
        fsm = self.fsm_for(tenant)
        return fsm is not None and fsm.is_cooling_down()

    def _call(self, method: str, tenant: str, args: tuple, *,
              charge_pause: bool = False, default=None):
        self.stats["calls"] += 1
        now = self.clock()
        key = (method, tenant)
        if self._gate_until.get(key, -math.inf) > now:
            # a previous cycle exhausted for this pair and we are still
            # inside its cooldown: don't start another storm
            self.stats["gated"] += 1
            self._trace("actuator_gated", tenant, method=method)
            return default
        delay = self.cfg.base_backoff_s
        lost = 0.0
        for attempt in range(self.cfg.max_attempts):
            fault = (self.faults.actuator_fault(method, now + lost)
                     if self.faults is not None else None)
            if fault is None:
                try:
                    val = getattr(self.inner, method)(*args)
                except ActuatorFault as exc:
                    fault = Fault(time=now + lost, kind="actuator_fail",
                                  method=method)
                    self._trace("actuator_fault", tenant, method=method,
                                error=str(exc))
                else:
                    if attempt > 0:
                        self.stats["retried_calls"] += 1
                    self._last_good[key] = args
                    if charge_pause and lost > 0:
                        self.time_lost_s += lost
                        return float(val) + lost
                    return val
            self.stats["faults"] += 1
            self._trace("actuator_retry", tenant, method=method,
                        attempt=attempt + 1, backoff_s=delay)
            lost += fault.timeout_s + delay
            delay *= self.cfg.backoff_mult
            if self._fsm_cooling(tenant):
                break   # FSM says hold still: no further retries
        # ---- exhausted: roll back to last known-good and gate
        self.stats["exhausted"] += 1
        self.time_lost_s += lost
        self._gate_until[key] = now + self.cfg.exhaustion_cooldown_s
        good = self._last_good.get(key)
        if good is not None and good != args:
            blocked = (self.faults.actuator_fault(method, now + lost)
                       if self.faults is not None else None)
            if blocked is None:
                try:
                    getattr(self.inner, method)(*good)
                    self.stats["rollbacks"] += 1
                    self._trace("actuator_rollback", tenant, method=method)
                except ActuatorFault:
                    self.stats["rollback_failed"] += 1
            else:
                self.stats["rollback_failed"] += 1
        return default

    # ------------------------------------------- Actuator protocol surface
    def reconfigure(self, tenant, profile):
        return self._call("reconfigure", tenant, (tenant, profile),
                          charge_pause=True, default=0.0)

    def move(self, tenant, slot):
        return self._call("move", tenant, (tenant, slot),
                          charge_pause=True, default=0.0)

    def set_io_throttle(self, tenant, bytes_per_s):
        return self._call("set_io_throttle", tenant, (tenant, bytes_per_s))

    def set_mps_quota(self, tenant, frac):
        return self._call("set_mps_quota", tenant, (tenant, frac))

    def pin_cpu_away_from_irq(self, tenant):
        return self._call("pin_cpu_away_from_irq", tenant, (tenant,))

    def free_slots(self):
        return self._call("free_slots", "", (), default=[])

    def headroom_units(self, device):
        return self._call("headroom_units", "", (device,), default=0)

    def migrate(self, tenant, replica_from, replica_to):
        return self._call("migrate", tenant,
                          (tenant, replica_from, replica_to),
                          charge_pause=True, default=0.0)
