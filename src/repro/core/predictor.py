"""Beyond-paper extension: proactive tail prediction.

The paper's controller is reactive — it waits for p99 > tau to persist Y
windows.  Its §5 notes "richer learning-based predictors could improve
stability at the cost of complexity".  This module adds the simplest
predictor that can act *before* the SLO is breached:

  * a short-horizon linear trend over the smoothed p99 stream
    (least-squares slope over the last W samples), and
  * a Kingman utilisation check (rho from observed rps x estimated mean
    service) that vetoes predictions when the system is clearly unloaded.

``predict(t)`` returns the extrapolated p99 at t + horizon; the controller
treats ``predicted > tau`` while ``current > guard * tau`` as an early
BREACH — all structural gates (dwell/cool-down/validation) still apply, so
the proactive path can only move actions *earlier*, never make them more
frequent than Algorithm 1 allows.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Tuple

import numpy as np

from repro.core.kingman import GG1


@dataclass(frozen=True)
class PredictorConfig:
    horizon_s: float = 15.0        # how far ahead to extrapolate
    window: int = 12               # trend-fit samples
    guard_frac: float = 0.6        # require current p99 > guard*tau to act
    min_slope: float = 1e-5        # s of p99 per s (ignore flat trends)
    rho_floor: float = 0.05        # skip predictions when nearly idle


class TailTrendPredictor:
    def __init__(self, cfg: PredictorConfig = PredictorConfig()):
        self.cfg = cfg
        self._hist: Deque[Tuple[float, float]] = deque(maxlen=cfg.window)

    def update(self, t: float, p99: float) -> None:
        self._hist.append((t, p99))

    def slope(self) -> float:
        if len(self._hist) < 4:
            return 0.0
        ts = np.array([t for t, _ in self._hist])
        ys = np.array([y for _, y in self._hist])
        ts = ts - ts.mean()
        denom = float(np.sum(ts * ts))
        if denom <= 0:
            return 0.0
        return float(np.sum(ts * (ys - ys.mean())) / denom)

    def predict(self, now: float) -> Optional[float]:
        """Extrapolated p99 at now + horizon (None if not enough data)."""
        if len(self._hist) < 4:
            return None
        slope = self.slope()
        if slope < self.cfg.min_slope:
            return None
        t_last, y_last = self._hist[-1]
        return y_last + slope * (now - t_last + self.cfg.horizon_s)

    def should_preact(self, now: float, current_p99: float, tau: float,
                      rps: float = 0.0,
                      mean_service_s: float = 0.0) -> bool:
        """True when the trend says tau will be crossed within the horizon."""
        if current_p99 <= self.cfg.guard_frac * tau:
            return False
        if rps > 0 and mean_service_s > 0:
            rho = GG1(arrival_rate=rps, mean_service=mean_service_s).rho
            if rho < self.cfg.rho_floor:
                return False
        pred = self.predict(now)
        return pred is not None and pred > tau
