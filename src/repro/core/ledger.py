"""DeviceLedger — the shared placement/budget source of truth (paper §2.3).

The paper's controller acts on an explicit model of the fabric: which MIG
slot every tenant-replica occupies, how many of each A100's 7 compute
units are spoken for, and how much sustained DMA demand each PCIe root
complex carries.  "In cases where no safe placement can be found for a new
tenant without violating the SLOs of existing tenants, an admission
control mechanism will queue or reject the new workload" (§2.3) — that
safety judgement, the placement scorer's candidate set, and the
reconfiguration optimizer's headroom all read the *same* bookkeeping.

Before this module, that bookkeeping was triplicated: ClusterSim rescanned
its replica lists, ServingActuator returned hard-coded constants, and the
AdmissionController took ad-hoc mappings.  DeviceLedger owns it once:

  * slot occupancy      — slot key -> owning tenant-replica,
  * per-GPU unit budget — device -> owner -> compute units (<= 7),
  * per-root demand     — root complex -> offered bytes/s per owner.

It is constructed from ``ClusterTopology`` + ``TenantRegistry.
resolve_placements()`` and mutated only through budget-checked operations
(`occupy` / `release` / `move` / `set_units`), so the invariants the
property suite asserts — no slot double-occupied, per-GPU use <= budget,
moves occupancy-conserving, release idempotent — hold by construction.
Both actuators (sim and serving) and the admission controller share one
instance; `view()` returns a canonical snapshot the sim<->serving parity
harness compares step-for-step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core.profiles import ProfileLattice, SliceProfile
from repro.core.topology import ClusterTopology, Slot


@dataclass
class LedgerEntry:
    """One tenant-replica's placement record."""
    tenant: str
    replica: int
    slot: Slot
    units: int                 # compute units pinned on slot.device
    demand: float = 0.0        # sustained bytes/s offered on slot's root
    role: str = "latency"

    @property
    def owner(self) -> str:
        return f"{self.tenant}/r{self.replica}"


class LedgerError(ValueError):
    """A budget-checked operation would violate a ledger invariant."""


class DeviceLedger:
    """Cluster-wide slot/unit/fabric bookkeeping, one instance per cluster.

    ``home_devices`` / ``ambient_units`` mirror the simulator's shared-
    cluster model: devices outside the modelled scenario carry ambient
    co-tenants whose units reduce *headroom* (decision-making) without
    being ledger entries (they are unmodelled, so they never move).  The
    hard budget check on mutations uses only real entries, exactly like
    the ComputeArbiter's accounting.
    """

    def __init__(self, topo: ClusterTopology, budget_per_gpu: int = 7,
                 home_devices: Sequence[str] = (), ambient_units: int = 0):
        self.topo = topo
        self.budget = budget_per_gpu
        self.home_devices = tuple(home_devices)
        self.ambient_units = ambient_units
        self._entries: Dict[str, LedgerEntry] = {}     # owner -> entry
        self._slot_owner: Dict[str, str] = {}          # slot key -> owner

    # ------------------------------------------------------------ builders
    @classmethod
    def from_registry(cls, topo: ClusterTopology, registry,
                      lattice: ProfileLattice,
                      placements: Optional[Mapping[str, List[Slot]]] = None,
                      *, budget_per_gpu: int = 7,
                      home_devices: Sequence[str] = (),
                      ambient_units: int = 0) -> "DeviceLedger":
        """Seed a ledger from a TenantRegistry's resolved placements.

        Latency tenants occupy ``profile`` units per replica and offer
        their mean DMA demand (rate x mean size, split across replicas);
        background tenants occupy ``spec.units`` and offer their
        ``pcie_demand``.
        """
        ledger = cls(topo, budget_per_gpu, home_devices, ambient_units)
        if placements is None:
            placements = registry.resolve_placements(topo)
        for spec in registry:
            slots = placements[spec.name]
            if spec.is_latency:
                units = ledger._profile_units(lattice, spec.profile)
                per_rep = spec.rate * spec.mean_size / max(1, len(slots))
                for i, s in enumerate(slots):
                    ledger.occupy(spec.name, s, units, replica=i,
                                  demand=per_rep, role=spec.role)
            else:
                for i, s in enumerate(slots):
                    ledger.occupy(spec.name, s, spec.units, replica=i,
                                  demand=spec.pcie_demand, role=spec.role)
        return ledger

    @staticmethod
    def _profile_units(lattice: ProfileLattice, name: str) -> int:
        try:
            return lattice[name].compute_units
        except KeyError:       # non-MIG lattice (e.g. TPU slices): 2nd rung
            return lattice.profiles[min(1, len(lattice) - 1)].compute_units

    # ----------------------------------------------------------- mutations
    def occupy(self, tenant: str, slot: Slot, units: int, *,
               replica: int = 0, demand: float = 0.0,
               role: str = "latency") -> LedgerEntry:
        """Claim a slot for one tenant-replica (budget- and slot-checked)."""
        owner = f"{tenant}/r{replica}"
        if owner in self._entries:
            raise LedgerError(f"{owner} already placed at "
                              f"{self._entries[owner].slot.key}")
        holder = self._slot_owner.get(slot.key)
        if holder is not None:
            raise LedgerError(f"slot {slot.key} already occupied by {holder}")
        if self.used_units(slot.device) + units > self.budget:
            raise LedgerError(
                f"placing {owner} ({units}u) oversubscribes {slot.device}: "
                f"{self.used_units(slot.device) + units}/{self.budget}")
        entry = LedgerEntry(tenant, replica, slot, units, demand, role)
        self._entries[owner] = entry
        self._slot_owner[slot.key] = owner
        return entry

    def release(self, tenant: str, replica: Optional[int] = None) -> int:
        """Free a tenant-replica's slot (all replicas when ``replica`` is
        None).  Idempotent: releasing an absent owner is a no-op.  Returns
        the number of entries released."""
        owners = [o for o, e in self._entries.items()
                  if e.tenant == tenant
                  and (replica is None or e.replica == replica)]
        for o in owners:
            entry = self._entries.pop(o)
            self._slot_owner.pop(entry.slot.key, None)
        return len(owners)

    def move(self, tenant: str, replica: int, slot: Slot) -> None:
        """Relocate one replica (occupancy-conserving, budget-checked on
        the destination device, destination slot must be free)."""
        owner = f"{tenant}/r{replica}"
        entry = self._entries.get(owner)
        if entry is None:
            raise LedgerError(f"{owner} is not placed")
        if slot.key == entry.slot.key:
            return
        holder = self._slot_owner.get(slot.key)
        if holder is not None:
            raise LedgerError(f"slot {slot.key} already occupied by {holder}")
        dst_used = sum(e.units for e in self._entries.values()
                       if e.slot.device == slot.device and e is not entry)
        if dst_used + entry.units > self.budget:
            raise LedgerError(
                f"moving {owner} ({entry.units}u) oversubscribes "
                f"{slot.device}: {dst_used + entry.units}/{self.budget}")
        del self._slot_owner[entry.slot.key]
        entry.slot = slot
        self._slot_owner[slot.key] = owner

    def set_units(self, tenant: str, units: int,
                  replica: Optional[int] = None) -> None:
        """Resize a tenant's slices (reconfigure/relax/rollback), budget-
        checked per device with replace semantics."""
        targets = [e for e in self._entries.values()
                   if e.tenant == tenant
                   and (replica is None or e.replica == replica)]
        if not targets:
            raise LedgerError(f"{tenant} is not placed")
        by_dev: Dict[str, int] = {}
        for e in targets:
            by_dev[e.slot.device] = by_dev.get(e.slot.device, 0) + 1
        for dev, n_here in by_dev.items():
            others = sum(e.units for e in self._entries.values()
                         if e.slot.device == dev and e not in targets)
            if others + units * n_here > self.budget:
                raise LedgerError(
                    f"resizing {tenant} to {units}u oversubscribes {dev}: "
                    f"{others + units * n_here}/{self.budget}")
        for e in targets:
            e.units = units

    def set_demand(self, tenant: str, demand: float,
                   replica: Optional[int] = None) -> None:
        for e in self._entries.values():
            if e.tenant == tenant and (replica is None
                                       or e.replica == replica):
                e.demand = demand

    # ------------------------------------------------------------- queries
    def entries(self) -> List[LedgerEntry]:
        return list(self._entries.values())

    def tenants(self) -> List[str]:
        return sorted({e.tenant for e in self._entries.values()})

    def owner_of(self, slot_key: str) -> Optional[str]:
        return self._slot_owner.get(slot_key)

    def slots_of(self, tenant: str) -> List[Slot]:
        return [e.slot for e in sorted(self._entries.values(),
                                       key=lambda e: e.replica)
                if e.tenant == tenant]

    def devices_of(self, tenant: str) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(
            e.slot.device for e in sorted(self._entries.values(),
                                          key=lambda e: e.replica)
            if e.tenant == tenant))

    def free_slots(self) -> List[Slot]:
        return [s for s in self.topo.slots()
                if s.key not in self._slot_owner]

    def used_units(self, device: str) -> int:
        """Units claimed by ledger entries on ``device`` (ambient excluded,
        like the arbiter's accounting)."""
        return sum(e.units for e in self._entries.values()
                   if e.slot.device == device)

    def headroom_units(self, device: str) -> int:
        """Free units available for decisions: budget minus entries minus
        the ambient co-tenants carried by non-home devices."""
        used = self.used_units(device)
        if device not in self.home_devices:
            used += self.ambient_units
        return max(0, self.budget - used)

    def root_demand(self, root: str) -> float:
        """Sustained offered bytes/s on a PCIe root complex."""
        return sum(e.demand for e in self._entries.values()
                   if self.topo.root_of(e.slot.device) == root)

    def latency_on_root(self, root: str) -> List[LedgerEntry]:
        return [e for e in self._entries.values()
                if e.role == "latency"
                and self.topo.root_of(e.slot.device) == root]

    # ---------------------------------------------------------- invariants
    def check(self) -> None:
        """Raise LedgerError if any invariant is violated (the property
        suite calls this after every random operation)."""
        seen: Dict[str, str] = {}
        for owner, e in self._entries.items():
            if owner != e.owner:
                raise LedgerError(f"owner index mismatch: {owner}")
            if e.slot.key in seen:
                raise LedgerError(f"slot {e.slot.key} double-occupied by "
                                  f"{seen[e.slot.key]} and {owner}")
            seen[e.slot.key] = owner
            if self._slot_owner.get(e.slot.key) != owner:
                raise LedgerError(f"slot index out of sync at {e.slot.key}")
        for key in self._slot_owner:
            if key not in seen:
                raise LedgerError(f"dangling slot index entry {key}")
        for dev in {e.slot.device for e in self._entries.values()}:
            if self.used_units(dev) > self.budget:
                raise LedgerError(f"{dev} oversubscribed: "
                                  f"{self.used_units(dev)}/{self.budget}")

    def check_ok(self) -> bool:
        try:
            self.check()
        except LedgerError:
            return False
        return True

    def view(self) -> Dict[str, Dict]:
        """Canonical comparable snapshot for the sim<->serving parity
        harness: occupancy, per-device unit use + headroom, root demand."""
        devices = self.topo.devices()
        return {
            "occupancy": dict(sorted(self._slot_owner.items())),
            "units": {d: self.used_units(d) for d in devices},
            "headroom": {d: self.headroom_units(d) for d in devices},
            "root_demand": {r: round(self.root_demand(r), 3)
                            for r in self.topo.roots()},
        }
