"""Controller input signals (paper §2.1): per-tenant tails + system-level
counters, EMA-smoothed with hysteresis."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.serving.metrics import EMA


@dataclass
class TenantSignals:
    p95: float = 0.0
    p99: float = 0.0
    p999: float = 0.0
    miss_rate: float = 0.0
    rps: float = 0.0
    ttft_p99: Optional[float] = None      # LLM serving (autoregressive)


@dataclass
class SystemSignals:
    pcie_bytes: Dict[str, float] = field(default_factory=dict)   # per root
    host_io: Dict[str, float] = field(default_factory=dict)      # per numa
    sm_util: Dict[str, float] = field(default_factory=dict)      # per device
    mem_bw: Dict[str, float] = field(default_factory=dict)       # per device
    irq_rate: Dict[str, float] = field(default_factory=dict)     # per host
    nic_bytes: Dict[str, float] = field(default_factory=dict)    # per host


@dataclass
class Snapshot:
    time: float
    tenants: Dict[str, TenantSignals]
    system: SystemSignals


class SignalSmoother:
    """EMA + hysteresis per signal key (paper: "signals are smoothed with
    exponential moving averages and hysteresis")."""

    def __init__(self, alpha: float = 0.3, hysteresis: float = 0.05):
        self.alpha = alpha
        self.hysteresis = hysteresis
        self._emas: Dict[str, EMA] = {}

    def _ema(self, key: str) -> EMA:
        if key not in self._emas:
            self._emas[key] = EMA(alpha=self.alpha,
                                  hysteresis=self.hysteresis)
        return self._emas[key]

    def smooth(self, snap: Snapshot) -> Snapshot:
        tenants = {}
        for name, t in snap.tenants.items():
            tenants[name] = TenantSignals(
                p95=self._ema(f"{name}.p95").update(t.p95),
                p99=self._ema(f"{name}.p99").update(t.p99),
                p999=self._ema(f"{name}.p999").update(t.p999),
                miss_rate=self._ema(f"{name}.miss").update(t.miss_rate),
                rps=self._ema(f"{name}.rps").update(t.rps),
                ttft_p99=(self._ema(f"{name}.ttft").update(t.ttft_p99)
                          if t.ttft_p99 is not None else None),
            )
        sys_out = SystemSignals()
        for attr in ("pcie_bytes", "host_io", "sm_util", "mem_bw",
                     "irq_rate", "nic_bytes"):
            src = getattr(snap.system, attr)
            dst = getattr(sys_out, attr)
            for k, v in src.items():
                dst[k] = self._ema(f"sys.{attr}.{k}").update(v)
        return Snapshot(time=snap.time, tenants=tenants, system=sys_out)
