"""Kingman's G/G/1 heavy-traffic approximation (paper §2.5.1).

    E[W_q] ~= rho/(1-rho) * (c_a^2 + c_s^2)/2 * E[S],   rho = lambda E[S]

Used qualitatively: the controller's diagnosis ranks candidate actions by
how much they reduce rho (via E[S]) for the latency-sensitive tenant; the
evaluation reports empirical p99/p999 (the paper avoids positing a
parametric tail form).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class GG1:
    arrival_rate: float     # lambda (1/s)
    mean_service: float     # E[S] (s)
    ca2: float = 1.0        # squared coeff. of variation of inter-arrivals
    cs2: float = 1.0        # squared coeff. of variation of service times

    @property
    def rho(self) -> float:
        return self.arrival_rate * self.mean_service

    def mean_wait(self) -> float:
        rho = self.rho
        if rho >= 1.0:
            return math.inf
        return rho / (1 - rho) * (self.ca2 + self.cs2) / 2 * self.mean_service

    def mean_sojourn(self) -> float:
        return self.mean_wait() + self.mean_service

    def tail_inflation(self) -> float:
        """Dimensionless saturation signal: how much queueing inflates the
        mean sojourn over the bare service time.  -> inf as rho -> 1,
        matching the paper's "saturation inflates tails" guidance."""
        if self.mean_service <= 0:
            return 0.0
        return self.mean_sojourn() / self.mean_service


def service_rate_needed(arrival_rate: float, target_rho: float = 0.7
                        ) -> float:
    """Capacity planning helper: mu such that rho == target at lambda."""
    return arrival_rate / target_rho
