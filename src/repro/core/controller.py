"""The multi-tenancy controller (paper §2, Algorithm 1, Figure 1).

Integrates: signal smoothing -> decision FSM (dwell/cool-down/persistence)
-> tiered decision space (guardrails -> PCIe-aware placement -> dynamic
MIG/slice reconfiguration) -> execution via an Actuator -> post-change
validation with rollback to last-known-good.

The Actuator abstracts the execution backend: the discrete-event cluster
simulator (faithful reproduction) and the JAX serving stack (engine quotas,
pipeline throttles, slice re-lowering) implement the same protocol.

Ablation flags (enable_mig / enable_placement / enable_guardrails)
reproduce the paper's E2 configurations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Tuple

from repro.core.audit import AuditLog, Decision, TenantConfig
from repro.core.guardrails import GuardrailBounds, GuardrailManager
from repro.core.placement import (PlacementWeights, intra_device_first,
                                  placement_score)
from repro.core.predictor import PredictorConfig, TailTrendPredictor
from repro.core.policy import DecisionFSM, PolicyConfig, Trigger
from repro.core.profiles import ProfileLattice, SliceProfile
from repro.core.optimizer import greedy_upgrade, relax_step
from repro.core.signals import SignalSmoother, Snapshot
from repro.core.topology import ClusterTopology, Slot


class Actuator(Protocol):
    def reconfigure(self, tenant: str, profile: SliceProfile) -> float: ...
    def move(self, tenant: str, slot: Slot) -> float: ...
    def set_io_throttle(self, tenant: str, bytes_per_s: Optional[float]) -> None: ...
    def set_mps_quota(self, tenant: str, frac: float) -> None: ...
    def pin_cpu_away_from_irq(self, tenant: str) -> None: ...
    def free_slots(self) -> List[Slot]: ...
    def headroom_units(self, device: str) -> int: ...


@dataclass(frozen=True)
class ControllerConfig:
    policy: PolicyConfig = field(default_factory=PolicyConfig)
    bounds: GuardrailBounds = field(default_factory=GuardrailBounds)
    weights: PlacementWeights = field(default_factory=PlacementWeights)
    enable_mig: bool = True
    enable_placement: bool = True
    enable_guardrails: bool = True
    placement_improvement: float = 0.25   # min score delta to justify a move
    relax_score_threshold: float = 0.5    # §2.2.1: conservative threshold
    pcie_busy_frac: float = 0.35          # root "hot" above this utilisation
    io_busy_bytes: float = 0.8e9
    fabric_capacity: float = 25e9
    ema_alpha: float = 0.35
    ema_hysteresis: float = 0.02
    # beyond-paper: proactive trend-predictive triggering (paper §5's
    # "richer predictors" future work); structural gates still apply
    proactive: bool = False
    predictor: PredictorConfig = field(default_factory=PredictorConfig)


@dataclass
class TenantState:
    role: str                  # "latency" | "background"
    slot: Slot
    profile: SliceProfile
    config: TenantConfig
    throttle_level: int = 0    # escalation counter for repeated throttles


class Controller:
    def __init__(self, topo: ClusterTopology, lattice: ProfileLattice,
                 actuator: Actuator, cfg: ControllerConfig = ControllerConfig(),
                 primary: str = "T1"):
        self.topo = topo
        self.lattice = lattice
        self.actuator = actuator
        self.cfg = cfg
        self.primary = primary
        self.fsm = DecisionFSM(cfg.policy)
        self.smoother = SignalSmoother(cfg.ema_alpha, cfg.ema_hysteresis)
        self.guardrails = GuardrailManager(cfg.bounds)
        self.audit = AuditLog()
        self.tenants: Dict[str, TenantState] = {}
        self._baseline_rps = 0.0
        self._last_throttle_time = -1e9
        self.throttle_grace_s = 10.0
        self.cpu_overhead_s = 0.0          # controller's own cost (Table 4)
        self.predictor = TailTrendPredictor(cfg.predictor) \
            if cfg.proactive else None

    # -------------------------------------------------------------- set-up
    def register_tenant(self, name: str, role: str, slot: Slot,
                        profile: SliceProfile) -> None:
        cfg = TenantConfig(profile=profile.name, device=slot.device,
                           slot=slot.index)
        self.tenants[name] = TenantState(role, slot, profile, cfg)
        if role == "latency":
            self.audit.mark_good(name, cfg)

    # ------------------------------------------------------------- helpers
    def _summary(self, snap: Snapshot) -> Dict[str, float]:
        t = snap.tenants.get(self.primary)
        root = self.topo.root_of(self.tenants[self.primary].slot.device)
        return {
            "p99": t.p99 if t else 0.0,
            "miss": t.miss_rate if t else 0.0,
            "pcie_root": snap.system.pcie_bytes.get(root, 0.0),
        }

    def _offenders(self) -> Tuple[Optional[str], Optional[str]]:
        """(bandwidth offender on primary's root, compute offender on
        primary's device)."""
        prim = self.tenants[self.primary]
        same_root = [
            (name, st) for name, st in self.tenants.items()
            if st.role == "background"
            and self.topo.same_root(st.slot.device, prim.slot.device)]
        comp = next((n for n, st in same_root
                     if st.slot.device == prim.slot.device), None)
        # bandwidth offender: prefer the sibling-device tenant (the
        # ETL/bandwidth class) over a same-device compute tenant
        bw = next((n for n, st in same_root
                   if st.slot.device != prim.slot.device),
                  same_root[0][0] if same_root else None)
        return bw, comp

    def _diagnose(self, snap: Snapshot) -> str:
        """Root-cause: "pcie_io" vs "compute_mem" (paper §2.3)."""
        prim = self.tenants[self.primary]
        root = self.topo.root_of(prim.slot.device)
        numa = self.topo.numa_of(prim.slot.device)
        pcie = snap.system.pcie_bytes.get(root, 0.0)
        io = snap.system.host_io.get(numa, 0.0)
        if pcie > self.cfg.pcie_busy_frac * self.cfg.fabric_capacity or \
                io > self.cfg.io_busy_bytes:
            return "pcie_io"
        return "compute_mem"

    # ---------------------------------------------------------------- loop
    def on_snapshot(self, raw: Snapshot) -> List[Decision]:
        decisions: List[Decision] = []
        snap = self.smoother.smooth(raw)
        now = snap.time
        self.guardrails.tick(self.actuator, now)

        prim_name = self.primary
        prim = self.tenants[prim_name]
        tsig = snap.tenants.get(prim_name)
        if tsig is None:
            return decisions
        p99 = tsig.p99

        # throughput budget bookkeeping (T_i >= 0.95 T_base)
        self._baseline_rps = max(self._baseline_rps, tsig.rps)
        throughput_ok = (self._baseline_rps <= 0 or
                         tsig.rps >= self.cfg.policy.throughput_budget *
                         self._baseline_rps)

        # -------- post-change validation / rollback (paper §2.4)
        verdict = self.fsm.validation_result(p99)
        if verdict is True:
            self.audit.mark_good(prim_name, prim.config)
            self.audit.set_validation(True)
        elif verdict is False:
            self.audit.set_validation(False)
            decisions.append(self._rollback(prim_name, snap))

        trig = self.fsm.observe(p99, throughput_ok)
        if trig == Trigger.NONE and self.predictor is not None \
                and self.fsm.phase.value == "monitor":
            # proactive path: act on the predicted breach, same gates
            self.predictor.update(now, p99)
            if self.predictor.should_preact(now, p99,
                                            self.cfg.policy.tau_s,
                                            rps=tsig.rps):
                trig = Trigger.BREACH
        elif self.predictor is not None:
            self.predictor.update(now, p99)
        if trig == Trigger.BREACH:
            decisions.extend(self._mitigate(snap, p99))
        elif trig == Trigger.STABLE:
            d = self._relax(snap, p99)
            if d is not None:
                decisions.append(d)
        return decisions

    # ------------------------------------------------------------- actions
    def _mitigate(self, snap: Snapshot, p99: float) -> List[Decision]:
        out: List[Decision] = []
        now = snap.time
        cause = self._diagnose(snap)
        bw_off, comp_off = self._offenders()

        # Tier 1 — guardrails: throttle the offending background tenant for
        # a bounded window Z when PCIe/IO pressure is the diagnosis.
        # Lightweight: not dwell-gated (only structural actions are).
        # Escalation memory (§2.3: "if throttling does not resolve the
        # issue, the controller proceeds to upgrade the tenant's
        # isolation"): once throttling has been tried repeatedly while a
        # structural lever exists, go structural instead.
        structural_available = self.cfg.enable_mig or self.cfg.enable_placement
        throttle_exhausted = (structural_available and bw_off is not None and
                              self.tenants[bw_off].throttle_level >= 3)
        if (self.cfg.enable_guardrails and cause == "pcie_io"
                and bw_off is not None and not throttle_exhausted
                and not self.guardrails.is_throttled(bw_off)
                and not self.guardrails.in_refractory(bw_off, now)):
            st = self.tenants[bw_off]
            lo, hi = self.cfg.bounds.io_throttle
            value = hi if st.throttle_level % 2 == 0 else lo
            st.throttle_level += 1
            self._last_throttle_time = now
            applied = self.guardrails.throttle_io(self.actuator, bw_off,
                                                  value, now)
            out.append(self.audit.record(Decision(
                now, "throttle_io", bw_off, {"bytes_per_s": applied},
                self._summary(snap))))
            return out

        # Structural tiers are gated by Algorithm 1's dwell/cool-down and a
        # grace period after a throttle (give the guardrail time to work).
        if not self.fsm.at_reconfig_boundary() or self.fsm.is_cooling_down():
            return out
        if (self.cfg.enable_guardrails and bw_off is not None
                and self.guardrails.is_throttled(bw_off)
                and now - self._last_throttle_time < self.throttle_grace_s):
            return out

        # Tier 2/3 — upgrade isolation (placement move first, then slice
        # enlargement; paper §2.2.1 ordering), plus CPU pinning and a
        # stricter MPS quota on the compute offender.
        prim = self.tenants[self.primary]
        before = prim.config.copy()

        if self.cfg.enable_placement:
            free = self.actuator.free_slots()
            ranked = intra_device_first(self.topo, prim.slot, free, snap,
                                        self.cfg.weights)
            cur_score = placement_score(self.topo, prim.slot, snap,
                                        self.cfg.weights)
            if ranked and ranked[0][1] < cur_score - \
                    self.cfg.placement_improvement:
                slot = ranked[0][0]
                pause = self.actuator.move(self.primary, slot)
                prim.slot = slot
                prim.config.device, prim.config.slot = slot.device, slot.index
                self.fsm.action_taken(p99)
                out.append(self.audit.record(Decision(
                    now, "move", self.primary,
                    {"to": slot.key, "score": ranked[0][1],
                     "from_score": cur_score, "pause_s": pause},
                    self._summary(snap), before.__dict__,
                    prim.config.copy().__dict__)))
                self._side_effects(out, snap, comp_off)
                return out

        if self.cfg.enable_mig:
            headroom = self.actuator.headroom_units(prim.slot.device)
            target = greedy_upgrade(self.lattice, prim.profile, headroom)
            if target is not None:
                pause = self.actuator.reconfigure(self.primary, target)
                prim.profile = target
                prim.config.profile = target.name
                self.fsm.action_taken(p99)
                out.append(self.audit.record(Decision(
                    now, "reconfigure", self.primary,
                    {"profile": target.name, "pause_s": pause},
                    self._summary(snap), before.__dict__,
                    prim.config.copy().__dict__)))
                self._side_effects(out, snap, comp_off)
                return out

        # last resort when structural levers are disabled/exhausted:
        # guardrail the compute offender
        if self.cfg.enable_guardrails and comp_off is not None:
            st = self.tenants[comp_off]
            new_q = max(self.cfg.bounds.mps_quota[0],
                        st.config.mps_quota - 0.25)
            if new_q < st.config.mps_quota:
                applied = self.guardrails.set_mps_quota(self.actuator,
                                                        comp_off, new_q)
                st.config.mps_quota = applied
                self.fsm.action_taken(p99)
                out.append(self.audit.record(Decision(
                    now, "mps", comp_off, {"quota": applied},
                    self._summary(snap))))
        return out

    def _side_effects(self, out: List[Decision], snap: Snapshot,
                      comp_off: Optional[str]) -> None:
        """Pin CPU away from IRQ-hot cores + stricter MPS quota (§2.3)."""
        now = snap.time
        prim = self.tenants[self.primary]
        if not prim.config.cpu_pinned_away_from_irq:
            self.actuator.pin_cpu_away_from_irq(self.primary)
            prim.config.cpu_pinned_away_from_irq = True
            out.append(self.audit.record(Decision(
                now, "pin_cpu", self.primary, {}, self._summary(snap))))
        if self.cfg.enable_guardrails and comp_off is not None:
            st = self.tenants[comp_off]
            new_q = max(self.cfg.bounds.mps_quota[0],
                        st.config.mps_quota - 0.25)
            if new_q < st.config.mps_quota:
                applied = self.guardrails.set_mps_quota(self.actuator,
                                                        comp_off, new_q)
                st.config.mps_quota = applied
                out.append(self.audit.record(Decision(
                    now, "mps", comp_off, {"quota": applied},
                    self._summary(snap))))

    def _relax(self, snap: Snapshot, p99: float) -> Optional[Decision]:
        """Relax isolation when stable (smaller profile whose placement
        score remains below a conservative threshold, §2.2.1)."""
        if not self.cfg.enable_mig:
            return None
        if not self.fsm.at_reconfig_boundary() or self.fsm.is_cooling_down():
            return None
        prim = self.tenants[self.primary]
        smaller = relax_step(self.lattice, prim.profile)
        if smaller is None:
            return None
        score = placement_score(self.topo, prim.slot, snap, self.cfg.weights)
        if score > self.cfg.relax_score_threshold:
            return None
        before = prim.config.copy()
        pause = self.actuator.reconfigure(self.primary, smaller)
        prim.profile = smaller
        prim.config.profile = smaller.name
        self.fsm.action_taken(p99)
        return self.audit.record(Decision(
            snap.time, "relax", self.primary,
            {"profile": smaller.name, "pause_s": pause},
            self._summary(snap), before.__dict__,
            prim.config.copy().__dict__))

    def _rollback(self, tenant: str, snap: Snapshot) -> Decision:
        prim = self.tenants[tenant]
        good = self.audit.last_known_good(tenant)
        before = prim.config.copy()
        pause = 0.0
        if good is not None:
            if good.profile != prim.config.profile:
                profile = self.lattice[good.profile]
                pause += self.actuator.reconfigure(tenant, profile)
                prim.profile = profile
            if (good.device, good.slot) != (prim.config.device,
                                            prim.config.slot):
                slot = Slot(self.topo.host_of(good.device), good.device,
                            good.slot)
                pause += self.actuator.move(tenant, slot)
                prim.slot = slot
            prim.config = good.copy()
        return self.audit.record(Decision(
            snap.time, "rollback", tenant, {"pause_s": pause},
            self._summary(snap), before.__dict__, prim.config.copy().__dict__))
