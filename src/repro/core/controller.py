"""The multi-tenancy controller (paper §2, Algorithm 1, Figure 1),
generalized to N latency-sensitive tenant lanes.

Integrates: signal smoothing -> per-tenant decision FSMs (dwell/cool-down/
persistence) -> tiered decision space (guardrails -> PCIe-aware placement
-> dynamic MIG/slice reconfiguration) -> execution via an Actuator ->
post-change validation with rollback to last-known-good.

Tenant identity is data, not code: each registered latency tenant gets its
own decision lane (FSM, predictor, throughput baseline, SLO threshold),
while a shared ComputeArbiter resolves conflicting isolation upgrades
under a cluster-wide per-GPU compute-unit budget — priority-weighted,
highest miss-rate first (the multi-SLO-tenant regime of MIG-serving /
ParvaGPU).  With exactly one latency tenant the control law reduces to
the paper's single-T1 loop.

The Actuator abstracts the execution backend: the discrete-event cluster
simulator (faithful reproduction) and the JAX serving stack (engine quotas,
pipeline throttles, slice re-lowering) implement the same protocol.

Ablation flags (enable_mig / enable_placement / enable_guardrails)
reproduce the paper's E2 configurations.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Protocol, Tuple

from repro.core.audit import AuditLog, Decision, TenantConfig
from repro.core.guardrails import GuardrailBounds, GuardrailManager
from repro.core.placement import (PlacementWeights, intra_device_first,
                                  placement_score)
from repro.core.predictor import PredictorConfig, TailTrendPredictor
from repro.core.policy import DecisionFSM, PolicyConfig, Trigger
from repro.core.profiles import ProfileLattice, SliceProfile
from repro.core.optimizer import greedy_upgrade, relax_step
from repro.core.signals import SignalSmoother, Snapshot, TenantSignals
from repro.core.tenancy import (ComputeArbiter, UpgradeRequest,
                                lane_weight)
from repro.core.topology import ClusterTopology, Slot


class Actuator(Protocol):
    def reconfigure(self, tenant: str, profile: SliceProfile) -> float: ...
    def move(self, tenant: str, slot: Slot) -> float: ...
    def set_io_throttle(self, tenant: str, bytes_per_s: Optional[float]) -> None: ...
    def set_mps_quota(self, tenant: str, frac: float) -> None: ...
    def pin_cpu_away_from_irq(self, tenant: str) -> None: ...
    def free_slots(self) -> List[Slot]: ...
    def headroom_units(self, device: str) -> int: ...
    def migrate(self, tenant: str, replica_from: int,
                replica_to: int) -> float: ...


@dataclass(frozen=True)
class ControllerConfig:
    policy: PolicyConfig = field(default_factory=PolicyConfig)
    bounds: GuardrailBounds = field(default_factory=GuardrailBounds)
    weights: PlacementWeights = field(default_factory=PlacementWeights)
    enable_mig: bool = True
    enable_placement: bool = True
    enable_guardrails: bool = True
    placement_improvement: float = 0.25   # min score delta to justify a move
    relax_score_threshold: float = 0.5    # §2.2.1: conservative threshold
    pcie_busy_frac: float = 0.35          # root "hot" above this utilisation
    io_busy_bytes: float = 0.8e9
    fabric_capacity: float = 25e9
    ema_alpha: float = 0.35
    ema_hysteresis: float = 0.02
    units_per_gpu: int = 7                # arbiter budget per device
    # beyond-paper: proactive trend-predictive triggering (paper §5's
    # "richer predictors" future work); structural gates still apply
    proactive: bool = False
    predictor: PredictorConfig = field(default_factory=PredictorConfig)


@dataclass
class TenantState:
    role: str                  # "latency" | "background"
    slot: Slot                 # primary replica's slot
    profile: SliceProfile
    config: TenantConfig
    throttle_level: int = 0    # escalation counter for repeated throttles
    priority: float = 1.0
    slo_s: Optional[float] = None
    replicas: List[Slot] = field(default_factory=list)

    @property
    def devices(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(s.device for s in self.replicas))


class Controller:
    def __init__(self, topo: ClusterTopology, lattice: ProfileLattice,
                 actuator: Actuator, cfg: ControllerConfig = ControllerConfig(),
                 primary: Optional[str] = None, tracer=None):
        self.topo = topo
        self.lattice = lattice
        self.actuator = actuator
        self.cfg = cfg
        # core.obs.Tracer (or None): every audited Decision also lands as
        # an instant on the shared "controller" track, so request
        # timelines can be correlated with the control loop's choices
        # (the actuator separately traces the actions it executes)
        self.tracer = tracer
        self._primary = primary            # None: first registered latency
        self.fsms: Dict[str, DecisionFSM] = {}
        self.smoother = SignalSmoother(cfg.ema_alpha, cfg.ema_hysteresis)
        self.guardrails = GuardrailManager(cfg.bounds)
        self.audit = AuditLog()
        self.arbiter = ComputeArbiter(lattice, cfg.units_per_gpu)
        self.tenants: Dict[str, TenantState] = {}
        self._baseline_rps: Dict[str, float] = {}
        self._last_throttle_time: Dict[str, float] = {}
        self.throttle_grace_s = 10.0
        self.cpu_overhead_s = 0.0          # controller's own cost (Table 4)
        self.predictors: Dict[str, TailTrendPredictor] = {}

    # ------------------------------------------------------------ identity
    @property
    def primary(self) -> Optional[str]:
        if self._primary is not None:
            return self._primary
        for name, st in self.tenants.items():
            if st.role == "latency":
                return name
        return None

    @property
    def fsm(self) -> Optional[DecisionFSM]:
        """Primary lane's FSM (single-tenant back-compat)."""
        p = self.primary
        return self.fsms.get(p) if p else None

    def fsm_for(self, tenant: str) -> Optional[DecisionFSM]:
        """One tenant's dwell/cooldown FSM (None if unregistered).  The
        RetryingActuator binds this so its retry cycles respect the same
        hold-still windows the control law does (a cooling-down lane is
        never thrashed by actuator retries)."""
        return self.fsms.get(tenant)

    def latency_tenants(self) -> List[str]:
        return [n for n, st in self.tenants.items() if st.role == "latency"]

    # -------------------------------------------------------------- set-up
    def register_tenant(self, name: str, role: str, slot: Slot,
                        profile: SliceProfile, *, priority: float = 1.0,
                        slo_s: Optional[float] = None,
                        replicas: Optional[List[Slot]] = None) -> None:
        cfg = TenantConfig(profile=profile.name, device=slot.device,
                           slot=slot.index)
        reps = list(replicas) if replicas else [slot]
        self.tenants[name] = TenantState(role, reps[0], profile, cfg,
                                         priority=priority, slo_s=slo_s,
                                         replicas=reps)
        if role == "latency":
            # Per-lane tail threshold: the tenant's SLO, unless the
            # operator explicitly overrode the policy's tau (e.g. the E3
            # sensitivity sweep or a TTFT-domain controller) — an explicit
            # tau applies to every lane.
            policy = self.cfg.policy
            if slo_s is not None and policy.tau_s == PolicyConfig().tau_s:
                policy = replace(policy, tau_s=slo_s)
            self.fsms[name] = DecisionFSM(policy)
            if self.cfg.proactive:
                self.predictors[name] = TailTrendPredictor(self.cfg.predictor)
            for i, s in enumerate(reps):
                self.arbiter.occupy(name, s.device, profile.compute_units,
                                    replica=i)
            self.audit.mark_good(name, cfg)

    def register_registry(self, registry, placements=None) -> None:
        """Register every tenant from a TenantRegistry.  ``placements``
        maps name -> [Slot]; resolved from the registry if omitted."""
        if placements is None:
            placements = registry.resolve_placements(self.topo)
        for spec in registry:
            slots = placements[spec.name]
            self.register_tenant(
                spec.name, spec.role, slots[0], self.lattice[spec.profile],
                priority=spec.priority,
                slo_s=spec.slo_s if spec.is_latency else None,
                replicas=slots)

    # ------------------------------------------------------------- helpers
    def _record(self, decision: Decision) -> Decision:
        """Audit-log a decision and mirror it onto the trace timeline."""
        self.audit.record(decision)
        if self.tracer is not None:
            self.tracer.instant(
                f"decision:{decision.action}", decision.time,
                track="controller", lane=decision.tenant, **decision.args)
        return decision

    def _tau(self, name: str) -> float:
        fsm = self.fsms.get(name)
        return fsm.cfg.tau_s if fsm is not None else self.cfg.policy.tau_s

    def _summary(self, name: str, snap: Snapshot) -> Dict[str, float]:
        t = snap.tenants.get(name)
        root = self.topo.root_of(self.tenants[name].slot.device)
        return {
            "p99": t.p99 if t else 0.0,
            "miss": t.miss_rate if t else 0.0,
            "pcie_root": snap.system.pcie_bytes.get(root, 0.0),
        }

    def _offenders(self, name: str) -> Tuple[Optional[str], Optional[str]]:
        """(bandwidth offender on the tenant's root, compute offender on
        the tenant's device)."""
        prim = self.tenants[name]
        same_root = [
            (n, st) for n, st in self.tenants.items()
            if st.role == "background"
            and self.topo.same_root(st.slot.device, prim.slot.device)]
        comp = next((n for n, st in same_root
                     if st.slot.device == prim.slot.device), None)
        # bandwidth offender: prefer the sibling-device tenant (the
        # ETL/bandwidth class) over a same-device compute tenant
        bw = next((n for n, st in same_root
                   if st.slot.device != prim.slot.device),
                  same_root[0][0] if same_root else None)
        return bw, comp

    def _diagnose(self, name: str, snap: Snapshot) -> str:
        """Root-cause: "pcie_io" vs "compute_mem" (paper §2.3)."""
        prim = self.tenants[name]
        root = self.topo.root_of(prim.slot.device)
        numa = self.topo.numa_of(prim.slot.device)
        pcie = snap.system.pcie_bytes.get(root, 0.0)
        io = snap.system.host_io.get(numa, 0.0)
        if pcie > self.cfg.pcie_busy_frac * self.cfg.fabric_capacity or \
                io > self.cfg.io_busy_bytes:
            return "pcie_io"
        return "compute_mem"

    # ---------------------------------------------------------------- loop
    def on_snapshot(self, raw: Snapshot) -> List[Decision]:
        decisions: List[Decision] = []
        snap = self.smoother.smooth(raw)
        now = snap.time
        self.guardrails.tick(self.actuator, now)

        lanes = [(n, self.tenants[n]) for n in self.latency_tenants()
                 if n in snap.tenants]
        # -------- phase 1: per-lane validation verdicts + gated triggers
        triggered: List[Tuple[str, Trigger, TenantSignals]] = []
        for name, st in lanes:
            tsig = snap.tenants[name]
            fsm = self.fsms[name]
            p99 = tsig.p99

            # throughput budget bookkeeping (T_i >= 0.95 T_base)
            base = max(self._baseline_rps.get(name, 0.0), tsig.rps)
            self._baseline_rps[name] = base
            throughput_ok = (base <= 0 or tsig.rps >=
                             self.cfg.policy.throughput_budget * base)

            # -------- post-change validation / rollback (paper §2.4)
            verdict = fsm.validation_result(p99)
            if verdict is True:
                self.audit.mark_good(name, st.config)
                self.audit.set_validation(True, name)
            elif verdict is False:
                self.audit.set_validation(False, name)
                decisions.append(self._rollback(name, snap))

            trig = fsm.observe(p99, throughput_ok)
            predictor = self.predictors.get(name)
            if trig == Trigger.NONE and predictor is not None \
                    and fsm.phase.value == "monitor":
                # proactive path: act on the predicted breach, same gates
                predictor.update(now, p99)
                if predictor.should_preact(now, p99, self._tau(name),
                                           rps=tsig.rps):
                    trig = Trigger.BREACH
            elif predictor is not None:
                predictor.update(now, p99)
            if trig != Trigger.NONE:
                triggered.append((name, trig, tsig))

        # -------- phase 2: arbitration order across competing lanes
        # (priority-weighted, highest miss-rate first — the shared arbiter
        # then enforces the per-GPU unit budget on each structural grant)
        breaching = [(n, t) for n, trig, t in triggered
                     if trig == Trigger.BREACH]
        breaching.sort(key=lambda nt: (
            -lane_weight(self.tenants[nt[0]].priority, nt[1].miss_rate),
            nt[0]))
        for name, tsig in breaching:
            decisions.extend(self._mitigate(name, snap, tsig.p99))
        for name, trig, tsig in triggered:
            if trig == Trigger.STABLE:
                d = self._relax(name, snap, tsig.p99)
                if d is not None:
                    decisions.append(d)
        return decisions

    # ------------------------------------------------------------- actions
    def _mitigate(self, name: str, snap: Snapshot, p99: float
                  ) -> List[Decision]:
        out: List[Decision] = []
        now = snap.time
        fsm = self.fsms[name]
        cause = self._diagnose(name, snap)
        bw_off, comp_off = self._offenders(name)

        # Tier 1 — guardrails: throttle the offending background tenant for
        # a bounded window Z when PCIe/IO pressure is the diagnosis.
        # Lightweight: not dwell-gated (only structural actions are).
        # Escalation memory (§2.3: "if throttling does not resolve the
        # issue, the controller proceeds to upgrade the tenant's
        # isolation"): once throttling has been tried repeatedly while a
        # structural lever exists, go structural instead.
        structural_available = self.cfg.enable_mig or self.cfg.enable_placement
        throttle_exhausted = (structural_available and bw_off is not None and
                              self.tenants[bw_off].throttle_level >= 3)
        if (self.cfg.enable_guardrails and cause == "pcie_io"
                and bw_off is not None and not throttle_exhausted
                and not self.guardrails.is_throttled(bw_off)
                and not self.guardrails.in_refractory(bw_off, now)):
            st = self.tenants[bw_off]
            lo, hi = self.cfg.bounds.io_throttle
            value = hi if st.throttle_level % 2 == 0 else lo
            st.throttle_level += 1
            self._last_throttle_time[name] = now
            applied = self.guardrails.throttle_io(self.actuator, bw_off,
                                                  value, now)
            out.append(self._record(Decision(
                now, "throttle_io", bw_off, {"bytes_per_s": applied,
                                             "for": name},
                self._summary(name, snap))))
            return out

        # Structural tiers are gated by Algorithm 1's dwell/cool-down and a
        # grace period after a throttle (give the guardrail time to work).
        if not fsm.at_reconfig_boundary() or fsm.is_cooling_down():
            return out
        if (self.cfg.enable_guardrails and bw_off is not None
                and self.guardrails.is_throttled(bw_off)
                and now - self._last_throttle_time.get(name, -1e9)
                < self.throttle_grace_s):
            return out

        # Tier 2/3 — upgrade isolation (placement move first, then slice
        # enlargement; paper §2.2.1 ordering), plus CPU pinning and a
        # stricter MPS quota on the compute offender.
        prim = self.tenants[name]
        before = prim.config.copy()

        if self.cfg.enable_placement:
            need = prim.profile.compute_units
            free = [
                s for s in self.actuator.free_slots()
                # a move carries the tenant's current slice: the target
                # device must have unit headroom for it (intra-device
                # moves keep the same units and are always feasible)
                if s.device == prim.slot.device
                or min(self.actuator.headroom_units(s.device),
                       self.arbiter.headroom(s.device)) >= need]
            ranked = intra_device_first(self.topo, prim.slot, free, snap,
                                        self.cfg.weights)
            cur_score = placement_score(self.topo, prim.slot, snap,
                                        self.cfg.weights)
            if ranked and ranked[0][1] < cur_score - \
                    self.cfg.placement_improvement:
                slot = ranked[0][0]
                old_device = prim.slot.device
                pause = self.actuator.move(name, slot)
                prim.slot = slot
                prim.replicas[0] = slot
                prim.config.device, prim.config.slot = slot.device, slot.index
                self.arbiter.move(name, old_device, slot.device,
                                  prim.profile.compute_units, now, replica=0)
                fsm.action_taken(p99)
                out.append(self._record(Decision(
                    now, "move", name,
                    {"to": slot.key, "score": ranked[0][1],
                     "from_score": cur_score, "pause_s": pause},
                    self._summary(name, snap), before.__dict__,
                    prim.config.copy().__dict__)))
                self._side_effects(out, name, snap, comp_off)
                return out

        if self.cfg.enable_mig:
            devices = prim.devices
            ext = {d: self.actuator.headroom_units(d) for d in devices}
            per_dev = []
            for d in devices:
                n_here = sum(1 for s in prim.replicas if s.device == d)
                have = min(ext[d], self.arbiter.headroom(d))
                per_dev.append(have // max(1, n_here))
            headroom = min(per_dev) if per_dev else 0
            target = greedy_upgrade(self.lattice, prim.profile, headroom)
            if target is not None:
                tsig = snap.tenants.get(name)
                req = UpgradeRequest(
                    tenant=name, priority=prim.priority,
                    miss_rate=tsig.miss_rate if tsig else 0.0,
                    devices=devices, current=prim.profile, target=target)
                if self.arbiter.grant(req, now, external_headroom=ext):
                    pause = self.actuator.reconfigure(name, target)
                    prim.profile = target
                    prim.config.profile = target.name
                    fsm.action_taken(p99)
                    out.append(self._record(Decision(
                        now, "reconfigure", name,
                        {"profile": target.name, "pause_s": pause},
                        self._summary(name, snap), before.__dict__,
                        prim.config.copy().__dict__)))
                    self._side_effects(out, name, snap, comp_off)
                    return out

        # last resort when structural levers are disabled/exhausted:
        # guardrail the compute offender
        if self.cfg.enable_guardrails and comp_off is not None:
            st = self.tenants[comp_off]
            new_q = max(self.cfg.bounds.mps_quota[0],
                        st.config.mps_quota - 0.25)
            if new_q < st.config.mps_quota:
                applied = self.guardrails.set_mps_quota(self.actuator,
                                                        comp_off, new_q)
                st.config.mps_quota = applied
                fsm.action_taken(p99)
                out.append(self._record(Decision(
                    now, "mps", comp_off, {"quota": applied, "for": name},
                    self._summary(name, snap))))
        return out

    def _side_effects(self, out: List[Decision], name: str, snap: Snapshot,
                      comp_off: Optional[str]) -> None:
        """Pin CPU away from IRQ-hot cores + stricter MPS quota (§2.3)."""
        now = snap.time
        prim = self.tenants[name]
        if not prim.config.cpu_pinned_away_from_irq:
            self.actuator.pin_cpu_away_from_irq(name)
            prim.config.cpu_pinned_away_from_irq = True
            out.append(self._record(Decision(
                now, "pin_cpu", name, {}, self._summary(name, snap))))
        if self.cfg.enable_guardrails and comp_off is not None:
            st = self.tenants[comp_off]
            new_q = max(self.cfg.bounds.mps_quota[0],
                        st.config.mps_quota - 0.25)
            if new_q < st.config.mps_quota:
                applied = self.guardrails.set_mps_quota(self.actuator,
                                                        comp_off, new_q)
                st.config.mps_quota = applied
                out.append(self._record(Decision(
                    now, "mps", comp_off, {"quota": applied, "for": name},
                    self._summary(name, snap))))

    def _relax(self, name: str, snap: Snapshot, p99: float
               ) -> Optional[Decision]:
        """Relax isolation when stable (smaller profile whose placement
        score remains below a conservative threshold, §2.2.1)."""
        if not self.cfg.enable_mig:
            return None
        fsm = self.fsms[name]
        if not fsm.at_reconfig_boundary() or fsm.is_cooling_down():
            return None
        prim = self.tenants[name]
        smaller = relax_step(self.lattice, prim.profile)
        if smaller is None:
            return None
        score = placement_score(self.topo, prim.slot, snap, self.cfg.weights)
        if score > self.cfg.relax_score_threshold:
            return None
        before = prim.config.copy()
        pause = self.actuator.reconfigure(name, smaller)
        prim.profile = smaller
        prim.config.profile = smaller.name
        self.arbiter.set_profile(name, smaller.compute_units, snap.time,
                                 action="relax")
        fsm.action_taken(p99)
        return self._record(Decision(
            snap.time, "relax", name,
            {"profile": smaller.name, "pause_s": pause},
            self._summary(name, snap), before.__dict__,
            prim.config.copy().__dict__))

    def _rollback(self, tenant: str, snap: Snapshot) -> Decision:
        prim = self.tenants[tenant]
        good = self.audit.last_known_good(tenant)
        before = prim.config.copy()
        pause = 0.0
        if good is not None:
            if good.profile != prim.config.profile:
                profile = self.lattice[good.profile]
                # restoring a *larger* profile needs the extra units to
                # still be free on every replica device (another lane may
                # have claimed them since): the actuator's ledger enforces
                # the budget, so check before asking
                extra = profile.compute_units - prim.profile.compute_units
                fits = extra <= 0 or all(
                    min(self.actuator.headroom_units(d),
                        self.arbiter.headroom(d))
                    >= extra * sum(1 for s in prim.replicas
                                   if s.device == d)
                    for d in prim.devices)
                if fits:
                    pause += self.actuator.reconfigure(tenant, profile)
                    prim.profile = profile
                    self.arbiter.set_profile(tenant, profile.compute_units,
                                             snap.time, action="rollback")
                else:
                    good = good.copy()
                    good.profile = prim.config.profile
            if (good.device, good.slot) != (prim.config.device,
                                            prim.config.slot):
                slot = Slot(self.topo.host_of(good.device), good.device,
                            good.slot)
                # the old home may have been claimed meanwhile: only move
                # back if the slot is still free and the device still has
                # unit headroom for us
                feasible = (
                    any(s.key == slot.key
                        for s in self.actuator.free_slots())
                    and (slot.device == prim.slot.device or
                         min(self.actuator.headroom_units(slot.device),
                             self.arbiter.headroom(slot.device))
                         >= prim.profile.compute_units))
                if feasible:
                    old_device = prim.slot.device
                    pause += self.actuator.move(tenant, slot)
                    prim.slot = slot
                    prim.replicas[0] = slot
                    self.arbiter.move(tenant, old_device, slot.device,
                                      prim.profile.compute_units, snap.time,
                                      replica=0)
                else:
                    good = good.copy()
                    good.device = prim.config.device
                    good.slot = prim.config.slot
            prim.config = good.copy()
        return self._record(Decision(
            snap.time, "rollback", tenant, {"pause_s": pause},
            self._summary(tenant, snap), before.__dict__,
            prim.config.copy().__dict__))
