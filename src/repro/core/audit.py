"""Decision audit log with rollback support (paper §2.4: "log all decisions
with signal snapshots for audit, and support rollback to the last-known-good
configuration if post-change p99 worsens within a short validation window").
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class TenantConfig:
    profile: str
    device: str
    slot: int
    mps_quota: float = 1.0
    cpu_pinned_away_from_irq: bool = False

    def copy(self) -> "TenantConfig":
        return TenantConfig(**asdict(self))


@dataclass
class Decision:
    time: float
    action: str                       # reconfigure|move|throttle_io|mps|relax|rollback
    tenant: str
    args: Dict[str, Any]
    signal_summary: Dict[str, float]
    config_before: Optional[Dict[str, Any]] = None
    config_after: Optional[Dict[str, Any]] = None
    validated: Optional[bool] = None


class AuditLog:
    def __init__(self):
        self.decisions: List[Decision] = []
        self._last_known_good: Dict[str, TenantConfig] = {}

    def record(self, d: Decision) -> Decision:
        self.decisions.append(d)
        return d

    def mark_good(self, tenant: str, cfg: TenantConfig) -> None:
        self._last_known_good[tenant] = cfg.copy()

    def last_known_good(self, tenant: str) -> Optional[TenantConfig]:
        cfg = self._last_known_good.get(tenant)
        return cfg.copy() if cfg is not None else None

    def set_validation(self, ok: bool, tenant: Optional[str] = None) -> None:
        """Attach the validation verdict to the most recent structural
        decision (reconfigure/move/relax), optionally restricted to one
        tenant's lane (multi-tenant controllers validate per lane)."""
        for d in reversed(self.decisions):
            if d.action in ("reconfigure", "move", "relax") and \
                    (tenant is None or d.tenant == tenant):
                d.validated = ok
                return

    # ------------------------------------------------------------- exports
    def actions_of(self, kind: str) -> List[Decision]:
        return [d for d in self.decisions if d.action == kind]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.decisions:
            out[d.action] = out.get(d.action, 0) + 1
        return out

    def to_json(self) -> str:
        return json.dumps([asdict(d) for d in self.decisions], indent=2,
                          default=str)
