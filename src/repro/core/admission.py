"""Admission control (paper §2.3): "In cases where no safe placement can be
found for a new tenant without violating the SLOs of existing tenants, an
admission control mechanism will queue or reject the new workload."

Registry-driven: a new workload arrives as a TenantSpec; admission scores
candidate slots with the *same* PCIe-aware placement scorer the controller
uses (core/placement.py), reads occupancy/headroom/fabric load from the
shared DeviceLedger, and — on admit — commits the placement: the spec
joins the TenantRegistry (with its chosen slot keys pinned, so a later
``resolve_placements`` over the expanded registry is stable) and the
ledger is updated.  Safety is assessed with the paper's formal substrate:

  * Claim-1 stability — the new tenant's sustained demand must keep
    sum_j g_j < B on every fabric (PCIe root complex) it touches;
  * Kingman guidance — predicted utilisation rho must stay below a
    conservative bound, both for the newcomer itself and for every
    existing latency-sensitive tenant whose fabric share would shrink;
  * unit feasibility — the new tenant's slice must fit the per-GPU
    compute-unit budget the ledger tracks.

QUEUE'd tenants are retried (``retry_queued``) whenever capacity frees —
a departure releases its ledger slots and the next retry admits.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.ledger import DeviceLedger
from repro.core.placement import PlacementWeights, rank_candidates
from repro.core.profiles import A100_MIG, ProfileLattice
from repro.core.signals import Snapshot, SystemSignals
from repro.core.tenancy import TenantRegistry, TenantSpec
from repro.core.topology import ClusterTopology, Slot


class AdmissionVerdict(enum.Enum):
    ADMIT = "admit"
    QUEUE = "queue"
    REJECT = "reject"


@dataclass(frozen=True)
class AdmissionConfig:
    fabric_capacity: float = 25e9     # per root complex (PCIe gen4 x16-ish)
    rho_bound: float = 0.85           # conservative utilisation bound
    max_queue: int = 8


@dataclass
class RateLimiter:
    """Per-tenant token bucket for *request-plane* admission.

    The tenant-plane controller above admits whole workloads; the gateway
    needs the same Kingman safety argument applied per request.  A bucket
    built by :meth:`kingman` refills at exactly the arrival rate that
    keeps the tenant's predicted utilisation rho = lambda E[S] at the
    configured bound — requests beyond that rate are the ones the G/G/1
    analysis says would blow up the queue, so the gateway REJECTs them
    fast (the 429 path) instead of letting them rot in a deadline queue.
    """
    rate: float                 # sustained tokens (requests) per second
    burst: float = 8.0          # bucket depth: tolerated arrival burst
    tokens: float = field(default=-1.0)
    _t: float = 0.0             # last refill time

    def __post_init__(self) -> None:
        if self.tokens < 0:
            self.tokens = self.burst

    def allow(self, now: float) -> bool:
        """Consume one token if available (refilling first)."""
        if now > self._t:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t) * self.rate)
            self._t = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    @classmethod
    def kingman(cls, spec: "TenantSpec",
                cfg: AdmissionConfig = AdmissionConfig(), *,
                n_flows: int = 1, burst: float = 8.0) -> "RateLimiter":
        """Bucket whose sustained rate holds rho at ``cfg.rho_bound``.

        Uses the same service-time estimate as the tenant-plane
        controller (E[S] = c0 + size/share under a fair fabric share
        split ``n_flows`` ways), so the per-request limit and the
        placement-time safety check agree about what "too fast" means.
        """
        share = cfg.fabric_capacity / max(1, n_flows)
        es = spec.c0_s + spec.mean_size / max(share, 1e-9)
        return cls(rate=cfg.rho_bound / max(es, 1e-9), burst=burst)


@dataclass
class AdmissionRecord:
    """One line of the admission audit trail."""
    time: float
    tenant: str
    verdict: AdmissionVerdict
    slots: Tuple[str, ...] = ()
    reason: str = ""


class AdmissionController:
    """Admit/queue/reject new TenantSpecs against the shared ledger."""

    def __init__(self, topo: ClusterTopology, registry: TenantRegistry,
                 ledger: DeviceLedger,
                 cfg: AdmissionConfig = AdmissionConfig(), *,
                 lattice: ProfileLattice = A100_MIG,
                 weights: PlacementWeights = PlacementWeights(),
                 tracer=None):
        self.topo = topo
        self.registry = registry
        self.ledger = ledger
        self.cfg = cfg
        self.lattice = lattice
        self.weights = weights
        self.queue: List[TenantSpec] = []
        self.records: List[AdmissionRecord] = []
        # core.obs.Tracer (or None): tenant-plane verdicts land as
        # instants on the controller track alongside actuator actions
        self.tracer = tracer

    def _record(self, rec: AdmissionRecord) -> None:
        self.records.append(rec)
        if self.tracer is not None:
            self.tracer.instant(
                f"admission:{rec.verdict.value}", rec.time,
                track="controller", lane=rec.tenant,
                slots=list(rec.slots), reason=rec.reason)

    # ------------------------------------------------------------- scoring
    def _snapshot(self, now: float) -> Snapshot:
        """Ledger-derived system view for the placement scorer (the live
        controller passes its smoothed telemetry instead)."""
        sys = SystemSignals(
            pcie_bytes={r: self.ledger.root_demand(r)
                        for r in self.topo.roots()})
        return Snapshot(now, {}, sys)

    def _demand_of(self, spec: TenantSpec, n_replicas: int) -> float:
        """Per-replica sustained fabric demand (bytes/s)."""
        if spec.is_latency:
            return spec.rate * spec.mean_size / max(1, n_replicas)
        return spec.pcie_demand

    def _units_of(self, spec: TenantSpec) -> int:
        if not spec.is_latency:
            return spec.units
        return DeviceLedger._profile_units(self.lattice, spec.profile)

    def _service_estimate(self, spec: TenantSpec, share: float) -> float:
        """E[S] under a given fabric share (compute + transfer)."""
        return spec.c0_s + spec.mean_size / max(share, 1e-9)

    def _rho_ok(self, spec: TenantSpec, root: str, extra_flows: int) -> bool:
        """Kingman guidance: with ``extra_flows`` new PS flows on ``root``,
        every resident latency tenant — and the newcomer itself — must
        keep rho = lambda E[S] below the bound."""
        resident = self.ledger.latency_on_root(root)
        n = max(1, len(resident))
        share_after = self.cfg.fabric_capacity / (n + extra_flows)
        for entry in resident:
            if entry.tenant not in self.registry:
                continue
            t = self.registry[entry.tenant]
            if not t.is_latency:
                continue
            lam = t.rate / max(1, t.replicas)
            if lam * self._service_estimate(t, share_after) \
                    > self.cfg.rho_bound:
                return False
        if spec.is_latency:
            lam = spec.rate / max(1, spec.replicas)
            if lam * self._service_estimate(spec, share_after) \
                    > self.cfg.rho_bound:
                return False
        return True

    def safe_slots_for(self, spec: TenantSpec,
                       snap: Optional[Snapshot] = None,
                       now: float = 0.0) -> Optional[List[Slot]]:
        """A full replica set of safe slots (scorer-ranked), or None.

        Slots are claimed tentatively while iterating so multi-replica
        tenants account for their own earlier replicas' demand and units.
        """
        want = spec.replicas if spec.is_latency else 1
        units = self._units_of(spec)
        demand = self._demand_of(spec, want)
        snap = snap if snap is not None else self._snapshot(now)
        ranked = rank_candidates(self.topo, self.ledger.free_slots(), snap,
                                 self.weights)
        chosen: List[Slot] = []
        extra_units: Dict[str, int] = {}      # device -> tentative units
        extra_demand: Dict[str, float] = {}   # root -> tentative demand
        extra_flows: Dict[str, int] = {}      # root -> tentative PS flows
        for slot, _score in ranked:
            dev = slot.device
            root = self.topo.root_of(dev)
            # unit feasibility under the per-GPU budget
            if self.ledger.headroom_units(dev) - extra_units.get(dev, 0) \
                    < units:
                continue
            # Claim-1: aggregate sustained demand stays under capacity
            load = self.ledger.root_demand(root) + extra_demand.get(root, 0.0)
            if load + demand >= self.cfg.fabric_capacity:
                continue
            # Kingman: bounded rho for residents and for the newcomer
            if not self._rho_ok(spec, root, 1 + extra_flows.get(root, 0)):
                continue
            chosen.append(slot)
            extra_units[dev] = extra_units.get(dev, 0) + units
            extra_demand[root] = extra_demand.get(root, 0.0) + demand
            extra_flows[root] = extra_flows.get(root, 0) + 1
            if len(chosen) == want:
                return chosen
        return None

    # ------------------------------------------------------------ verdicts
    def _commit(self, spec: TenantSpec, slots: List[Slot]) -> TenantSpec:
        """Admit: pin the placement into the registry + ledger."""
        placed = spec.with_(placement=tuple(s.key for s in slots))
        self.registry.add(placed)
        units = self._units_of(spec)
        demand = self._demand_of(spec, len(slots))
        for i, s in enumerate(slots):
            self.ledger.occupy(spec.name, s, units, replica=i,
                               demand=demand, role=spec.role)
        return placed

    def decide(self, spec: TenantSpec, snap: Optional[Snapshot] = None,
               now: float = 0.0
               ) -> Tuple[AdmissionVerdict, Optional[List[Slot]]]:
        if spec.name in self.registry:
            raise ValueError(f"tenant {spec.name!r} already admitted")
        if any(q.name == spec.name for q in self.queue):
            raise ValueError(f"tenant {spec.name!r} already queued")
        slots = self.safe_slots_for(spec, snap, now)
        if slots is not None:
            self._commit(spec, slots)
            self._record(AdmissionRecord(
                now, spec.name, AdmissionVerdict.ADMIT,
                tuple(s.key for s in slots)))
            return AdmissionVerdict.ADMIT, slots
        if len(self.queue) < self.cfg.max_queue:
            self.queue.append(spec)
            self._record(AdmissionRecord(
                now, spec.name, AdmissionVerdict.QUEUE,
                reason="no safe placement"))
            return AdmissionVerdict.QUEUE, None
        self._record(AdmissionRecord(
            now, spec.name, AdmissionVerdict.REJECT, reason="queue full"))
        return AdmissionVerdict.REJECT, None

    def retry_queued(self, snap: Optional[Snapshot] = None, now: float = 0.0
                     ) -> List[Tuple[TenantSpec, List[Slot]]]:
        """Re-score the queue (call when capacity frees); admits in FIFO
        order, leaves the rest queued."""
        admitted: List[Tuple[TenantSpec, List[Slot]]] = []
        still: List[TenantSpec] = []
        for spec in self.queue:
            if spec.name in self.registry:   # admitted out-of-band: drop
                continue
            slots = self.safe_slots_for(spec, snap, now)
            if slots is not None:
                placed = self._commit(spec, slots)
                self._record(AdmissionRecord(
                    now, spec.name, AdmissionVerdict.ADMIT,
                    tuple(s.key for s in slots), reason="retry"))
                admitted.append((placed, slots))
            else:
                still.append(spec)
        self.queue = still
        return admitted

    def release(self, name: str, now: float = 0.0) -> None:
        """Tenant departure: free its ledger slots, registry entry, and
        any still-queued copy."""
        self.ledger.release(name)
        if name in self.registry:
            self.registry.remove(name)
        self.queue = [q for q in self.queue if q.name != name]

    # --------------------------------------------------------------- audit
    def counts(self) -> Dict[str, int]:
        out = {v.value: 0 for v in AdmissionVerdict}
        for r in self.records:
            out[r.verdict.value] += 1
        return out
