"""Admission control (paper §2.3): "In cases where no safe placement can be
found for a new tenant without violating the SLOs of existing tenants, an
admission control mechanism will queue or reject the new workload."

Safety is assessed with the paper's own formal substrate:
  * Claim-1 stability — the new tenant's throttled demand must keep
    sum_j g_j < B on every fabric it touches;
  * Kingman guidance — the predicted utilisation rho for each existing
    latency-sensitive tenant must stay below a conservative bound.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import psmodel
from repro.core.kingman import GG1
from repro.core.signals import Snapshot
from repro.core.topology import ClusterTopology, Slot


class AdmissionVerdict(enum.Enum):
    ADMIT = "admit"
    QUEUE = "queue"
    REJECT = "reject"


@dataclass(frozen=True)
class TenantDemand:
    name: str
    pcie_bytes_per_s: float           # sustained fabric demand
    arrival_rate: float = 0.0         # requests/s (0 for batch tenants)
    mean_service_s: float = 0.0


@dataclass(frozen=True)
class AdmissionConfig:
    fabric_capacity: float = 25e9     # per root complex (PCIe gen4 x16-ish)
    rho_bound: float = 0.85           # conservative utilisation bound
    max_queue: int = 8


class AdmissionController:
    def __init__(self, topo: ClusterTopology,
                 cfg: AdmissionConfig = AdmissionConfig()):
        self.topo = topo
        self.cfg = cfg
        self.queue: List[TenantDemand] = []

    def _root_demand(self, root: str, placements: Mapping[str, Slot],
                     demands: Mapping[str, TenantDemand]) -> float:
        total = 0.0
        for tenant, slot in placements.items():
            if self.topo.root_of(slot.device) == root and tenant in demands:
                total += demands[tenant].pcie_bytes_per_s
        return total

    def safe_slot_for(self, new: TenantDemand,
                      placements: Mapping[str, Slot],
                      demands: Mapping[str, TenantDemand],
                      latency_tenants: Mapping[str, GG1],
                      free_slots: Sequence[Slot]) -> Optional[Slot]:
        """First slot where both safety conditions hold, or None."""
        for slot in free_slots:
            root = self.topo.root_of(slot.device)
            load = self._root_demand(root, placements, demands)
            # Claim-1: aggregate (throttled) demand under capacity
            if load + new.pcie_bytes_per_s >= self.cfg.fabric_capacity:
                continue
            # Kingman: existing latency tenants on this root keep rho bounded
            ok = True
            for tenant, gg1 in latency_tenants.items():
                t_slot = placements.get(tenant)
                if t_slot is None or self.topo.root_of(t_slot.device) != root:
                    continue
                # service time inflates when the fabric share shrinks
                share_before = self.cfg.fabric_capacity / max(
                    1, self._count_on_root(root, placements))
                share_after = self.cfg.fabric_capacity / (
                    self._count_on_root(root, placements) + 1)
                inflation = share_before / max(share_after, 1e-9)
                rho = gg1.arrival_rate * gg1.mean_service * inflation
                if rho > self.cfg.rho_bound:
                    ok = False
                    break
            if ok:
                return slot
        return None

    def _count_on_root(self, root: str, placements: Mapping[str, Slot]) -> int:
        return sum(1 for s in placements.values()
                   if self.topo.root_of(s.device) == root)

    def decide(self, new: TenantDemand, placements: Mapping[str, Slot],
               demands: Mapping[str, TenantDemand],
               latency_tenants: Mapping[str, GG1],
               free_slots: Sequence[Slot]
               ) -> Tuple[AdmissionVerdict, Optional[Slot]]:
        slot = self.safe_slot_for(new, placements, demands, latency_tenants,
                                  free_slots)
        if slot is not None:
            return AdmissionVerdict.ADMIT, slot
        if len(self.queue) < self.cfg.max_queue:
            self.queue.append(new)
            return AdmissionVerdict.QUEUE, None
        return AdmissionVerdict.REJECT, None

    def retry_queued(self, placements, demands, latency_tenants, free_slots
                     ) -> List[Tuple[TenantDemand, Slot]]:
        admitted = []
        still = []
        for t in self.queue:
            slot = self.safe_slot_for(t, placements, demands, latency_tenants,
                                      free_slots)
            if slot is not None:
                admitted.append((t, slot))
            else:
                still.append(t)
        self.queue = still
        return admitted
