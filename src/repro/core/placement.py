"""Topology-aware placement heuristic (paper §2.2.1).

The score penalises a candidate slot for
  (i)   sharing a PCIe root complex with a bandwidth-heavy tenant,
  (ii)  colocating with a NUMA domain exhibiting high block I/O,
  (iii) recent IRQ bursts on adjacent CPU cores,
and (beyond-paper, for the cluster case) (iv) crossing to another host,
which costs a full state transfer.  Lower is better.  "When upgrading
isolation, we first attempt an intra-GPU move to the least-penalised MIG
instance; only if insufficient do we enlarge the MIG slice."
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.signals import Snapshot
from repro.core.topology import ClusterTopology, Slot


@dataclass(frozen=True)
class PlacementWeights:
    pcie: float = 1.0          # (i) shared busy root complex
    numa_io: float = 0.6       # (ii) NUMA block-I/O pressure
    irq: float = 0.3           # (iii) adjacent IRQ bursts
    cross_host: float = 0.5    # (iv) inter-host move penalty
    # normalisation constants (units -> dimensionless)
    pcie_scale: float = 12.5e9      # bytes/s at which the root is "busy"
    io_scale: float = 2.0e9
    irq_scale: float = 10_000.0


def placement_score(topo: ClusterTopology, slot: Slot, snap: Snapshot,
                    weights: PlacementWeights = PlacementWeights(),
                    current_host: Optional[int] = None) -> float:
    root = topo.root_of(slot.device)
    numa = topo.numa_of(slot.device)
    host = f"h{topo.host_of(slot.device)}"
    s = snap.system
    score = 0.0
    score += weights.pcie * (s.pcie_bytes.get(root, 0.0) / weights.pcie_scale)
    score += weights.numa_io * (s.host_io.get(numa, 0.0) / weights.io_scale)
    score += weights.irq * (s.irq_rate.get(host, 0.0) / weights.irq_scale)
    if current_host is not None and topo.host_of(slot.device) != current_host:
        score += weights.cross_host
    return score


def rank_candidates(topo: ClusterTopology, candidates: Sequence[Slot],
                    snap: Snapshot,
                    weights: PlacementWeights = PlacementWeights(),
                    current_host: Optional[int] = None
                    ) -> List[Tuple[Slot, float]]:
    scored = [(c, placement_score(topo, c, snap, weights, current_host))
              for c in candidates]
    return sorted(scored, key=lambda x: (x[1], x[0].key))


def best_candidate(topo: ClusterTopology, candidates: Sequence[Slot],
                   snap: Snapshot,
                   weights: PlacementWeights = PlacementWeights(),
                   current_host: Optional[int] = None
                   ) -> Optional[Tuple[Slot, float]]:
    ranked = rank_candidates(topo, candidates, snap, weights, current_host)
    return ranked[0] if ranked else None


def intra_device_first(topo: ClusterTopology, current: Slot,
                       free_slots: Sequence[Slot], snap: Snapshot,
                       weights: PlacementWeights = PlacementWeights()
                       ) -> List[Tuple[Slot, float]]:
    """Paper ordering: intra-GPU slots first, then same-host, then remote."""
    def tier(s: Slot) -> int:
        if s.device == current.device:
            return 0
        if topo.host_of(s.device) == topo.host_of(current.device):
            return 1
        return 2

    ranked = rank_candidates(topo, free_slots, snap, weights,
                             current_host=topo.host_of(current.device))
    return sorted(ranked, key=lambda x: (tier(x[0]), x[1], x[0].key))
