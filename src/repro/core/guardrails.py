"""Lightweight guardrails (paper §2.2): MPS quotas and cgroup-style I/O
throttles, applied for bounded windows with automatic expiry (§2.4: "I/O
throttles use cgroup io.max with bounded windows (tens of seconds) to
reduce collateral damage")."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Tuple


@dataclass(frozen=True)
class GuardrailBounds:
    """Table 1: MPS quota 50-100%; IO throttle 100-500 MB/s.  Bounded
    windows "reduce collateral damage" (§2.4): a refractory period after
    expiry keeps the background tenant from being throttled back-to-back."""
    mps_quota: Tuple[float, float] = (0.5, 1.0)
    io_throttle: Tuple[float, float] = (100e6, 500e6)
    io_window_s: float = 30.0
    io_refractory_s: float = 90.0


class GuardrailActuator(Protocol):
    def set_io_throttle(self, tenant: str, bytes_per_s: Optional[float]) -> None: ...
    def set_mps_quota(self, tenant: str, frac: float) -> None: ...


@dataclass
class ActiveThrottle:
    tenant: str
    bytes_per_s: float
    expires_at: float


class GuardrailManager:
    def __init__(self, bounds: GuardrailBounds = GuardrailBounds()):
        self.bounds = bounds
        self.active_throttles: Dict[str, ActiveThrottle] = {}
        self.mps_quotas: Dict[str, float] = {}
        self._last_expiry: Dict[str, float] = {}

    def in_refractory(self, tenant: str, now: float) -> bool:
        exp = self._last_expiry.get(tenant)
        return exp is not None and now < exp + self.bounds.io_refractory_s

    def throttle_io(self, actuator: GuardrailActuator, tenant: str,
                    bytes_per_s: float, now: float,
                    window_s: Optional[float] = None) -> float:
        lo, hi = self.bounds.io_throttle
        value = float(min(max(bytes_per_s, lo), hi))
        window = window_s if window_s is not None else self.bounds.io_window_s
        actuator.set_io_throttle(tenant, value)
        self.active_throttles[tenant] = ActiveThrottle(
            tenant, value, now + window)
        return value

    def set_mps_quota(self, actuator: GuardrailActuator, tenant: str,
                      frac: float) -> float:
        lo, hi = self.bounds.mps_quota
        value = float(min(max(frac, lo), hi))
        actuator.set_mps_quota(tenant, value)
        self.mps_quotas[tenant] = value
        return value

    def tick(self, actuator: GuardrailActuator, now: float) -> List[str]:
        """Expire bounded-window throttles.  Returns expired tenant names."""
        expired = [t for t, a in self.active_throttles.items()
                   if now >= a.expires_at]
        for t in expired:
            actuator.set_io_throttle(t, None)
            self._last_expiry[t] = now
            del self.active_throttles[t]
        return expired

    def is_throttled(self, tenant: str) -> bool:
        return tenant in self.active_throttles

    def total_throttle(self) -> float:
        """Sum of active caps — feeds the Claim-1 stability check."""
        return sum(a.bytes_per_s for a in self.active_throttles.values())
