"""Serving request / response types."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class SubmitOutcome:
    """Result of an engine/scheduler ``submit``: truthy on admission, and
    on rejection it carries *why* — the gateway's backpressure policy
    needs the distinction between a transient shortage (retry later, the
    pool may drain) and a structural impossibility (reject fast, no
    amount of waiting helps).  ``bool(outcome)`` preserves the old
    ``submit() -> bool`` contract for every existing call site."""
    ok: bool
    reason: str = ""            # "" | "pool_exhausted" | "never_fits" |
                                # "exceeds_seq_cap"
    transient: bool = False     # True: retrying later may succeed

    def __bool__(self) -> bool:
        return self.ok


ADMITTED = SubmitOutcome(True)
POOL_EXHAUSTED = SubmitOutcome(False, "pool_exhausted", transient=True)
NEVER_FITS = SubmitOutcome(False, "never_fits")
EXCEEDS_SEQ_CAP = SubmitOutcome(False, "exceeds_seq_cap")


@dataclass
class Request:
    req_id: int
    tenant: str
    prompt_len: int
    max_new_tokens: int
    arrival: float                      # seconds (sim or wall clock)
    slo_ms: Optional[float] = None      # per-request TTFT SLO, if any
    prompt_tokens: Optional[object] = None   # [S] int32 (None => synthetic)
    # scheduling weight for the paged runtime's SLO-aware preemption:
    # lower-priority sequences are evicted first when the page pool is
    # exhausted (ties broken by deadline = arrival + slo)
    priority: float = 1.0
    # optional speculative-decode hint corpus ([T] int tokens): text the
    # frontend believes likely to continue this response (e.g. the
    # completion previously observed for the same templated prompt).
    # Hints are only ever *searched* by the n-gram drafter and *verified*
    # by the model — a stale hint costs rejected draft rows, never a
    # wrong output token
    draft_hints: Optional[object] = None

    # --- runtime state ---
    slot: int = -1
    # time the request was accepted by an ENGINE (set by the gateway on a
    # successful submit; -1 when the request never passed through a
    # gateway door).  ``arrival`` is the front-door timestamp, so
    # door-measured TTFT = prefill_done - arrival (includes door-queue
    # wait) while engine-measured TTFT = prefill_done - submitted
    submitted: float = -1.0
    prefill_done: float = -1.0          # time the first token was emitted
    finished: float = -1.0
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)
    decode_times: List[float] = field(default_factory=list)  # per decode token

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done < 0:
            return None
        return self.prefill_done - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean time-per-output-token after the first (needs >= 2 tokens)."""
        if self.prefill_done < 0 or not self.decode_times:
            return None
        return (self.decode_times[-1] - self.prefill_done) / \
            len(self.decode_times)

    @property
    def itls(self) -> List[float]:
        """Inter-token latencies (first gap measured from prefill_done)."""
        if self.prefill_done < 0 or not self.decode_times:
            return []
        ts = [self.prefill_done] + self.decode_times
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def done(self) -> bool:
        return self.finished >= 0
