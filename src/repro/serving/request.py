"""Serving request / response types."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Request:
    req_id: int
    tenant: str
    prompt_len: int
    max_new_tokens: int
    arrival: float                      # seconds (sim or wall clock)
    slo_ms: Optional[float] = None      # per-request TTFT SLO, if any
    prompt_tokens: Optional[object] = None   # [S] int32 (None => synthetic)

    # --- runtime state ---
    slot: int = -1
    prefill_done: float = -1.0          # time the first token was emitted
    finished: float = -1.0
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done < 0:
            return None
        return self.prefill_done - self.arrival

    @property
    def done(self) -> bool:
        return self.finished >= 0
