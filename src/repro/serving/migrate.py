"""Live lane migration: verified KV-page shipping between replicas.

PR 9 made replica death survivable by *recompute*: ``drain_requests()``
hands the victim's requests back and the gateway redrives them cold —
every recovered request pays a full re-prefill, so a crash converts
directly into a TTFT tail spike.  This module adds the cheap path: a
lane's KV pages are serialized (with per-page **chain hashes**, the int8
scales when the pool is quantized, and the request's cursor/metric
stamps), shipped to the destination replica, verified, and re-linked
into the destination pool refcount-correctly — attaching to
already-shared prefix pages through the destination's chain-hash index
instead of copying them.

The handshake is **verify-then-commit**: the importer recomputes every
chain hash on arrival and on ANY mismatch imports nothing — the lane
falls back to the PR 9 recompute-redrive path.  Graceful degradation,
never a wrong token: greedy decode makes recompute token-identical, so
the worst a corrupted transfer can cost is latency.

A migration is PS traffic like any tenant flow, so
:class:`MigrationPlanner` prices the transfer against the ledger's
per-root fabric demand (the same waterfill bookkeeping every other flow
is charged under) — migration must not become its own noisy neighbor.

Layering: pure host-side numpy + the paged runtime's pool dicts; no
scheduler policy and no gateway state lives here.  The wiring (who
migrates whom, and when) belongs to ``ServingActuator.migrate`` and the
serve loop's crash/drain/gray-failure triggers.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.serving.kvcache import PageTableEntry
from repro.serving.request import Request
from repro.serving.sched import SeqState


def _pool_leaves(pools) -> Iterator[Tuple[str, str, str, str]]:
    """Stable iteration order over every page-pool leaf:
    ``(leaf_key, group, name, field)`` where group is ``prefix`` |
    ``period``.  The leaf key is what the chain hash covers, so the
    order must be deterministic across export and import."""
    for group in sorted(pools):
        for name in sorted(pools[group]):
            for fld in sorted(pools[group][name]):
                yield f"{group}/{name}/{fld}", group, name, fld


def _page_digest(prev: bytes, tokens: Tuple[int, ...],
                 payload: Dict[str, np.ndarray]) -> bytes:
    """Chained per-page hash: ties this page's KV bytes to the page's
    token content AND to the whole history before it (same recursive
    construction as the prefix cache's chain keys, but over the actual
    pool bytes).  A digest match at page *i* therefore vouches for the
    entire transfer up to *i*."""
    h = hashlib.sha256()
    h.update(prev)
    h.update(np.asarray(tokens, np.int64).tobytes())
    for key in sorted(payload):
        h.update(key.encode())
        h.update(np.ascontiguousarray(payload[key]).tobytes())
    return h.digest()


@dataclass
class PageRecord:
    """One shipped KV page: its token content (the valid rows), every
    pool leaf's bytes for that page, and the chain digest."""
    src_page: int                      # source pool id (debugging only)
    tokens: Tuple[int, ...]            # valid-row token content
    payload: Dict[str, np.ndarray]     # leaf key -> page bytes
    digest: bytes                      # chained sha256 (see _page_digest)

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self.payload.values()) \
            + 8 * len(self.tokens)


@dataclass
class LaneManifest:
    """Everything one lane needs to resume on another replica: the
    request's cursor/metric stamps (snapshotted BEFORE the drain resets
    them) plus the page chain.  ``pages == []`` is a *cold* manifest —
    the lane held no KV (still queued) or the caller chose recompute."""
    req: Request
    prompt_tokens: np.ndarray
    prefilled: int = 0
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)
    decode_times: List[float] = field(default_factory=list)
    last_token: int = 0
    prefix_hit: int = 0
    chunks_done: int = 0
    cache_tokens: int = 0              # tokens resident in the pages
    pages: List[PageRecord] = field(default_factory=list)

    @property
    def warm(self) -> bool:
        return bool(self.pages)

    @property
    def total_bytes(self) -> int:
        return sum(p.nbytes for p in self.pages)

    def history(self) -> np.ndarray:
        """The lane's true token history covered by the cache: prompt
        then committed output, truncated to ``cache_tokens``."""
        out = np.asarray(self.output_tokens, np.int64)
        prm = np.asarray(self.prompt_tokens, np.int64)
        return np.concatenate([prm, out])[: self.cache_tokens]


class PageExporter:
    """Serialize a paged runtime's resident lanes into
    :class:`LaneManifest` objects.  Must run BEFORE
    ``drain_for_redrive()`` — the drain resets the request cursors the
    manifest snapshots."""

    def __init__(self, runtime):
        self.rt = runtime

    def export_lane(self, seq: SeqState) -> LaneManifest:
        req = seq.req
        man = LaneManifest(
            req=req,
            prompt_tokens=np.asarray(req.prompt_tokens, np.int64)
            if req.prompt_tokens is not None else np.zeros(0, np.int64),
            prefilled=seq.prefilled, generated=req.generated,
            output_tokens=list(req.output_tokens),
            decode_times=list(req.decode_times),
            last_token=seq.last_token, prefix_hit=seq.prefix_hit,
            chunks_done=seq.chunks_done)
        kv = self.rt.kv
        entry = kv.tables.get(req.req_id)
        if entry is None:
            return man                                # cold: no pages
        # tokens actually resident: an in-flight prefill holds exactly
        # ``prefilled``; a decode lane holds prompt + generated-1 (the
        # newest token is only appended by the next step)
        if seq.prefilled < req.prompt_len:
            cache_tokens = seq.prefilled
        else:
            cache_tokens = req.prompt_len + max(0, req.generated - 1)
        cache_tokens = min(cache_tokens, entry.length)
        if cache_tokens <= 0:
            return man
        man.cache_tokens = cache_tokens
        hist = man.history()
        ps = kv.page_size
        prev = b""
        for p in range(kv.pages_needed(cache_tokens)):
            page = entry.pages[p]
            toks = tuple(int(t) for t in hist[p * ps:(p + 1) * ps])
            payload: Dict[str, np.ndarray] = {}
            for key, group, name, fld in _pool_leaves(self.rt.pools):
                pool = self.rt.pools[group][name][fld]
                if group == "period":                 # stacked [repeats,...]
                    payload[key] = np.asarray(pool[:, page])
                else:
                    payload[key] = np.asarray(pool[page])
            prev = _page_digest(prev, toks, payload)
            man.pages.append(PageRecord(src_page=page, tokens=toks,
                                        payload=payload, digest=prev))
        return man

    def export_all(self) -> List[LaneManifest]:
        """Every resident lane, in-service first then queued (queued
        lanes hold no pages and export cold)."""
        sched = self.rt.sched
        seqs = list(sched.prefilling) + list(sched.active) \
            + list(sched.waiting)
        return [self.export_lane(s) for s in seqs]


class ImportReject(Exception):
    """Internal: a verify-then-commit check failed — the lane degrades
    to the recompute-redrive path (never surfaced to callers)."""


class PageImporter:
    """Re-link shipped lanes into a destination runtime's page pool,
    refcount-correctly, behind the verify-then-commit handshake.

    Commit order per lane: (1) recompute every chain hash — ANY
    mismatch rejects the whole lane before a single byte lands;
    (2) attach the longest run of full prompt pages the destination
    already shares (its chain-hash prefix index — a ref bump, zero
    copies); (3) allocate + write the remaining pages, rolling the
    attach back if the pool cannot hold them; (4) register the block
    table, publish the prompt pages to the destination's prefix index,
    restore the request cursors, and hand the lane to the scheduler.
    A rejected lane leaves the destination bit-identical to before the
    call."""

    def __init__(self, runtime):
        self.rt = runtime
        self.imported_lanes = 0
        self.imported_pages = 0
        self.attached_pages = 0
        self.copied_pages = 0
        self.cold_lanes = 0          # no pages shipped: nothing to verify
        self.verify_failures = 0     # shipped but rejected -> recompute

    # ------------------------------------------------------------- verify
    def _verify(self, man: LaneManifest) -> None:
        hist = man.history()
        ps = self.rt.kv.page_size
        if man.cache_tokens > len(hist):
            raise ImportReject("cursor past token history")
        if (man.generated >= 1) != (man.prefilled >= man.req.prompt_len):
            raise ImportReject("inconsistent prefill/decode cursors")
        prev = b""
        for p, rec in enumerate(man.pages):
            want = tuple(int(t) for t in hist[p * ps:(p + 1) * ps])
            if rec.tokens != want:
                raise ImportReject(f"page {p} token mismatch")
            prev = _page_digest(prev, rec.tokens, rec.payload)
            if prev != rec.digest:
                raise ImportReject(f"page {p} chain-hash mismatch")

    # ------------------------------------------------------------- commit
    def _write_page(self, rec: PageRecord, dst_page: int) -> None:
        pools = self.rt.pools
        for key, group, name, fld in _pool_leaves(pools):
            arr = rec.payload.get(key)
            if arr is None:
                raise ImportReject(f"payload leaf {key} missing")
            pool = pools[group][name][fld]
            if group == "period":
                pools[group][name][fld] = pool.at[:, dst_page].set(arr)
            else:
                pools[group][name][fld] = pool.at[dst_page].set(arr)

    def import_lane(self, man: LaneManifest) -> bool:
        """Verify-then-commit one lane.  True iff the lane is now
        resident on the destination; False means the caller must fall
        back to the recompute redrive (the destination is untouched)."""
        kv, sched = self.rt.kv, self.rt.sched
        req = man.req
        if not man.warm:
            self.cold_lanes += 1
            return False
        try:
            if req.req_id in kv.tables:
                raise ImportReject("req_id already resident")
            self._verify(man)
        except ImportReject:
            self.verify_failures += 1
            return False

        ps = kv.page_size
        n_pages = len(man.pages)
        # full prompt pages are attachable through the destination's
        # chain-hash index — the same key construction the digest chain
        # vouches for, so an index hit IS a verified content match
        n_prompt_full = min(man.cache_tokens, req.prompt_len) // ps
        attached: List[int] = []
        if kv.enable_prefix_cache:
            for _, key in kv._chain_keys(man.prompt_tokens, n_prompt_full):
                page = kv.prefix_index.get(key)
                if page is None:
                    break
                attached.append(page)
        for page in attached:
            kv.ref[page] = kv.ref.get(page, 0) + 1
            kv.cached.pop(page, None)
        fresh: List[int] = []
        try:
            for rec in man.pages[len(attached):]:
                fresh.append(kv._alloc_page())
                self._write_page(rec, fresh[-1])
        except (MemoryError, ImportReject):
            for page in fresh + attached:             # full rollback
                kv._drop_page_ref(page)
            self.verify_failures += 1
            return False

        entry = PageTableEntry(req.req_id, pages=attached + fresh,
                               length=man.cache_tokens,
                               shared_tokens=len(attached) * ps)
        kv.tables[req.req_id] = entry
        kv.commit_prefix(req.req_id, man.prompt_tokens,
                         min(man.cache_tokens, req.prompt_len))

        # restore the request's cursor/metric stamps (the source drain
        # reset them after export); TTFT/decode stamps are conserved —
        # a warm lane resumes, it does not restart
        req.generated = man.generated
        req.output_tokens[:] = man.output_tokens
        req.decode_times[:] = man.decode_times
        req.slot = -1
        seq = SeqState(req, prefilled=man.prefilled,
                       last_token=man.last_token,
                       prefix_hit=man.prefix_hit,
                       chunks_done=man.chunks_done)
        if man.prefilled >= req.prompt_len:
            sched.active.append(seq)
        else:
            sched.prefilling.append(seq)
        self.imported_lanes += 1
        self.imported_pages += n_pages
        self.attached_pages += len(attached)
        self.copied_pages += len(fresh)
        return True


@dataclass(frozen=True)
class MigrationPlan:
    """A priced transfer: how many lanes/pages/bytes move and how long
    the fabric share makes the copy take."""
    lanes: int
    warm_lanes: int
    pages: int
    bytes: int
    bandwidth: float
    transfer_s: float


class MigrationPlanner:
    """Price a migration against the fabric the way every other flow is
    priced: the transfer's bandwidth is the capacity left on the more
    contended of the two root complexes involved (per the ledger's
    per-root demand bookkeeping), floored at ``min_frac`` of capacity —
    a PS flow never fully starves.  Without a ledger/topology the
    planner falls back to raw capacity (single-host tests)."""

    def __init__(self, fabric=None, topo=None, ledger=None,
                 min_frac: float = 0.1, setup_s: float = 0.005):
        self.fabric = fabric
        self.topo = topo
        self.ledger = ledger
        self.min_frac = min_frac
        self.setup_s = setup_s

    def _root_bandwidth(self, device: Optional[str]) -> float:
        cap = self.fabric.pcie_capacity if self.fabric is not None else 25e9
        if device is None or self.topo is None or self.ledger is None:
            return cap
        demand = self.ledger.root_demand(self.topo.root_of(device))
        return max(self.min_frac * cap, cap - demand)

    def price(self, manifests: List[LaneManifest],
              src_device: Optional[str] = None,
              dst_device: Optional[str] = None) -> MigrationPlan:
        total = sum(m.total_bytes for m in manifests)
        pages = sum(len(m.pages) for m in manifests)
        warm = sum(1 for m in manifests if m.warm)
        bw = min(self._root_bandwidth(src_device),
                 self._root_bandwidth(dst_device))
        transfer_s = self.setup_s + (total / bw if total else 0.0)
        return MigrationPlan(lanes=len(manifests), warm_lanes=warm,
                             pages=pages, bytes=total, bandwidth=bw,
                             transfer_s=transfer_s)
