"""Serving-side controller actuator: the real JAX engine + PS fabric.

FabricState models the shared PCIe/ICI path with the paper's PS law;
ServingActuator implements the controller Actuator protocol over a live
ServingEngine (quota <-> MPS, io throttle <-> pipeline cap, move <->
fabric path, reconfigure <-> slice compute scale with a paused re-lower).
Used by benchmarks/llm_ttft.py and repro.launch.serve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import psmodel
from repro.serving.engine import ServingEngine


@dataclass
class FabricState:
    pcie_capacity: float = 25e9
    t2_demand: float = 20e9
    t2_ps_weight: float = 3.0
    t2_active: bool = False
    io_throttle: Optional[float] = None
    throttle_residual: float = 0.6
    on_shared_root: bool = True           # until the controller moves T1

    def t1_bandwidth(self) -> float:
        demands = {"T1": psmodel.Demand(weight=1.0)}
        if self.t2_active and self.on_shared_root:
            eff = self.t2_demand if self.io_throttle is None else \
                self.t2_demand * self.throttle_residual + self.io_throttle
            demands["T2"] = psmodel.Demand(weight=self.t2_ps_weight,
                                           throttle=eff)
        else:
            demands["amb"] = psmodel.Demand(weight=1.0, throttle=10e9)
        return psmodel.ps_shares_waterfill(demands, self.pcie_capacity)["T1"]


class ServingActuator:
    """Controller Actuator over the real engine + fabric model."""

    def __init__(self, engine: ServingEngine, fabric: FabricState,
                 topo, clock):
        self.engine = engine
        self.fabric = fabric
        self.topo = topo
        self.clock = clock
        self.compute_scale = 1.0          # MIG-profile compute multiplier
        self.ref_units = 2
        self.pause_until = 0.0
        self.reconfigs = []

    def reconfigure(self, tenant, profile):
        pause = max(8.0, np.random.default_rng(0).normal(18.0, 3.0))
        self.compute_scale = (self.ref_units / profile.compute_units) ** 0.35
        self.pause_until = max(self.pause_until, self.clock() + pause)
        self.reconfigs.append(pause)
        return pause

    def move(self, tenant, slot):
        self.fabric.on_shared_root = False
        self.pause_until = max(self.pause_until, self.clock() + 2.0)
        return 2.0

    def set_io_throttle(self, tenant, bytes_per_s):
        self.fabric.io_throttle = bytes_per_s

    def set_mps_quota(self, tenant, frac):
        self.engine.set_quota(max(frac, 0.5))

    def pin_cpu_away_from_irq(self, tenant):
        pass

    def free_slots(self):
        return [s for s in self.topo.slots()
                if s.device not in ("h0:g0", "h0:g1")]

    def headroom_units(self, device: str) -> int:
        return 2 if device == "h0:g0" else 4


