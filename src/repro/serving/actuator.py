"""Serving-side controller actuator: real JAX engines + PS fabric.

FabricState models the shared PCIe/ICI path with the paper's PS law, now
per-tenant: every latency tenant that still sits on the contended root
complex shares the fabric with the ETL stream *and with each other*.
ServingActuator implements the controller Actuator protocol over one or
more live ServingEngines — one engine per tenant-replica, all sharing the
FabricState — mapping quota <-> MPS, io throttle <-> pipeline cap,
move <-> fabric path, reconfigure <-> slice compute scale with a paused
re-lower.  Used by benchmarks/llm_ttft.py and repro.launch.serve.

Single-tenant call sites keep working: passing one engine wraps it as
tenant "T1", and the legacy ``compute_scale`` / ``pause_until`` /
``t1_bandwidth`` views read that tenant's state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core import psmodel
from repro.serving.engine import ServingEngine


@dataclass
class FabricState:
    pcie_capacity: float = 25e9
    t2_demand: float = 20e9
    t2_ps_weight: float = 3.0
    t2_active: bool = False
    io_throttle: Optional[float] = None
    throttle_residual: float = 0.6
    on_shared_root: bool = True           # legacy single-tenant flag ("T1")
    # per-tenant root membership: tenant -> still on the contended root
    shared_tenants: Dict[str, bool] = field(default_factory=dict)
    # offered PCIe demand of a sibling latency tenant: they are mostly-
    # idle DMA streams, so they compete as *throttled* flows (the same
    # modelling choice as ClusterSim._bandwidth), not saturating ones
    sibling_demand: float = 5e9

    def set_on_root(self, tenant: str, on: bool) -> None:
        self.shared_tenants[tenant] = on
        if tenant == "T1":
            self.on_shared_root = on

    def _on_root(self, tenant: str) -> bool:
        return self.shared_tenants.get(tenant, self.on_shared_root)

    def bandwidth(self, tenant: str) -> float:
        """PS share of ``tenant`` on its current root complex."""
        demands = {tenant: psmodel.Demand(weight=1.0)}
        if self._on_root(tenant):
            if self.t2_active:
                eff = self.t2_demand if self.io_throttle is None else \
                    self.t2_demand * self.throttle_residual + self.io_throttle
                demands["T2"] = psmodel.Demand(weight=self.t2_ps_weight,
                                               throttle=eff)
            # sibling latency tenants still on the shared root compete too
            for other, on in self.shared_tenants.items():
                if on and other != tenant:
                    demands[other] = psmodel.Demand(
                        weight=1.0, throttle=self.sibling_demand)
        else:
            demands["amb"] = psmodel.Demand(weight=1.0, throttle=10e9)
        return psmodel.ps_shares_waterfill(demands,
                                           self.pcie_capacity)[tenant]

    def t1_bandwidth(self) -> float:
        return self.bandwidth("T1")


EngineMap = Dict[str, List[ServingEngine]]


class ServingActuator:
    """Controller Actuator over live engines + the shared fabric model.

    ``engines`` is either a single ServingEngine (wrapped as tenant "T1")
    or a dict tenant -> engine | [engine per replica].
    """

    def __init__(self, engines: Union[ServingEngine, EngineMap],
                 fabric: FabricState, topo, clock, ref_units: int = 2):
        if isinstance(engines, ServingEngine):
            engines = {"T1": [engines]}
        self.engines: EngineMap = {
            t: list(e) if isinstance(e, (list, tuple)) else [e]
            for t, e in engines.items()}
        self.fabric = fabric
        self.topo = topo
        self.clock = clock
        self.ref_units = ref_units
        self.compute_scales: Dict[str, float] = {
            t: 1.0 for t in self.engines}     # MIG-profile compute multiplier
        self.pauses: Dict[str, float] = {t: 0.0 for t in self.engines}
        self.reconfigs: List[float] = []
        self._occupied = ("h0:g0", "h0:g1")

    # ------------------------------------------------- single-tenant views
    @property
    def _first(self) -> str:
        return next(iter(self.engines))

    @property
    def engine(self) -> ServingEngine:
        return self.engines[self._first][0]

    @property
    def compute_scale(self) -> float:
        return self.compute_scales.get("T1",
                                       self.compute_scales[self._first])

    @property
    def pause_until(self) -> float:
        return self.pauses.get("T1", self.pauses[self._first])

    # --------------------------------------------------- per-tenant access
    def tenant_engines(self, tenant: str) -> List[ServingEngine]:
        return self.engines.get(tenant, self.engines[self._first])

    def compute_scale_of(self, tenant: str) -> float:
        return self.compute_scales.get(tenant, 1.0)

    def paused_until(self, tenant: str) -> float:
        return self.pauses.get(tenant, 0.0)

    # ------------------------------------------------------------ Actuator
    def reconfigure(self, tenant, profile):
        pause = max(8.0, np.random.default_rng(0).normal(18.0, 3.0))
        scale = (self.ref_units / profile.compute_units) ** 0.35
        key = tenant if tenant in self.engines else self._first
        self.compute_scales[key] = scale
        self.pauses[key] = max(self.pauses.get(key, 0.0),
                               self.clock() + pause)
        self.reconfigs.append(pause)
        return pause

    def move(self, tenant, slot):
        self.fabric.set_on_root(tenant if tenant in self.engines
                                else self._first, False)
        key = tenant if tenant in self.engines else self._first
        self.pauses[key] = max(self.pauses.get(key, 0.0),
                               self.clock() + 2.0)
        return 2.0

    def set_io_throttle(self, tenant, bytes_per_s):
        self.fabric.io_throttle = bytes_per_s

    def set_mps_quota(self, tenant, frac):
        for eng in self.tenant_engines(tenant):
            eng.set_quota(max(frac, 0.5))

    def pin_cpu_away_from_irq(self, tenant):
        pass

    def free_slots(self):
        return [s for s in self.topo.slots()
                if s.device not in self._occupied]

    def headroom_units(self, device: str) -> int:
        return 2 if device == "h0:g0" else 4
