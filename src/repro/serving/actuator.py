"""Serving-side controller actuator: real JAX engines + PS fabric.

FabricState models the shared PCIe/ICI path with the paper's PS law, now
per-tenant: every latency tenant that still sits on the contended root
complex shares the fabric with the ETL stream *and with each other*, and
cgroup-style io.max throttles are tracked per background tenant (a
throttle aimed at one offender no longer clobbers another's guardrail).
ServingActuator implements the controller Actuator protocol over one or
more live ServingEngines — one engine per tenant-replica, all sharing the
FabricState — mapping quota <-> MPS, io throttle <-> pipeline cap,
move <-> fabric path, reconfigure <-> slice compute scale with a paused
re-lower.  Placement state (slot occupancy, per-GPU unit budget, per-root
demand) lives in a shared DeviceLedger — the same bookkeeping the cluster
simulator reads — so ``free_slots``/``headroom_units`` report real fabric
state instead of constants, and moves/reconfigures are budget-checked.
Used by benchmarks/llm_ttft.py and repro.launch.serve.

Single-tenant call sites keep working: passing one engine wraps it as
tenant "T1" over a paper-default ledger (T1 on h0:g0:s0 against the
ETL/trainer slots), and the legacy ``compute_scale`` / ``pause_until`` /
``t1_bandwidth`` views read that tenant's state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core import psmodel
from repro.core.ledger import DeviceLedger
from repro.core.profiles import A100_MIG
from repro.serving.engine import ServingEngine


@dataclass
class FabricState:
    pcie_capacity: float = 25e9
    t2_demand: float = 20e9
    t2_ps_weight: float = 3.0
    t2_active: bool = False
    throttle_residual: float = 0.6
    on_shared_root: bool = True           # legacy single-tenant flag ("T1")
    # per-tenant root membership: tenant -> still on the contended root
    shared_tenants: Dict[str, bool] = field(default_factory=dict)
    # per-tenant io.max caps (bytes/s, None = uncapped): the guardrail
    # throttles a *specific* background offender, so the caps must not
    # share one global knob
    io_throttles: Dict[str, Optional[float]] = field(default_factory=dict)
    # offered PCIe demand of a sibling latency tenant: they are mostly-
    # idle DMA streams, so they compete as *throttled* flows (the same
    # modelling choice as ClusterSim._bandwidth), not saturating ones
    sibling_demand: float = 5e9

    def set_on_root(self, tenant: str, on: bool) -> None:
        self.shared_tenants[tenant] = on
        if tenant == "T1":
            self.on_shared_root = on

    def _on_root(self, tenant: str) -> bool:
        return self.shared_tenants.get(tenant, self.on_shared_root)

    def set_io_throttle(self, tenant: str,
                        bytes_per_s: Optional[float]) -> None:
        if bytes_per_s is None:
            self.io_throttles.pop(tenant, None)
        else:
            self.io_throttles[tenant] = bytes_per_s

    def io_throttle_of(self, tenant: str) -> Optional[float]:
        return self.io_throttles.get(tenant)

    @property
    def io_throttle(self) -> Optional[float]:
        """Legacy view: the ETL stream's cap."""
        return self.io_throttles.get("T2")

    def bandwidth(self, tenant: str) -> float:
        """PS share of ``tenant`` on its current root complex."""
        demands = {tenant: psmodel.Demand(weight=1.0)}
        if self._on_root(tenant):
            if self.t2_active:
                thr = self.io_throttles.get("T2")
                eff = self.t2_demand if thr is None else \
                    self.t2_demand * self.throttle_residual + thr
                demands["T2"] = psmodel.Demand(weight=self.t2_ps_weight,
                                               throttle=eff)
            # sibling latency tenants still on the shared root compete too
            for other, on in self.shared_tenants.items():
                if on and other != tenant:
                    demands[other] = psmodel.Demand(
                        weight=1.0, throttle=self.sibling_demand)
        else:
            demands["amb"] = psmodel.Demand(weight=1.0, throttle=10e9)
        return psmodel.ps_shares_waterfill(demands,
                                           self.pcie_capacity)[tenant]

    def t1_bandwidth(self) -> float:
        return self.bandwidth("T1")


EngineMap = Dict[str, List[ServingEngine]]


class ServingActuator:
    """Controller Actuator over live engines + the shared fabric model.

    ``engines`` is either a single ServingEngine (wrapped as tenant "T1")
    or a dict tenant -> engine | [engine per replica].  ``ledger`` is the
    shared DeviceLedger placement bookkeeping; when omitted, a paper-
    default ledger is synthesized (each engine tenant auto-placed against
    the ETL/trainer background slots).  ``rng`` seeds the reconfiguration-
    pause draw — pass the run's generator so repeated reconfigs sample
    the paper's 18 +- 6 s distribution instead of one frozen value.
    """

    def __init__(self, engines: Union[ServingEngine, EngineMap],
                 fabric: FabricState, topo, clock, ref_units: int = 2,
                 ledger: Optional[DeviceLedger] = None,
                 rng: Optional[np.random.Generator] = None,
                 tracer: Optional[object] = None):
        if isinstance(engines, ServingEngine):
            engines = {"T1": [engines]}
        # every Actuator protocol method emits exactly one trace event
        # (no silent actions) — asserted by the trace lint test
        self.tracer = tracer
        self.engines: EngineMap = {
            t: list(e) if isinstance(e, (list, tuple)) else [e]
            for t, e in engines.items()}
        self.fabric = fabric
        self.topo = topo
        self.clock = clock
        self.ref_units = ref_units
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.ledger = ledger if ledger is not None else self._default_ledger()
        self.compute_scales: Dict[str, float] = {
            t: 1.0 for t in self.engines}     # MIG-profile compute multiplier
        self.pauses: Dict[str, float] = {t: 0.0 for t in self.engines}
        self.reconfigs: List[float] = []
        # completed migrate() results (dicts), appended per call for the
        # serving loop to pop: the actuator re-homes lanes engine-side
        # but holds no gateway reference, so the caller finishes the
        # request-plane half (warm adoption / cold redrive)
        self.migrations: List[Dict] = []
        # the hot fabric path is the root hosting the heaviest bandwidth
        # (ETL-class) background stream, whatever it is named
        bw = [e for e in self.ledger.entries()
              if e.role != "latency" and e.demand > 0]
        self.contended_root = (
            self.topo.root_of(max(bw, key=lambda e: e.demand).slot.device)
            if bw else "h0:r0")

    def _default_ledger(self) -> DeviceLedger:
        """Paper-default bookkeeping for legacy call sites: engine tenants
        plus the ETL (h0:g1:s0) / trainer (h0:g0:s1) background slots,
        ambient co-tenants on every other device (mirrors SimParams)."""
        from repro.core.tenancy import BACKGROUND, TenantRegistry, TenantSpec
        reg = TenantRegistry()
        single = len(self.engines) == 1
        for name, engs in self.engines.items():
            placement = ("h0:g0:s0",) if single else ()
            reg.add(TenantSpec(name=name, replicas=len(engs),
                               placement=placement))
        if "T2" not in reg:
            reg.add(TenantSpec(name="T2", role=BACKGROUND,
                               profile="7g.80gb", units=0,
                               pcie_demand=self.fabric.t2_demand,
                               placement=("h0:g1:s0",)))
        if "T3" not in reg:
            reg.add(TenantSpec(name="T3", role=BACKGROUND,
                               profile="2g.20gb", units=2,
                               placement=("h0:g0:s1",)))
        return DeviceLedger.from_registry(
            self.topo, reg, A100_MIG,
            home_devices=("h0:g0",), ambient_units=3)

    # ------------------------------------------------- single-tenant views
    @property
    def _first(self) -> str:
        return next(iter(self.engines))

    @property
    def engine(self) -> ServingEngine:
        return self.engines[self._first][0]

    @property
    def compute_scale(self) -> float:
        return self.compute_scales.get("T1",
                                       self.compute_scales[self._first])

    @property
    def pause_until(self) -> float:
        return self.pauses.get("T1", self.pauses[self._first])

    # --------------------------------------------------- per-tenant access
    def tenant_engines(self, tenant: str) -> List[ServingEngine]:
        return self.engines.get(tenant, self.engines[self._first])

    def compute_scale_of(self, tenant: str) -> float:
        return self.compute_scales.get(tenant, 1.0)

    def paused_until(self, tenant: str) -> float:
        return self.pauses.get(tenant, 0.0)

    def _key(self, tenant: str) -> str:
        return tenant if tenant in self.engines else self._first

    def _sync_root_membership(self, tenant: str) -> None:
        on = any(self.topo.root_of(s.device) == self.contended_root
                 for s in self.ledger.slots_of(tenant))
        self.fabric.set_on_root(tenant, on)

    def _trace(self, name: str, tenant, dur: float = 0.0, **args) -> None:
        if self.tracer is not None:
            self.tracer.action(name, self.clock(), str(tenant),
                               dur=dur, **args)

    # ------------------------------------------------------------ Actuator
    def reconfigure(self, tenant, profile):
        pause = max(8.0, self.rng.normal(18.0, 3.0))
        scale = (self.ref_units / profile.compute_units) ** 0.35
        key = self._key(tenant)
        self.ledger.set_units(key, profile.compute_units)   # budget-checked
        self.compute_scales[key] = scale
        self.pauses[key] = max(self.pauses.get(key, 0.0),
                               self.clock() + pause)
        self.reconfigs.append(pause)
        self._trace("reconfigure", key, dur=pause,
                    profile=profile.name, units=profile.compute_units)
        return pause

    def move(self, tenant, slot):
        key = self._key(tenant)
        self.ledger.move(key, 0, slot)                      # budget-checked
        self._sync_root_membership(key)
        self.pauses[key] = max(self.pauses.get(key, 0.0),
                               self.clock() + 2.0)
        self._trace("move", key, dur=2.0, slot=str(slot))
        return 2.0

    def set_io_throttle(self, tenant, bytes_per_s):
        self.fabric.set_io_throttle(tenant, bytes_per_s)
        self._trace("set_io_throttle", tenant, bytes_per_s=bytes_per_s)

    def set_mps_quota(self, tenant, frac):
        for eng in self.tenant_engines(tenant):
            eng.set_quota(max(frac, 0.5))
        self._trace("set_mps_quota", tenant, frac=frac)

    def pin_cpu_away_from_irq(self, tenant):
        self._trace("pin_cpu_away_from_irq", tenant)

    def free_slots(self):
        self._trace("query_free_slots", "")
        return self.ledger.free_slots()

    def headroom_units(self, device: str) -> int:
        self._trace("query_headroom_units", "", device=device)
        return self.ledger.headroom_units(device)

    def _replica_device(self, tenant: str, replica: int) -> Optional[str]:
        for e in self.ledger.entries():
            if e.tenant == tenant and e.replica == replica:
                return e.slot.device
        return None

    def migrate(self, tenant: str, replica_from: int,
                replica_to: int) -> float:
        """Live lane migration: ship ``replica_from``'s resident lanes
        (KV pages + cursors, chain-hashed) to ``replica_to`` and resume
        them there.  The transfer is priced against the ledger's
        per-root fabric demand — migration is PS traffic like any tenant
        flow — and returned as the pause the victim's lanes observe.
        Lanes that fail the importer's verify-then-commit handshake (or
        never held pages) land in the result's ``cold`` list: the caller
        must finish those through ``Gateway.redrive`` — the PR 9
        recompute path — so a corrupted transfer degrades to latency,
        never a wrong token.  The engine-side re-homing happens here;
        the request-plane half (warm adoption / cold redrive) is the
        caller's, via the appended ``self.migrations`` record."""
        from repro.serving.migrate import MigrationPlanner, PageImporter
        key = self._key(tenant)
        engs = self.engines[key]
        src, dst = engs[replica_from], engs[replica_to]
        manifests = src.drain_requests(ship_state=True)
        planner = MigrationPlanner(self.fabric, self.topo, self.ledger)
        plan = planner.price(manifests,
                             src_device=self._replica_device(key,
                                                             replica_from),
                             dst_device=self._replica_device(key,
                                                             replica_to))
        warm: List = []
        cold: List = []
        importer = PageImporter(dst.runtime) if dst.runtime is not None \
            else None
        for man in manifests:
            if importer is not None and importer.import_lane(man):
                warm.append(man.req)
            else:
                cold.append(man.req)
        self.migrations.append({
            "tenant": key, "from": replica_from, "to": replica_to,
            "warm": warm, "cold": cold, "transfer_s": plan.transfer_s,
            "pages": plan.pages, "bytes": plan.bytes,
            "attached_pages": importer.attached_pages if importer else 0,
            "copied_pages": importer.copied_pages if importer else 0,
            "verify_failures": importer.verify_failures if importer else 0})
        self._trace("migrate", key, dur=plan.transfer_s,
                    replica_from=replica_from, replica_to=replica_to,
                    lanes=plan.lanes, warm=len(warm), cold=len(cold),
                    pages=plan.pages, bytes=plan.bytes)
        return plan.transfer_s

    # ------------------------------------------------------- KV observability
    def kv_pressure(self, tenant: str) -> Dict[str, float]:
        """Aggregate KV page-pool pressure across a tenant's replicas
        (works on either engine backend; the paged runtime's reserved ==
        live pages, the dense backend reserves prompt+max_new up front).
        Distinguishing reserved from used is what lets admission see
        headroom the dense reservation hides."""
        engs = self.tenant_engines(tenant)
        used = sum(e.metrics.kv_used_pages for e in engs)
        reserved = sum(e.metrics.kv_reserved_pages for e in engs)
        total = sum(e.metrics.kv_total_pages for e in engs)
        return {"used_pages": used, "reserved_pages": reserved,
                "total_pages": total,
                "reserved_frac": reserved / total if total else 0.0,
                "used_frac": used / total if total else 0.0}
