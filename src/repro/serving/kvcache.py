"""Paged KV cache management (vLLM-style) for the serving engine.

Host-side page-table bookkeeping (free list, per-sequence block tables,
per-page refcounts, prefix-cache index) plus device-side page pools
consumed by the ``paged_attention`` Pallas kernel.  The dense slot-cache
path used by the pure-jnp models shares the same accounting so admission
control sees identical memory pressure either way.

Pages are *refcounted*: a page normally belongs to one sequence, but the
prefix cache lets many sequences map the same physical page (shared
system/common prompt prefixes).  The sharing contract is page-aligned
copy-on-write by construction: only FULL pages are ever shared, a
sequence's writes always land at positions past its shared prefix (which
is page-aligned), so a shared page is immutable while it has sharers and
divergence mid-page simply misses the index and allocates a private page.

Prefix index: each full prompt page is keyed by the chain
``key = (parent_key, page_tokens)`` — a collision-free recursive tuple —
so a hit at page *i* guarantees the entire token history up to *i* matches.
When a shared page's refcount drops to zero it parks on a ``cached`` LRU
(content intact, still matchable) instead of the free list; allocation
prefers truly-free pages and only then evicts cached pages LRU-first, so
prefix reuse never costs live capacity.

Occupancy views (they differ under the dense engine's conservative
prompt+max_new reservation, under the paged runtime's grow-on-demand
reservation, and under prefix sharing):

  * ``reserved_pages`` — distinct pages held by live sequences (capacity
    pressure: what admission must respect — cached pages are reclaimable
    and do NOT count);
  * ``used_pages``     — distinct pages holding live KV (what the decode
    kernels actually read);
  * ``cached_pages``   — refcount-zero prefix pages kept warm for reuse.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class PageTableEntry:
    seq_id: int
    pages: List[int] = field(default_factory=list)
    length: int = 0
    shared_tokens: int = 0     # prefix tokens mapped from the cache


class PagedKVCache:
    """Page pool allocator: fixed pool of ``num_pages`` pages of
    ``page_size`` tokens each, allocated per sequence on demand, with
    refcounted cross-sequence prefix sharing."""

    def __init__(self, num_pages: int, page_size: int,
                 enable_prefix_cache: bool = True):
        self.num_pages = num_pages
        self.page_size = page_size
        self.enable_prefix_cache = enable_prefix_cache
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.tables: Dict[int, PageTableEntry] = {}
        self.ref: Dict[int, int] = {}              # page -> live sharers
        # prefix cache state (all empty when disabled)
        self.prefix_index: Dict[tuple, int] = {}   # chain key -> page
        self.page_key: Dict[int, tuple] = {}       # page -> chain key
        self.cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref==0
        # optional event sink (duck-typed: ``on_commit(chain_key,
        # upto_tokens)`` / ``on_evict(chain_key)``) — the cluster-wide
        # PrefixDirectory subscribes here so the dispatcher learns which
        # replica holds which page-aligned prefix.  Events fire when an
        # index entry is born (commit_prefix) or dies (cached-page
        # eviction); a listener that lags is stale-but-SAFE: routing on
        # stale holdings only costs a prefix-cache miss, never a token
        self.listener = None

    # -- allocation ---------------------------------------------------------
    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        need = self.pages_needed(prompt_len + max_new)
        return len(self.free) + len(self.cached) >= need

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def _alloc_page(self) -> int:
        """One fresh page: the free list first, then LRU eviction of
        refcount-zero cached prefix pages (their index entries die with
        them — a page with live sharers is never here, so sharing is
        never broken by allocation pressure)."""
        if self.free:
            page = self.free.pop()
        elif self.cached:
            page, _ = self.cached.popitem(last=False)
            key = self.page_key.pop(page)
            del self.prefix_index[key]
            if self.listener is not None:
                self.listener.on_evict(key)
        else:
            raise MemoryError("KV page pool exhausted")
        self.ref[page] = 1
        return page

    def allocate(self, seq_id: int, prompt_len: int,
                 reserve_total: int | None = None) -> PageTableEntry:
        """Allocates pages for ``reserve_total`` tokens up front (defaults
        to prompt_len).  Reserving prompt+max_new at admission guarantees
        append_token never exhausts the pool mid-decode (vLLM-conservative
        reservation; admission control enforces the budget)."""
        assert seq_id not in self.tables, f"seq {seq_id} already allocated"
        entry = PageTableEntry(seq_id)
        self.tables[seq_id] = entry
        self._grow(entry, reserve_total or prompt_len)
        entry.length = prompt_len
        return entry

    def append_token(self, seq_id: int) -> None:
        entry = self.tables[seq_id]
        self._grow(entry, entry.length + 1)
        entry.length += 1

    def reserve(self, seq_id: int, target_tokens: int) -> None:
        """Grow a sequence's page list to cover ``target_tokens`` WITHOUT
        marking them live — the paged runtime reserves before launching a
        forward pass (the device scatter needs real page ids), then calls
        :meth:`extend` once the tokens are actually written.  Allocates the
        sequence lazily on first use (the paged runtime does not reserve
        prompt+max_new at submit).  Raises MemoryError when the pool is
        exhausted; partial growth is kept (tracked, released on release())."""
        entry = self.tables.get(seq_id)
        if entry is None:
            entry = PageTableEntry(seq_id)
            self.tables[seq_id] = entry
        self._grow(entry, target_tokens)

    def extend(self, seq_id: int, target_tokens: int) -> None:
        """Mark the sequence as holding ``target_tokens`` live tokens
        (monotone), growing pages if the caller skipped reserve()."""
        entry = self.tables[seq_id]
        self._grow(entry, target_tokens)
        entry.length = max(entry.length, target_tokens)

    def _grow(self, entry: PageTableEntry, target_tokens: int) -> None:
        need = self.pages_needed(target_tokens)
        while len(entry.pages) < need:
            entry.pages.append(self._alloc_page())

    def release(self, seq_id: int) -> None:
        """Drop one sequence's references.  Pages whose refcount hits zero
        return to the free list — unless they are indexed prefix pages,
        which park on the cached LRU with their KV intact.  A page with
        remaining sharers is left untouched (never freed under a live
        sharer).  Releasing an unknown / already-released ``seq_id``
        raises: silently ignoring it would hand the same pages out twice
        and corrupt every sharer's KV."""
        entry = self.tables.pop(seq_id, None)
        if entry is None:
            raise KeyError(
                f"release() of unknown or already-released seq {seq_id} — "
                f"double-release would re-free shared pages and corrupt "
                f"the free list")
        for page in entry.pages:
            self._drop_page_ref(page)

    def release_all(self) -> int:
        """Release every live sequence at once (replica teardown / drain
        safety net).  Returns the number of sequences released.  After
        this, ``reserved_pages == 0``: every page is either free or
        parked on the cached prefix LRU."""
        seqs = list(self.tables)
        for seq_id in seqs:
            self.release(seq_id)
        return len(seqs)

    def _drop_page_ref(self, page: int) -> None:
        """One sequence stops referencing ``page``: decrement, and on
        refcount zero return it to the free list (or park an indexed
        prefix page on the cached LRU, KV intact)."""
        self.ref[page] -= 1
        if self.ref[page] > 0:
            return
        del self.ref[page]
        if self.enable_prefix_cache and page in self.page_key:
            self.cached[page] = None         # appends at the LRU tail
        else:
            self.free.append(page)

    def truncate(self, seq_id: int, new_len: int) -> None:
        """Roll a sequence back to ``new_len`` tokens, freeing the pages
        past ``pages_needed(new_len)`` (page-granular: a partially-covered
        final page is kept).  This is the speculative-decode rollback
        primitive — rejected draft tokens over-extended the sequence and
        their pages must return to the pool without reaching into the
        allocator's internals.

        Truncating into a *shared* page (refcount > 1) raises ValueError
        before any state changes: a shared page's KV is live for its other
        sharers, so rolling it back would corrupt them.  In practice
        shared pages cover the page-aligned prompt prefix, which is always
        below any decode rollback point; hitting this error means the
        caller computed a bogus ``new_len``."""
        if new_len < 0:
            raise ValueError(f"truncate to negative length {new_len}")
        entry = self.tables[seq_id]
        keep = self.pages_needed(new_len)
        drop = entry.pages[keep:]
        for page in drop:
            if self.ref.get(page, 0) > 1:
                raise ValueError(
                    f"truncate(seq {seq_id}, {new_len}) would roll back "
                    f"shared page {page} (refcount {self.ref[page]}) — "
                    f"shared pages are live for their other sharers and "
                    f"must never be rolled back")
        for page in drop:
            self._drop_page_ref(page)
        del entry.pages[keep:]
        entry.length = min(entry.length, new_len)
        entry.shared_tokens = min(entry.shared_tokens, new_len)

    # -- prefix sharing -----------------------------------------------------
    def _chain_keys(self, tokens, n_pages: int):
        """Chained per-page keys for the first ``n_pages`` full pages."""
        key: Optional[tuple] = None
        ps = self.page_size
        for p in range(n_pages):
            chunk = tuple(int(t) for t in tokens[p * ps:(p + 1) * ps])
            key = (key, chunk)
            yield p, key

    def match_prefix(self, seq_id: int, tokens) -> int:
        """Map the longest cached page-aligned prefix of ``tokens`` into
        ``seq_id``'s block table (bumping refcounts) and mark it live.
        At least the final token is always left uncovered so the tail
        prefill still produces the first-token logits (TTFT = O(tail)).
        Returns the number of prompt tokens covered.  Only valid before
        the sequence holds any pages."""
        if not self.enable_prefix_cache or tokens is None:
            return 0
        entry = self.tables.get(seq_id)
        if entry is not None and entry.pages:
            return 0
        max_pages = (len(tokens) - 1) // self.page_size
        attached: List[int] = []
        for _, key in self._chain_keys(tokens, max_pages):
            page = self.prefix_index.get(key)
            if page is None:
                break
            attached.append(page)
        if not attached:
            return 0
        if entry is None:
            entry = PageTableEntry(seq_id)
            self.tables[seq_id] = entry
        for page in attached:
            self.ref[page] = self.ref.get(page, 0) + 1
            self.cached.pop(page, None)
        entry.pages.extend(attached)
        entry.length = len(attached) * self.page_size
        entry.shared_tokens = entry.length
        return entry.length

    def commit_prefix(self, seq_id: int, tokens, upto_tokens: int) -> None:
        """Publish ``seq_id``'s fully-written prompt pages (the first
        ``upto_tokens`` are live) into the prefix index so later requests
        can share them.  Idempotent; pages already indexed (their own or
        a colliding chain) are skipped — the sequence then simply keeps a
        private copy."""
        if not self.enable_prefix_cache or tokens is None:
            return
        entry = self.tables.get(seq_id)
        if entry is None:
            return
        n_pages = min(upto_tokens, len(tokens)) // self.page_size
        for p, key in self._chain_keys(tokens, n_pages):
            page = entry.pages[p]
            if page in self.page_key or key in self.prefix_index:
                continue
            self.prefix_index[key] = page
            self.page_key[page] = key
            if self.listener is not None:
                self.listener.on_commit(key, (p + 1) * self.page_size)

    # -- views --------------------------------------------------------------
    def block_table(self, seq_id: int, pages_per_seq: int) -> np.ndarray:
        entry = self.tables[seq_id]
        if len(entry.pages) > pages_per_seq:
            raise ValueError(
                f"seq {seq_id} holds {len(entry.pages)} pages but the block "
                f"table is only {pages_per_seq} wide — a truncated table "
                f"would make the kernel read the wrong pages")
        out = np.zeros(pages_per_seq, np.int32)
        out[: len(entry.pages)] = entry.pages
        return out

    def utilisation(self) -> float:
        """Live-reserved fraction of the pool (capacity pressure)."""
        return self.reserved_pages / self.num_pages

    def live_utilisation(self) -> float:
        """Fraction of the pool holding live KV."""
        return self.used_pages / self.num_pages

    @property
    def reserved_pages(self) -> int:
        """Distinct pages held by live sequences (cached prefix pages are
        reclaimable and excluded)."""
        return self.num_pages - len(self.free) - len(self.cached)

    @property
    def used_pages(self) -> int:
        """Distinct pages backing live KV (tokens actually written or
        mapped from the prefix cache) — shared pages count once."""
        live = set()
        for e in self.tables.values():
            live.update(e.pages[: self.pages_needed(e.length)])
        return len(live)

    @property
    def cached_pages(self) -> int:
        return len(self.cached)
