"""Paged KV cache management (vLLM-style) for the serving engine.

Host-side page-table bookkeeping (free list, per-sequence block tables) plus
device-side page pools consumed by the ``paged_attention`` Pallas kernel.
The dense slot-cache path used by the pure-jnp models shares the same
accounting so admission control sees identical memory pressure either way.

Two occupancy views are exposed (they differ under the dense engine's
conservative prompt+max_new reservation, and under the paged runtime's
grow-on-demand reservation):

  * ``reserved_pages`` — pages taken off the free list (capacity pressure:
    what admission must respect);
  * ``used_pages``     — pages holding live KV (``entry.length`` tokens):
    what the decode kernels actually read.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class PageTableEntry:
    seq_id: int
    pages: List[int] = field(default_factory=list)
    length: int = 0


class PagedKVCache:
    """Page pool allocator: fixed pool of ``num_pages`` pages of
    ``page_size`` tokens each, allocated per sequence on demand."""

    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.tables: Dict[int, PageTableEntry] = {}

    # -- allocation ---------------------------------------------------------
    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        need = self.pages_needed(prompt_len + max_new)
        return len(self.free) >= need

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def allocate(self, seq_id: int, prompt_len: int,
                 reserve_total: int | None = None) -> PageTableEntry:
        """Allocates pages for ``reserve_total`` tokens up front (defaults
        to prompt_len).  Reserving prompt+max_new at admission guarantees
        append_token never exhausts the pool mid-decode (vLLM-conservative
        reservation; admission control enforces the budget)."""
        assert seq_id not in self.tables, f"seq {seq_id} already allocated"
        entry = PageTableEntry(seq_id)
        self.tables[seq_id] = entry
        self._grow(entry, reserve_total or prompt_len)
        entry.length = prompt_len
        return entry

    def append_token(self, seq_id: int) -> None:
        entry = self.tables[seq_id]
        self._grow(entry, entry.length + 1)
        entry.length += 1

    def reserve(self, seq_id: int, target_tokens: int) -> None:
        """Grow a sequence's page list to cover ``target_tokens`` WITHOUT
        marking them live — the paged runtime reserves before launching a
        forward pass (the device scatter needs real page ids), then calls
        :meth:`extend` once the tokens are actually written.  Allocates the
        sequence lazily on first use (the paged runtime does not reserve
        prompt+max_new at submit).  Raises MemoryError when the pool is
        exhausted; partial growth is kept (tracked, released on release())."""
        entry = self.tables.get(seq_id)
        if entry is None:
            entry = PageTableEntry(seq_id)
            self.tables[seq_id] = entry
        self._grow(entry, target_tokens)

    def extend(self, seq_id: int, target_tokens: int) -> None:
        """Mark the sequence as holding ``target_tokens`` live tokens
        (monotone), growing pages if the caller skipped reserve()."""
        entry = self.tables[seq_id]
        self._grow(entry, target_tokens)
        entry.length = max(entry.length, target_tokens)

    def _grow(self, entry: PageTableEntry, target_tokens: int) -> None:
        need = self.pages_needed(target_tokens)
        while len(entry.pages) < need:
            if not self.free:
                raise MemoryError("KV page pool exhausted")
            entry.pages.append(self.free.pop())

    def release(self, seq_id: int) -> None:
        entry = self.tables.pop(seq_id)
        self.free.extend(entry.pages)

    # -- views --------------------------------------------------------------
    def block_table(self, seq_id: int, pages_per_seq: int) -> np.ndarray:
        entry = self.tables[seq_id]
        if len(entry.pages) > pages_per_seq:
            raise ValueError(
                f"seq {seq_id} holds {len(entry.pages)} pages but the block "
                f"table is only {pages_per_seq} wide — a truncated table "
                f"would make the kernel read the wrong pages")
        out = np.zeros(pages_per_seq, np.int32)
        out[: len(entry.pages)] = entry.pages
        return out

    def utilisation(self) -> float:
        """Reserved fraction of the pool (capacity pressure)."""
        return 1.0 - len(self.free) / self.num_pages

    def live_utilisation(self) -> float:
        """Fraction of the pool holding live KV tokens."""
        return self.used_pages / self.num_pages

    @property
    def reserved_pages(self) -> int:
        """Pages off the free list (live KV + reserved-but-unwritten)."""
        return self.num_pages - len(self.free)

    @property
    def used_pages(self) -> int:
        """Pages backing live KV (tokens actually written/accounted)."""
        return sum(self.pages_needed(e.length) for e in self.tables.values())
