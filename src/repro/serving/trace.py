"""Per-request flight recorder: tail attribution across door ->
scheduler -> runtime -> controller.

Every request accrues a *span timeline* — contiguous named segments that
tile ``[arrival, terminal]`` exactly:

    door_queued -> (admitted) -> sched_queued -> prefill_chunk[i]
        -> (preempted/requeued) -> decode -> (spec_verify/rollback)
        -> verdict

and every controller/actuator action (MIG reconfigure with its pause
window, move, MPS/io throttle, arbiter grant) lands on the shared
``controller`` track of the same virtual-clock timeline
(``core/obs.py``).  The contract mirrors the gateway's verdict ledger:

    **conservation invariant** — a request's named segments sum to its
    door-measured latency (terminal - arrival) within float tolerance,

asserted for every finished request (``RequestTimeline.check``), so a
missing instrumentation hook is a test failure, not a silent
attribution gap.  Segment semantics:

* ``door_queued``   — front-door arrival to engine submit (the gap
  between the door- and engine-measured TTFT windows, exactly).
* ``sched_queued``  — admitted but not computing: waiting in the
  scheduler queue, or an in-flight chunked prefill waiting for step
  budget.
* ``prefill_chunk`` — a fused-step window that computed a chunk of this
  request's prompt (args carry the chunk index/offset/length).
* ``preempted``     — evicted by SLO-aware preemption: from the evict
  to the restart prefill completing (the full price of the preemption,
  including recompute wait).
* ``decode``        — decode cadence: every inter-token span, wait and
  compute folded together (matches ``TenantMetrics.itl`` samples).
  Speculative verify/rollback ride as instant events on the segment.
* ``handoff``       — replica death to re-dispatch on a survivor: the
  request's ONE timeline carries across engines (span links across
  replicas), from the crash instant to the redriven submit landing.

The :class:`FlightRecorder` keeps *summaries* (segment sums) for every
request but full timelines only for the slowest-K per tenant per time
window (tail exemplars) plus every request overlapping a controller
action — the ring-buffer discipline that makes always-on tracing
affordable.  Export: Chrome/Perfetto ``trace_event`` JSON
(:meth:`FlightRecorder.dump`) and a per-tenant latency-breakdown table
(:meth:`FlightRecorder.table`: ``p99 = X ms door + Y ms sched + ...``).

Tracing is opt-in and zero-cost when off: every producer call site is
guarded by ``if tracer is not None`` and timestamps are the harness's
own virtual-clock stamps — attaching a recorder never perturbs the
clock, so traced and untraced runs are token- and timing-identical.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.obs import Tracer, TraceEvent, chrome_trace, dump_chrome_trace


@dataclass
class Segment:
    name: str
    t0: float
    t1: float
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass
class Instant:
    name: str
    t: float
    args: Dict[str, Any] = field(default_factory=dict)


class RequestTimeline:
    """Contiguous segment timeline of one request.

    ``span`` closes the current wait (labelled by the request's state)
    up to the span's start, then appends the named segment; ``finish``
    closes the final wait at the terminal stamp.  Contiguity is by
    construction, which is exactly what makes the conservation check
    meaningful: it fails iff a producer stamped out of order or a
    terminal landed twice — the same class of bug the gateway ledger
    catches for verdicts.
    """

    def __init__(self, req_id: int, tenant: str, arrival: float,
                 wait: str = "door_queued"):
        self.req_id = req_id
        self.tenant = tenant
        self.arrival = arrival
        self.segments: List[Segment] = []
        self.instants: List[Instant] = []
        self.cursor = arrival
        self.wait = wait              # label for time not inside a span
        self.verdict: Optional[str] = None
        self.end: Optional[float] = None
        self.first_token_t: Optional[float] = None
        self.preemptions = 0

    # ------------------------------------------------------------ building
    def _fill(self, t: float) -> None:
        if t < self.cursor - 1e-12:
            raise AssertionError(
                f"req {self.req_id} ({self.tenant}): stamp {t} precedes "
                f"cursor {self.cursor} — producer out of order")
        if t > self.cursor:
            self.segments.append(Segment(self.wait, self.cursor, t))
            self.cursor = t

    def span(self, name: str, t1: float, t0: Optional[float] = None,
             **args: Any) -> None:
        """Append a named segment ending at ``t1``.  ``t0`` is the span
        start (wait up to it is labelled with the current state); when
        None the span absorbs the wait from the cursor (decode cadence
        semantics)."""
        if self.end is not None:
            raise AssertionError(
                f"req {self.req_id}: span {name!r} after terminal "
                f"{self.verdict!r}")
        if t0 is not None and t0 > self.cursor:
            self._fill(t0)
        start = self.cursor
        if t1 < start - 1e-12:
            raise AssertionError(
                f"req {self.req_id}: span {name!r} ends at {t1} before "
                f"cursor {start}")
        self.segments.append(Segment(name, start, max(t1, start), args))
        self.cursor = max(t1, start)

    def event(self, name: str, t: float, **args: Any) -> None:
        self.instants.append(Instant(name, t, args))

    def mark(self, t: float, wait: str) -> None:
        """Close the current wait at ``t`` and enter a new wait state."""
        self._fill(t)
        self.wait = wait

    def finish(self, t: float, verdict: str) -> None:
        if self.end is not None:
            raise AssertionError(
                f"req {self.req_id} ({self.tenant}) finished twice: "
                f"{self.verdict!r} then {verdict!r}")
        self._fill(t)
        self.end = t
        self.verdict = verdict
        self.instants.append(Instant("verdict", t, {"verdict": verdict}))

    # ------------------------------------------------------------- queries
    @property
    def e2e(self) -> float:
        assert self.end is not None
        return self.end - self.arrival

    def seg_sums(self, until: Optional[float] = None) -> Dict[str, float]:
        """Per-segment-name duration totals, optionally clipped at
        ``until`` (pass the first-token stamp for the TTFT view)."""
        out: Dict[str, float] = {}
        for s in self.segments:
            t1 = s.t1 if until is None else min(s.t1, until)
            d = t1 - s.t0
            if d > 0:
                out[s.name] = out.get(s.name, 0.0) + d
        return out

    def check(self, tol: float = 1e-6) -> None:
        """Conservation: segments tile [arrival, end] and sum to the
        measured latency.  Mirrors ``Gateway.check()``."""
        assert self.end is not None, f"req {self.req_id} has no terminal"
        prev = self.arrival
        for s in self.segments:
            assert abs(s.t0 - prev) <= tol, (
                f"req {self.req_id} ({self.tenant}): gap before "
                f"{s.name!r} at {s.t0} (previous segment ended {prev})")
            assert s.t1 >= s.t0 - tol
            prev = s.t1
        assert abs(prev - self.end) <= tol, (
            f"req {self.req_id}: last segment ends {prev} != terminal "
            f"{self.end}")
        total = sum(s.dur for s in self.segments)
        assert abs(total - self.e2e) <= tol, (
            f"req {self.req_id} ({self.tenant}): segments sum to "
            f"{total} but measured latency is {self.e2e} "
            f"(conservation violated)")


@dataclass
class RequestSummary:
    """The always-kept per-request record (full timelines are retained
    only for tail exemplars / action overlaps)."""
    req_id: int
    tenant: str
    arrival: float
    end: float
    e2e: float
    verdict: str
    preemptions: int
    segs: Dict[str, float]
    ttft_segs: Dict[str, float]
    ttft: Optional[float]


class FlightRecorder(Tracer):
    """Ring-buffered per-request tracing across the whole serving stack.

    ``keep_slowest`` full timelines are retained per tenant per
    ``window_s`` bucket of terminal time (tail exemplars), plus every
    request whose lifetime overlapped a controller action; summaries
    (bounded deques) are kept for all requests.  All stamps are the
    harness's virtual-clock values — the recorder never reads a clock.
    """

    def __init__(self, keep_slowest: int = 8, window_s: float = 10.0,
                 max_summaries: int = 8192, max_action_exemplars: int = 512):
        super().__init__()
        self.keep_slowest = keep_slowest
        self.window_s = window_s
        self._live: Dict[Tuple[str, int], RequestTimeline] = {}
        self.summaries: Dict[str, deque] = {}
        self._max_summaries = max_summaries
        # recently-finished keys: a producer stamping a request after its
        # terminal must not silently begin a SECOND timeline (the
        # double-terminal bug the gateway ledger catches for verdicts).
        # Bounded like the summaries so always-on tracing stays O(window).
        self._done: set = set()
        self._done_order: deque = deque()
        # (tenant, window index) -> [(e2e, timeline)] slowest-K heap-ish
        self._tail: Dict[Tuple[str, int], List[Tuple[float,
                                                     RequestTimeline]]] = {}
        self.action_exemplars: deque = deque(maxlen=max_action_exemplars)
        self.finished = 0

    # -------------------------------------------------------- lifecycle
    def _key(self, req) -> Tuple[str, int]:
        return (req.tenant, req.req_id)

    def timeline_of(self, req) -> Optional[RequestTimeline]:
        return self._live.get(self._key(req))

    def _timeline(self, req, wait: str = "sched_queued") -> RequestTimeline:
        """Fetch-or-begin.  Requests fronted by a gateway begin in
        ``on_offer``; engine-only harnesses (no door) begin lazily at
        first contact, with the whole pre-compute wait labelled
        ``sched_queued``."""
        key = self._key(req)
        tl = self._live.get(key)
        if tl is None:
            if key in self._done:
                raise AssertionError(
                    f"req {req.req_id} ({req.tenant}): event after "
                    f"terminal — request already finished")
            tl = RequestTimeline(req.req_id, req.tenant, req.arrival,
                                 wait=wait)
            self._live[key] = tl
        return tl

    def on_offer(self, req, now: float, verdict) -> None:
        """Gateway front door: begin the timeline at front-door arrival;
        a terminal verdict at the door (SHED/REJECTED) finishes it on
        the spot — rejected requests conserve too."""
        tl = self._timeline(req, wait="door_queued")
        name = getattr(verdict, "value", str(verdict))
        if name != "accepted":
            self._finish(tl, max(now, tl.cursor), name)
        else:
            tl.event("offered", now)

    def on_admit(self, req, now: float, engine: int = 0) -> None:
        """Door queue -> engine submit landed: the ``door_queued``
        segment closes here, which is exactly ``submitted - arrival`` —
        the gap between the door- and engine-measured TTFT windows."""
        tl = self._timeline(req, wait="door_queued")
        # handoff admits clamp to the cursor: a redriven request's last
        # step on the dead replica may have ENDED past the global clock
        # (engines run in parallel virtual time), leaving the handoff
        # segment zero-length.  Everything else keeps strict ordering.
        t = max(now, tl.cursor) if tl.wait == "handoff" else now
        tl.mark(t, "sched_queued")
        tl.event("admitted", now, engine=engine)

    def on_redrive(self, req, now: float, from_engine: int = -1) -> None:
        """Replica death: the request's timeline survives the engine it
        was running on.  The current wait closes at the crash instant
        and an explicit ``handoff`` segment opens; the re-dispatch's
        ``on_admit`` closes it (span links across replicas — the ONE
        timeline carries across engines, it never restarts)."""
        tl = self._timeline(req)
        t = max(now, tl.cursor)
        tl.mark(t, "handoff")
        tl.event("handoff", t, from_engine=from_engine)

    def on_fault(self, now: float, kind: str, tenant: str = "",
                 **args) -> None:
        """Fault deliveries and recovery actions land as instants on the
        shared controller track, so request timelines can be correlated
        with the chaos schedule the same way they are with controller
        decisions."""
        self.instant(f"fault:{kind}", now, track="controller",
                     lane="faults", tenant=tenant, **args)

    def on_terminal(self, req, now: float, verdict: str,
                    reason: str = "") -> None:
        """A terminal verdict away from the engine (EXPIRED in the door
        queue, REJECTED after a failed submit)."""
        key = self._key(req)
        tl = self._live.get(key)
        if tl is None:
            tl = self._timeline(req, wait="door_queued")
        if reason:
            tl.event("reject", now, reason=reason)
        self._finish(tl, max(now, tl.cursor), verdict)

    def on_preempt(self, req, now: float, beneficiary: int = -1,
                   engine: str = "") -> None:
        """One preemption: close the victim's current phase and open the
        ``preempted`` wait.  Called from :meth:`on_step` for plan-time
        SLO preemptions and directly by the harness when the stuck-lane
        watchdog requeues a hung lane between steps."""
        tl = self._timeline(req)
        t = max(now, tl.cursor)
        tl.mark(t, "preempted")
        tl.preemptions += 1
        tl.event("preempted", t, beneficiary=beneficiary, engine=engine)

    # ------------------------------------------------------------- steps
    def on_step(self, report, start: Optional[float], end: float,
                engine: str = "") -> None:
        """Fold one finalized engine step into every participating
        request's timeline.  ``start``/``end`` are the harness's step
        window stamps (``end`` is the same value ``finalize_step``
        stamps into metrics, so segments and metrics windows agree
        sample-for-sample); ``start=None`` degrades gracefully — spans
        absorb from each request's cursor."""
        # preemptions happen at plan time (step start): the victim's
        # current phase closes and the preempted wait opens
        bene = {v: b for v, b in getattr(report, "preempt_pairs", [])}
        for req in report.preempted:
            self.on_preempt(req, start if start is not None else end,
                            beneficiary=bene.get(req.req_id, -1),
                            engine=engine)
        for req, tok_start, clen, idx in getattr(report, "chunks", []):
            tl = self._timeline(req)
            tl.span("prefill_chunk", end, t0=start, i=idx,
                    token_start=tok_start, tokens=clen,
                    restart=tl.preemptions > 0)
        for req in report.prefilled:
            tl = self._timeline(req)
            tl.mark(end, "decode")
            tl.first_token_t = end
            tl.event("first_token", end)
        spec = {id(r): (d, a) for r, d, a in getattr(report, "spec", [])}
        seen: Dict[int, int] = {}
        for req in report.decoded:
            seen[id(req)] = seen.get(id(req), 0) + 1
        done = set()
        for req in report.decoded:
            if id(req) in done:
                continue
            done.add(id(req))
            tl = self._timeline(req)
            if tl.first_token_t is None:
                # restart decode after preemption: TTFT kept its
                # original stamp, so the first regenerated emission
                # closes the preempted wait instead of re-marking decode
                tl.mark(max(tl.cursor, end), "decode")
                tl.first_token_t = tl.cursor
            tl.span("decode", end, tokens=seen[id(req)])
            if id(req) in spec:
                drafted, accepted = spec[id(req)]
                tl.event("spec_verify", end, drafted=drafted,
                         accepted=accepted)
                if accepted < drafted:
                    tl.event("spec_rollback", end,
                             rejected=drafted - accepted)
        for req in report.completed:
            tl = self._live.get(self._key(req))
            if tl is not None and tl.end is None:
                self._finish(tl, end, "completed")

    # ----------------------------------------------------- finish/retain
    def _finish(self, tl: RequestTimeline, t: float, verdict: str) -> None:
        tl.finish(t, verdict)
        tl.check()
        self.finished += 1
        ttft = (tl.first_token_t - tl.arrival
                if tl.first_token_t is not None else None)
        summ = RequestSummary(
            tl.req_id, tl.tenant, tl.arrival, tl.end, tl.e2e, verdict,
            tl.preemptions, tl.seg_sums(),
            tl.seg_sums(until=tl.first_token_t), ttft)
        dq = self.summaries.setdefault(
            tl.tenant, deque(maxlen=self._max_summaries))
        dq.append(summ)
        key = (tl.tenant, tl.req_id)
        self._live.pop(key, None)
        self._done.add(key)
        self._done_order.append(key)
        if len(self._done_order) > self._max_summaries:
            self._done.discard(self._done_order.popleft())
        # retention: requests overlapping a controller action keep the
        # full trace unconditionally (that correlation is the point)...
        if self.actions_overlapping(tl.arrival, tl.end):
            self.action_exemplars.append(tl)
            return
        # ...everything else competes for the slowest-K exemplar slots
        # of its (tenant, window) bucket
        key = (tl.tenant, int(tl.end // self.window_s))
        bucket = self._tail.setdefault(key, [])
        bucket.append((tl.e2e, tl))
        if len(bucket) > self.keep_slowest:
            bucket.sort(key=lambda p: -p[0])
            del bucket[self.keep_slowest:]

    def retained(self) -> List[RequestTimeline]:
        out = [tl for bucket in self._tail.values() for _, tl in bucket]
        out.extend(self.action_exemplars)
        return sorted(out, key=lambda tl: (tl.tenant, tl.arrival))

    def check(self) -> None:
        """Re-assert conservation on every retained timeline and verify
        every live request is still unterminated (ledger discipline)."""
        for tl in self.retained():
            tl.check()
        for tl in self._live.values():
            assert tl.end is None

    # ----------------------------------------------------------- analysis
    def breakdown(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Per-tenant latency attribution: ``p99 = X ms door + Y ms
        sched + Z ms preempted + ...``.  The tail composition averages
        the segment sums of the completed requests at or above the e2e
        p99 (the tail exemplar population); ``*_ttft`` attributes the
        first-token window the same way."""
        out: Dict[str, Any] = {}
        for tenant, dq in self.summaries.items():
            comp = [s for s in dq if s.verdict == "completed"]
            res: Dict[str, Any] = {
                "finished": len(dq), "completed": len(comp),
                "verdicts": {}, "preemptions": sum(s.preemptions
                                                   for s in dq)}
            for s in dq:
                res["verdicts"][s.verdict] = \
                    res["verdicts"].get(s.verdict, 0) + 1
            if comp:
                e2e = np.array([s.e2e for s in comp])
                p99 = float(np.quantile(e2e, 0.99))
                tail = [s for s in comp if s.e2e >= p99 - 1e-12]
                res.update(
                    e2e_p50_ms=float(np.quantile(e2e, 0.5)) * 1e3,
                    e2e_p99_ms=p99 * 1e3,
                    tail_n=len(tail),
                    tail_ms=_mean_segs(tail, "segs"),
                    mean_ms=_mean_segs(comp, "segs"))
                with_t = [s for s in comp if s.ttft is not None]
                if with_t:
                    ttft = np.array([s.ttft for s in with_t])
                    tp99 = float(np.quantile(ttft, 0.99))
                    ttail = [s for s in with_t if s.ttft >= tp99 - 1e-12]
                    res.update(ttft_p99_ms=tp99 * 1e3,
                               ttft_tail_ms=_mean_segs(ttail, "ttft_segs"))
            out[tenant] = res
        return out

    def segment_quantile(self, tenant: str, segment: str, q: float,
                         verdict: str = "completed") -> float:
        """Quantile of one named segment's per-request duration
        (seconds) — e.g. the ``door_queued`` p99 the --trace benchmark
        arm checks against the two-window TTFT gap."""
        dq = self.summaries.get(tenant, ())
        vals = [s.segs.get(segment, 0.0) for s in dq
                if s.verdict == verdict]
        if not vals:
            return 0.0
        return float(np.quantile(np.asarray(vals), q))

    def table(self, now: Optional[float] = None) -> str:
        """Human-readable per-tenant breakdown table."""
        lines = []
        for tenant, res in sorted(self.breakdown(now).items()):
            if "e2e_p99_ms" not in res:
                lines.append(f"{tenant}: no completed requests "
                             f"({res['verdicts']})")
                continue
            parts = " + ".join(
                f"{ms:.1f} ms {name}" for name, ms in
                sorted(res["tail_ms"].items(), key=lambda kv: -kv[1]))
            lines.append(
                f"{tenant}: p99 = {res['e2e_p99_ms']:.1f} ms "
                f"[tail n={res['tail_n']}: {parts}] "
                f"(completed {res['completed']}/{res['finished']}, "
                f"preemptions {res['preemptions']})")
        return "\n".join(lines)

    # ------------------------------------------------------------- export
    def chrome_events(self) -> List[TraceEvent]:
        """Everything on one timeline: retained request timelines plus
        the shared controller/admission track."""
        evs: List[TraceEvent] = list(self.events)
        for tl in self.retained():
            lane = f"req {tl.req_id}"
            for s in tl.segments:
                evs.append(TraceEvent(s.name, "X", s.t0, s.dur,
                                      tl.tenant, lane, dict(s.args)))
            for i in tl.instants:
                evs.append(TraceEvent(i.name, "i", i.t, 0.0,
                                      tl.tenant, lane, dict(i.args)))
        return evs

    def chrome_trace(self) -> Dict[str, Any]:
        return chrome_trace(self.chrome_events())

    def dump(self, path: str) -> None:
        dump_chrome_trace(self.chrome_events(), path)


def _mean_segs(summaries, attr: str) -> Dict[str, float]:
    """Mean per-segment milliseconds over a summary population."""
    tot: Dict[str, float] = {}
    for s in summaries:
        for name, d in getattr(s, attr).items():
            tot[name] = tot.get(name, 0.0) + d
    n = max(1, len(summaries))
    return {name: d / n * 1e3 for name, d in sorted(tot.items())}
