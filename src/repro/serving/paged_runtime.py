"""Block-table-driven paged serving runtime (the vLLM-style serving core).

Where the dense ``ServingEngine`` path stores KV in a ``[max_slots,
seq_cap]`` slot cache, this runtime keeps every attention layer's KV in a
fixed pool of ``page_size``-token pages (plus one trash page for masked
lanes) and addresses it through per-sequence block tables owned by
``PagedKVCache``.  Decode memory therefore scales with *live tokens*, the
pool can be overcommitted (admission never reserves prompt+max_new up
front), and the block-table width handed to the attention kernel is
bucketed to the longest live sequence, so per-step attention cost tracks
live context rather than ``max_slots x seq_cap``.

ONE forward pass, pure and jitted — the **fused mixed step**: the batch
is the FLATTENED token stream of the step (the vLLM ragged-batch layout):
every decode lane contributes one row, every prefill chunk contributes
``chunk`` rows, all packed back to back under the scheduler's per-step
token budget (``PagedScheduler.plan()``).  Each row carries its own
sequence position and its lane's block table; the rows' K/V are scattered
into the pages, then every row attends its pages through
``kernels/paged_attention/ops.paged_attention_mixed`` with causal masking
*inside the page walk* (a chunk row sees its own chunk's earlier rows
because the scatter lands before the gather and the mask is positional).
Because decode lanes ride in the same call as prefill chunks, an admitted
prompt never stalls the decode lanes — it only consumes the prefill share
of the step budget — which is what keeps ITL tails flat under admission
churn; and because the batch is packed, step cost tracks REAL tokens
(8 decodes + a 64-token chunk cost ~72 rows, not lanes x max-chunk
padding).  Row counts are bucketed (pow2 then /16 granules) so the jit
shape set stays bounded; pad rows write to the trash page and carry
position 0, so they read one valid slot and their output is discarded.

Page pools may be int8 (``kv_dtype="int8"``): K/V rows are quantized
per-row on scatter with the scales stored in parallel per-page-row pools,
and both attention paths dequantize only the gathered pages.

Prefix-cache sharing (``prefix_cache=True``) lives in ``PagedKVCache``:
prompts sharing a page-aligned prefix map it to existing pages and skip
that prefill compute entirely — see ``serving/kvcache.py``.

Speculative multi-token decode lanes (``spec_k > 0``): the scheduler's
n-gram/prompt-lookup drafter attaches up to k proposed tokens to a decode
lane (see ``serving/sched.py``) and the lane rides the SAME fused ragged
step with q_len = 1+k rows — the base feedback token plus the draft, each
row at its own position, causality inside the page walk making row j see
rows < j's freshly-scattered K/V.  Every decode row's logits come back;
the longest draft prefix agreeing with the model's own argmax chain plus
the first correction is committed (token-identical to sequential greedy
decode), and the rejected tail's over-extended pages are rolled back via
``PagedKVCache.truncate`` — the step's fixed cost (plan, page walk,
dispatch) is amortised over up to k+1 tokens, which is what lifts the ITL
floor left after continuous batching.

Only pure-GQA decoder stacks are supported (no MLA / SSM / RWKV mixers, no
sliding windows, no cross-attention): that covers the paper's serving case
study (OLMo-2, StableLM); everything else keeps the dense backend.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels.paged_attention.ops import paged_attention_mixed
from repro.models import attention as attn_mod
from repro.models.common import NO_POLICY, ShardPolicy, apply_rope, rms_norm, shard
from repro.models.model import _apply_ffn, _logits, embed_tokens
from repro.serving.engine import StepReport
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import EXCEEDS_SEQ_CAP, Request, SubmitOutcome
from repro.serving.sched import (PagedScheduler, SchedConfig, bucket_rows,
                                 next_pow2)


def paged_unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """None when the paged runtime can serve this config, else why not."""
    if cfg.encoder is not None:
        return "encoder-decoder models"
    if cfg.frontend.kind != "none":
        return "multimodal frontends"
    if cfg.attn.kind != "gqa":
        return f"attention kind {cfg.attn.kind!r}"
    for layer in cfg.layer_specs():
        if layer.mixer != "attn":
            return f"mixer {layer.mixer!r}"
        if layer.window:
            return "sliding-window layers"
        if layer.cross_attn:
            return "cross-attention layers"
    return None


# row/width bucketing lives in serving/sched.py (the draft planner is
# bucket-aware: rows riding the padding are funded at zero budget cost)
_next_pow2 = next_pow2
_bucket_rows = bucket_rows


class PagedRuntime:
    """One tenant-replica's paged serving state: page pools + scheduler +
    the jitted fused mixed prefill+decode forward pass."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 seq_cap: int = 256, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 step_tokens: Optional[int] = None,
                 policy: ShardPolicy = NO_POLICY, attn_impl: str = "auto",
                 kv_dtype: str = "auto", prefix_cache: bool = True,
                 spec_k: int = 0, spec_ngram: int = 3,
                 response_cache=None, seed: int = 0):
        reason = paged_unsupported_reason(cfg)
        if reason is not None:
            raise ValueError(
                f"paged backend does not support {reason} ({cfg.name}); "
                f"use backend='dense'")
        if kv_dtype not in ("auto", "int8"):
            raise ValueError(f"unknown kv_dtype {kv_dtype!r} "
                             f"(expected 'auto' or 'int8')")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.page = page_size
        self.pps = -(-seq_cap // page_size)          # block-table width cap
        self.seq_cap = self.pps * page_size
        self.max_slots = max_slots
        self.pool_pages = (pool_pages if pool_pages is not None
                           else max_slots * self.pps)
        chunk = chunk_tokens or min(self.seq_cap, 4 * page_size)
        self.chunk = max(page_size, (chunk // page_size) * page_size)
        self.attn_impl = attn_impl
        self.kv_quant = kv_dtype == "int8"
        self.spec_k = spec_k
        self.kv = PagedKVCache(self.pool_pages, page_size,
                               enable_prefix_cache=prefix_cache)
        self.sched = PagedScheduler(
            self.kv, SchedConfig(chunk_tokens=self.chunk,
                                 max_active=max_slots,
                                 step_tokens=step_tokens,
                                 spec_k=spec_k, spec_ngram=spec_ngram),
            response_cache=response_cache)
        self.pools = self._init_pools()
        # donate the pools so the per-step KV scatter updates in place
        # (without aliasing every step would copy the whole page pool,
        # making step cost O(pool) instead of O(live tokens))
        self._mixed_fn = jax.jit(self._mixed_impl, donate_argnums=(1,))
        # executable cache per (rows, width) bucket: the fused step has
        # more shape buckets than the old split prefill/decode passes, so
        # each bucket is AOT-compiled on first sight OUTSIDE the timed
        # region (production runtimes precompile their bucket grid at
        # startup; compile time must not pollute the virtual clock's
        # measured per-step compute)
        self._mixed_exec: Dict[tuple, Any] = {}
        self._rng = np.random.default_rng(seed)

    # ------------------------------------------------------------- pools
    def _init_pools(self) -> Dict[str, Any]:
        a = self.cfg.attn
        dt = jnp.int8 if self.kv_quant else jnp.dtype(self.cfg.dtype)
        shape = (self.pool_pages + 1, self.page, a.num_kv_heads, a.head_dim)
        sshape = (self.pool_pages + 1, self.page, a.num_kv_heads)

        def pool(stack: int = 0):
            s = (stack,) + shape if stack else shape
            d = {"k": jnp.zeros(s, dt), "v": jnp.zeros(s, dt)}
            if self.kv_quant:
                ss = (stack,) + sshape if stack else sshape
                d["k_scale"] = jnp.zeros(ss, jnp.float32)
                d["v_scale"] = jnp.zeros(ss, jnp.float32)
            return d

        pools: Dict[str, Any] = {}
        if self.cfg.prefix:
            pools["prefix"] = {f"layer{i}": pool()
                               for i in range(len(self.cfg.prefix))}
        if self.cfg.period:
            pools["period"] = {f"sub{i}": pool(self.cfg.repeats)
                               for i in range(len(self.cfg.period))}
        return pools

    # ------------------------------------------------------- forward: shared
    def _scatter(self, pool, k, v, page_ids, offs):
        """Write the K/V rows of every valid (lane, row) into the page
        pools (masked rows land on the trash page).  int8 pools quantize
        per-row and store the scales beside the pages."""
        if not self.kv_quant:
            return {**pool,
                    "k": pool["k"].at[page_ids, offs].set(
                        k.astype(pool["k"].dtype)),
                    "v": pool["v"].at[page_ids, offs].set(
                        v.astype(pool["v"].dtype))}
        kq, ks = attn_mod._quantize_kv(k)
        vq, vs = attn_mod._quantize_kv(v)
        return {**pool,
                "k": pool["k"].at[page_ids, offs].set(kq),
                "v": pool["v"].at[page_ids, offs].set(vq),
                "k_scale": pool["k_scale"].at[page_ids, offs].set(
                    ks.astype(jnp.float32)),
                "v_scale": pool["v_scale"].at[page_ids, offs].set(
                    vs.astype(jnp.float32))}

    def _walk_layers(self, params, pools, h, layer_fn):
        """Run ``layer_fn(lp, h, layer, pool) -> (h, pool)`` over the
        prefix layers and the scanned period stack, threading each layer's
        page-pool dict through (the stacked period pools are
        indexed/updated per scan step, mirroring the dense decode path),
        then apply the final norm."""
        cfg = self.cfg
        new_pools = dict(pools)
        if cfg.prefix:
            new_pools["prefix"] = dict(pools["prefix"])
            for i, layer in enumerate(cfg.prefix):
                key = f"layer{i}"
                h, p = layer_fn(params["prefix"][key], h, layer,
                                pools["prefix"][key])
                new_pools["prefix"][key] = p
        if cfg.period:
            def body(carry, xs):
                hh, pp = carry
                lp_stack, idx = xs
                for i, layer in enumerate(cfg.period):
                    sub = f"sub{i}"
                    pool_i = {key: jax.lax.dynamic_index_in_dim(
                        pp[sub][key], idx, 0, keepdims=False)
                        for key in pp[sub]}
                    hh, pool_i = layer_fn(lp_stack[sub], hh, layer, pool_i)
                    pp = {**pp, sub: {
                        key: jax.lax.dynamic_update_index_in_dim(
                            pp[sub][key], pool_i[key], idx, 0)
                        for key in pp[sub]}}
                return (hh, pp), ()

            idxs = jnp.arange(cfg.repeats, dtype=jnp.int32)
            (h, period_pools), _ = jax.lax.scan(
                body, (h, pools["period"]), (params["period"], idxs))
            new_pools["period"] = period_pools
        return rms_norm(h, params["final_norm"], cfg.norm_eps), new_pools

    # ------------------------------------------------ forward: fused mixed
    def _mixed_layer(self, lp, h, layer: LayerSpec, positions, qpos,
                     page_ids, offs, block_tables, pool):
        """One GQA layer over the flattened fused batch: ``h`` is
        [1, T, d] packed token rows, KV via the page pool, causality via
        per-row positions inside the page walk.  Mirrors
        ``attn_mod.gqa_prefill`` numerics (same einsums, same f32 masked
        softmax) with the gathered pages standing in for the in-context
        K/V."""
        cfg, policy = self.cfg, self.policy
        ap = lp["attn"]
        xin = rms_norm(h, lp["norm1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xin, ap["wq"])
        k = jnp.einsum("bsd,dhk->bshk", xin, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xin, ap["wv"])
        q = shard(apply_rope(q, positions, cfg.rope_theta), policy.heads)
        k = apply_rope(k, positions, cfg.rope_theta)
        pool = self._scatter(pool, k[0], v[0], page_ids, offs)
        kwargs = {}
        if self.kv_quant:
            kwargs = dict(k_scales=pool["k_scale"],
                          v_scales=pool["v_scale"])
        # each packed row is its own one-row lane of the ragged kernel.
        # deliberate tradeoff: chunk rows re-gather their lane's pages per
        # row (O(rows x pages) gather traffic) but the batch carries ZERO
        # pad rows; the per-lane Q-block form (one Q=chunk lane, decode
        # lanes padded to Q) amortises the gather but measured ~3x slower
        # on the CPU oracle because padding dominates — on TPU the Q>1
        # kernel path is the one to switch to (see ROADMAP)
        ctx = paged_attention_mixed(q[0][:, None].astype(h.dtype),
                                    pool["k"], pool["v"], block_tables,
                                    qpos[:, None], impl=self.attn_impl,
                                    **kwargs)                 # [T, 1, H, hd]
        out = jnp.einsum("bshk,hkd->bsd",
                         ctx[None, :, 0].astype(h.dtype), ap["wo"])
        h = h + shard(out, policy.act)
        h, _, _ = _apply_ffn(lp, h, layer, cfg, policy)
        return h, pool

    def _mixed_impl(self, params, pools, tokens, positions, n_rows,
                    block_tables, last_rows):
        """tokens/positions [T] int32 — the step's packed token rows
        (T bucketed); n_rows scalar int32 (rows beyond it are padding);
        block_tables [T, W] int32 (each row carries its lane's table,
        W bucketed); last_rows [L] int32 (the row whose logits each lane
        needs).  Returns (logits [L, V], pools)."""
        cfg, policy = self.cfg, self.policy
        t = tokens.shape[0]
        width = block_tables.shape[1]
        valid = jnp.arange(t, dtype=jnp.int32) < n_rows
        slot = jnp.clip(positions // self.page, 0, width - 1)
        page_ids = jnp.where(valid, block_tables[jnp.arange(t), slot],
                             self.pool_pages)
        offs = positions % self.page
        # pad rows read slot 0 of their (zero) table so the online softmax
        # never sees an empty row; their output is discarded
        qpos = jnp.where(valid, positions, 0)
        positions2 = qpos[None]
        h = embed_tokens(params, cfg, tokens[None], policy)
        h, new_pools = self._walk_layers(
            params, pools, h,
            lambda lp, hh, layer, pool: self._mixed_layer(
                lp, hh, layer, positions2, qpos, page_ids, offs,
                block_tables, pool))
        h_last = h[0][last_rows][None]                   # [1, L, d]
        logits = _logits(params, cfg, h_last, policy)[0]
        return logits, new_pools

    # ------------------------------------------------------------ engine API
    def submit(self, req: Request) -> SubmitOutcome:
        """Rejects only requests that can NEVER fit (footprint beyond the
        block-table width or the whole pool); pool pressure is resolved
        later by SLO-aware preemption instead of at submit.  Rejections
        carry their reason — both are structural (non-transient)."""
        if req.prompt_len + req.max_new_tokens > self.seq_cap:
            return EXCEEDS_SEQ_CAP
        if req.prompt_tokens is None:
            # materialise synthetic prompts once so every chunk (and any
            # post-preemption recompute) sees identical tokens
            req.prompt_tokens = self._rng.integers(
                0, self.cfg.vocab_size, req.prompt_len)
        return self.sched.submit(req)

    def has_work(self) -> bool:
        return self.sched.has_work()

    def running(self) -> List[Request]:
        return self.sched.running()

    @property
    def queue(self):
        return self.sched.waiting

    def set_budget(self, n: int) -> None:
        self.sched.set_budget(n)

    def drain_for_redrive(self) -> List[Request]:
        """Replica death: release every page and hand back the resident
        requests for the dispatcher to redrive (see
        ``PagedScheduler.drain_for_redrive``)."""
        return self.sched.drain_for_redrive()

    # ------------------------------------------------------------ fused step
    def _run_mixed(self, tokens, positions, n_rows, bts, last_rows):
        """Execute the fused forward for this (rows, width, logit-rows)
        bucket, AOT-compiling the bucket on first sight so compile time
        never enters the measured compute.  Returns (logits, compute_s)."""
        key = (tokens.shape[0], bts.shape[1], last_rows.shape[0])
        fn = self._mixed_exec.get(key)
        if fn is None:
            fn = self._mixed_fn.lower(
                self.params, self.pools, tokens, positions, n_rows, bts,
                last_rows).compile()
            self._mixed_exec[key] = fn
        t0 = time.perf_counter()
        logits, self.pools = fn(self.params, self.pools, tokens, positions,
                                n_rows, bts, last_rows)
        logits = jax.block_until_ready(logits)
        return logits, time.perf_counter() - t0

    def step(self) -> StepReport:
        log_mark = len(self.sched.preempt_log)
        plan = self.sched.plan()
        report = StepReport(kind="idle")
        report.preempted = [s.req for s in plan.preempted]
        # every preemption happens inside plan(): the log's new tail is
        # exactly this step's (victim, beneficiary) pairs — the flight
        # recorder attaches the beneficiary to the victim's timeline
        report.preempt_pairs = list(self.sched.preempt_log[log_mark:])
        report.prefix_hit_tokens = plan.prefix_hit_tokens
        if plan.empty:
            return report
        decodes, prefills = plan.decodes, plan.prefills
        report.kind = ("mixed" if decodes and prefills
                       else "decode" if decodes else "prefill")

        # pack the step's real tokens back to back: 1+len(draft) rows per
        # decode lane (the base feedback token plus its speculative
        # verify rows), ``clen`` rows per prefill chunk — cost tracks
        # live tokens, and the row/width/logit buckets keep the jit shape
        # set bounded
        n_rows = sum(1 + len(s.draft) for s in decodes) \
            + sum(c for _, _, c in prefills)
        # every decode row needs its logits for verification; prefill
        # chunks only need their final row's
        n_logits = sum(1 + len(s.draft) for s in decodes) + len(prefills)
        t = _bucket_rows(n_rows)
        tokens = np.zeros(t, np.int32)
        positions = np.zeros(t, np.int32)
        last_rows = np.zeros(_bucket_rows(n_logits), np.int32)
        lanes: List[tuple] = []
        row_of: List[tuple] = []          # (row_start, n) per lane
        row = 0
        li = 0                            # next logit-row slot
        max_pages = 1
        for s in decodes:
            q = 1 + len(s.draft)          # verify q_len for this lane
            lanes.append(("d", s, li, q))
            pos = s.req.prompt_len + s.req.generated - 1
            tokens[row] = s.last_token
            if s.draft:
                tokens[row + 1:row + q] = np.asarray(s.draft, np.int32)
            positions[row:row + q] = pos + np.arange(q, dtype=np.int32)
            last_rows[li:li + q] = row + np.arange(q, dtype=np.int32)
            li += q
            row_of.append((row, q))
            row += q
            max_pages = max(max_pages, self.kv.pages_needed(pos + q))
        for s, start, clen in prefills:
            lanes.append(("p", s, start, clen, li))
            tokens[row:row + clen] = np.asarray(
                s.req.prompt_tokens, np.int32)[start:start + clen]
            positions[row:row + clen] = start + np.arange(clen,
                                                          dtype=np.int32)
            last_rows[li] = row + clen - 1
            li += 1
            row_of.append((row, clen))
            row += clen
            max_pages = max(max_pages, self.kv.pages_needed(start + clen))
        width = min(self.pps, _next_pow2(max_pages))
        bts = np.zeros((t, width), np.int32)
        for (r0, n), lane in zip(row_of, lanes):
            bts[r0:r0 + n] = self.kv.block_table(lane[1].req.req_id, width)

        logits, report.compute_s = self._run_mixed(
            jnp.asarray(tokens), jnp.asarray(positions), np.int32(n_rows),
            jnp.asarray(bts), jnp.asarray(last_rows))
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))

        for lane in lanes:
            if lane[0] == "d":
                _, s, li, q = lane
                d = s.draft
                # greedy verify: row j's argmax is the model's token for
                # position pos+j+1 GIVEN the draft prefix d[:j]; the
                # longest draft prefix matching the model's own argmax
                # chain is exactly what sequential decode would have
                # produced, so committing it (plus the first
                # disagreement's correction — the "bonus" token) is
                # token-identical to non-speculative decode
                g = [int(next_tokens[li + j]) for j in range(q)]
                a = 0
                while a < len(d) and d[a] == g[a]:
                    a += 1
                m = min(a + 1, s.req.max_new_tokens - s.req.generated)
                committed = g[:m]
                if d:
                    report.spec.append((s.req, len(d), m - 1))
                    self.sched.commit_verified(s, m, drafted=len(d),
                                               accepted=m - 1)
                else:
                    self.sched.commit_decode(s)
                s.last_token = committed[-1]
                s.req.generated += m
                s.req.output_tokens.extend(committed)
                report.decode_tokens += m
                report.tokens += m
                report.drafted_tokens += len(d)
                report.accepted_tokens += m - 1
                # one decoded entry per committed token: finalize_step
                # stamps them all with this step's end time, so a burst's
                # 2nd..mth tokens record ~zero inter-token latency (the
                # whole point of amortising the per-step fixed cost)
                report.decoded.extend([s.req] * m)
                if s.req.generated >= s.req.max_new_tokens:
                    self.sched.complete(s)
                    report.completed.append(s.req)
            else:
                _, s, start, clen, li = lane
                report.chunks.append((s.req, start, clen, s.chunks_done))
                self.sched.finish_chunk(s, clen)
                report.prefill_tokens += clen
                report.tokens += clen
                if s.prefilled >= s.req.prompt_len:   # final chunk: 1st token
                    first = int(next_tokens[li])
                    s.last_token = first
                    s.req.generated = 1
                    s.req.output_tokens.append(first)
                    # a restart after preemption regenerates the SAME first
                    # token, so only a fresh emission defines TTFT
                    if s.req.prefill_done < 0:
                        report.prefilled.append(s.req)
                    if s.req.generated >= s.req.max_new_tokens:
                        self.sched.complete(s)
                        report.completed.append(s.req)
        return report
