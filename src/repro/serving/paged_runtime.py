"""Block-table-driven paged decode runtime (the vLLM-style serving core).

Where the dense ``ServingEngine`` path stores KV in a ``[max_slots,
seq_cap]`` slot cache, this runtime keeps every attention layer's KV in a
fixed pool of ``page_size``-token pages (plus one trash page for masked
lanes) and addresses it through per-sequence block tables owned by
``PagedKVCache``.  Decode memory therefore scales with *live tokens*, the
pool can be overcommitted (admission never reserves prompt+max_new up
front), and the block-table width handed to the attention kernel is
bucketed to the longest live sequence, so per-step attention cost tracks
live context rather than ``max_slots x seq_cap``.

Three forward passes, all pure and jitted:

* ``prefill chunk`` — ``chunk_tokens`` prompt tokens at a time (padded to a
  fixed width so one compilation serves every chunk): scatter the chunk's
  K/V into the pages, then attend over the pages gathered through the
  block table.  Interleaving chunks with decode steps is the scheduler's
  job (``serving/sched.py``).
* ``decode step`` — one token for every active sequence, batched to
  ``max_slots`` lanes; attention runs through
  ``kernels/paged_attention/ops.paged_attention`` (Pallas kernel on TPU /
  interpret mode, jnp oracle as the CPU fallback — ``attn_impl``).
* masked lanes write to the trash page and carry ``length=1`` so the
  online softmax never sees an empty sequence.

Only pure-GQA decoder stacks are supported (no MLA / SSM / RWKV mixers, no
sliding windows, no cross-attention): that covers the paper's serving case
study (OLMo-2, StableLM); everything else keeps the dense backend.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import attention as attn_mod
from repro.models.common import NO_POLICY, ShardPolicy, apply_rope, rms_norm, shard
from repro.models.model import _apply_ffn, _logits, embed_tokens
from repro.serving.engine import StepReport
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request
from repro.serving.sched import PagedScheduler, SchedConfig


def paged_unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """None when the paged runtime can serve this config, else why not."""
    if cfg.encoder is not None:
        return "encoder-decoder models"
    if cfg.frontend.kind != "none":
        return "multimodal frontends"
    if cfg.attn.kind != "gqa":
        return f"attention kind {cfg.attn.kind!r}"
    for layer in cfg.layer_specs():
        if layer.mixer != "attn":
            return f"mixer {layer.mixer!r}"
        if layer.window:
            return "sliding-window layers"
        if layer.cross_attn:
            return "cross-attention layers"
    return None


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class PagedRuntime:
    """One tenant-replica's paged serving state: page pools + scheduler +
    jitted chunk-prefill / batched-decode forward passes."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 seq_cap: int = 256, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 policy: ShardPolicy = NO_POLICY, attn_impl: str = "auto",
                 seed: int = 0):
        reason = paged_unsupported_reason(cfg)
        if reason is not None:
            raise ValueError(
                f"paged backend does not support {reason} ({cfg.name}); "
                f"use backend='dense'")
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.page = page_size
        self.pps = -(-seq_cap // page_size)          # block-table width cap
        self.seq_cap = self.pps * page_size
        self.max_slots = max_slots
        self.pool_pages = (pool_pages if pool_pages is not None
                           else max_slots * self.pps)
        chunk = chunk_tokens or min(self.seq_cap, 4 * page_size)
        self.chunk = max(page_size, (chunk // page_size) * page_size)
        self.attn_impl = attn_impl
        self.kv = PagedKVCache(self.pool_pages, page_size)
        self.sched = PagedScheduler(
            self.kv, SchedConfig(chunk_tokens=self.chunk,
                                 max_active=max_slots))
        self.pools = self._init_pools()
        # donate the pools so the per-step KV scatter updates in place
        # (without aliasing every step would copy the whole page pool,
        # making step cost O(pool) instead of O(live tokens))
        self._prefill_fn = jax.jit(self._prefill_impl, donate_argnums=(1,))
        self._decode_fn = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._rng = np.random.default_rng(seed)

    # ------------------------------------------------------------- pools
    def _init_pools(self) -> Dict[str, Any]:
        a = self.cfg.attn
        dt = jnp.dtype(self.cfg.dtype)
        shape = (self.pool_pages + 1, self.page, a.num_kv_heads, a.head_dim)

        def pool(stack: int = 0):
            s = (stack,) + shape if stack else shape
            return {"k": jnp.zeros(s, dt), "v": jnp.zeros(s, dt)}

        pools: Dict[str, Any] = {}
        if self.cfg.prefix:
            pools["prefix"] = {f"layer{i}": pool()
                               for i in range(len(self.cfg.prefix))}
        if self.cfg.period:
            pools["period"] = {f"sub{i}": pool(self.cfg.repeats)
                               for i in range(len(self.cfg.period))}
        return pools

    # ------------------------------------------------------- forward: shared
    def _scatter(self, kp, vp, k, v, page_ids, offs):
        """Write one K/V row per lane/token into the page pools."""
        kp = kp.at[page_ids, offs].set(k.astype(kp.dtype))
        vp = vp.at[page_ids, offs].set(v.astype(vp.dtype))
        return kp, vp

    # ------------------------------------------------ forward: prefill chunk
    def _prefill_layer(self, lp, h, layer: LayerSpec, positions2, page_ids,
                       offs, block_table, kp, vp):
        """One GQA layer over a prompt chunk, KV via the page pool.

        Mirrors ``attn_mod.gqa_prefill`` numerics exactly (same einsums,
        same ``_attend_block``), with the gathered pages standing in for
        the chunk-local K/V: gathered slot t holds sequence position t, so
        the causal mask alone excludes stale/unwritten slots."""
        cfg, policy = self.cfg, self.policy
        a = cfg.attn
        scale = 1.0 / jnp.sqrt(a.head_dim).astype(jnp.float32)
        xin = rms_norm(h, lp["norm1"], cfg.norm_eps)
        ap = lp["attn"]
        q = jnp.einsum("bsd,dhk->bshk", xin, ap["wq"])
        k = jnp.einsum("bsd,dhk->bshk", xin, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xin, ap["wv"])
        q = shard(apply_rope(q, positions2, cfg.rope_theta), policy.heads)
        k = apply_rope(k, positions2, cfg.rope_theta)
        kp, vp = self._scatter(kp, vp, k[0], v[0], page_ids, offs)
        t = block_table.shape[0] * self.page
        k_all = kp[block_table].reshape(t, a.num_kv_heads, a.head_dim)[None]
        v_all = vp[block_table].reshape(t, a.num_kv_heads, a.head_dim)[None]
        pos_k = jnp.arange(t, dtype=jnp.int32)[None]
        qg = attn_mod._split_heads(q, a.num_kv_heads)
        ctx = attn_mod._attend_block(qg, k_all.astype(h.dtype),
                                     v_all.astype(h.dtype), positions2, pos_k,
                                     scale, a, layer, True, h.dtype)
        ctx = ctx.reshape(1, -1, a.num_heads, a.head_dim)
        out = jnp.einsum("bshk,hkd->bsd", ctx, ap["wo"])
        h = h + shard(out, policy.act)
        h, _, _ = _apply_ffn(lp, h, layer, cfg, policy)
        return h, kp, vp

    def _walk_layers(self, params, pools, h, layer_fn):
        """Run ``layer_fn(lp, h, layer, kp, vp) -> (h, kp, vp)`` over the
        prefix layers and the scanned period stack, threading each layer's
        page pool through (the stacked period pools are indexed/updated
        per scan step, mirroring the dense decode path), then apply the
        final norm.  Shared by the chunk-prefill and decode forwards."""
        cfg = self.cfg
        new_pools = dict(pools)
        if cfg.prefix:
            new_pools["prefix"] = dict(pools["prefix"])
            for i, layer in enumerate(cfg.prefix):
                key = f"layer{i}"
                p = pools["prefix"][key]
                h, kp, vp = layer_fn(params["prefix"][key], h, layer,
                                     p["k"], p["v"])
                new_pools["prefix"][key] = {"k": kp, "v": vp}
        if cfg.period:
            def body(carry, xs):
                hh, pp = carry
                lp_stack, idx = xs
                for i, layer in enumerate(cfg.period):
                    sub = f"sub{i}"
                    kp = jax.lax.dynamic_index_in_dim(pp[sub]["k"], idx, 0,
                                                      keepdims=False)
                    vp = jax.lax.dynamic_index_in_dim(pp[sub]["v"], idx, 0,
                                                      keepdims=False)
                    hh, kp, vp = layer_fn(lp_stack[sub], hh, layer, kp, vp)
                    pp = {**pp, sub: {
                        "k": jax.lax.dynamic_update_index_in_dim(
                            pp[sub]["k"], kp, idx, 0),
                        "v": jax.lax.dynamic_update_index_in_dim(
                            pp[sub]["v"], vp, idx, 0)}}
                return (hh, pp), ()

            idxs = jnp.arange(cfg.repeats, dtype=jnp.int32)
            (h, period_pools), _ = jax.lax.scan(
                body, (h, pools["period"]), (params["period"], idxs))
            new_pools["period"] = period_pools
        return rms_norm(h, params["final_norm"], cfg.norm_eps), new_pools

    def _prefill_impl(self, params, pools, tokens, start, valid, block_table):
        """tokens [C] int32 (padded chunk); start/valid scalars int32;
        block_table [PPS].  Returns (last-valid-token logits [V], pools)."""
        cfg, policy = self.cfg, self.policy
        c = tokens.shape[0]
        positions = start + jnp.arange(c, dtype=jnp.int32)
        positions2 = positions[None]
        wmask = jnp.arange(c, dtype=jnp.int32) < valid
        page_ids = jnp.where(wmask, block_table[positions // self.page],
                             self.pool_pages)
        offs = positions % self.page
        h = embed_tokens(params, cfg, tokens[None], policy)
        h, new_pools = self._walk_layers(
            params, pools, h,
            lambda lp, hh, layer, kp, vp: self._prefill_layer(
                lp, hh, layer, positions2, page_ids, offs, block_table,
                kp, vp))
        h_last = jax.lax.dynamic_slice_in_dim(h, valid - 1, 1, axis=1)
        logits = _logits(params, cfg, h_last, policy)[0, 0]
        return logits, new_pools

    # ---------------------------------------------------- forward: decode
    def _decode_layer(self, lp, h, layer: LayerSpec, positions, page_ids,
                      offs, block_tables, lengths, kp, vp):
        cfg, policy = self.cfg, self.policy
        a = cfg.attn
        xin = rms_norm(h, lp["norm1"], cfg.norm_eps)
        ap = lp["attn"]
        pos2 = positions[:, None]
        q = jnp.einsum("bsd,dhk->bshk", xin, ap["wq"])
        k = jnp.einsum("bsd,dhk->bshk", xin, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xin, ap["wv"])
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)
        kp, vp = self._scatter(kp, vp, k[:, 0], v[:, 0], page_ids, offs)
        ctx = paged_attention(q[:, 0].astype(h.dtype), kp, vp, block_tables,
                              lengths, impl=self.attn_impl)    # [B, H, hd]
        out = jnp.einsum("bshk,hkd->bsd", ctx[:, None].astype(h.dtype),
                         ap["wo"])
        h = h + shard(out, policy.act)
        h, _, _ = _apply_ffn(lp, h, layer, cfg, policy)
        return h, kp, vp

    def _decode_impl(self, params, pools, tokens, positions, block_tables,
                     lengths, active):
        """tokens/positions/lengths [B] int32, block_tables [B, W] int32
        (W bucketed), active [B] bool.  Returns (logits [B, V], pools)."""
        cfg, policy = self.cfg, self.policy
        b = tokens.shape[0]
        bidx = jnp.arange(b)
        width = block_tables.shape[1]
        slot = jnp.clip(positions // self.page, 0, width - 1)
        page_ids = jnp.where(active, block_tables[bidx, slot],
                             self.pool_pages)
        offs = positions % self.page
        lens = jnp.maximum(jnp.where(active, lengths, 1), 1)
        h = embed_tokens(params, cfg, tokens[:, None], policy)
        h, new_pools = self._walk_layers(
            params, pools, h,
            lambda lp, hh, layer, kp, vp: self._decode_layer(
                lp, hh, layer, positions, page_ids, offs, block_tables,
                lens, kp, vp))
        logits = _logits(params, cfg, h, policy)[:, 0]
        return logits, new_pools

    # ------------------------------------------------------------ engine API
    def submit(self, req: Request) -> bool:
        """Rejects only requests that can NEVER fit (footprint beyond the
        block-table width or the whole pool); pool pressure is resolved
        later by SLO-aware preemption instead of at submit."""
        if req.prompt_len + req.max_new_tokens > self.seq_cap:
            return False
        if req.prompt_tokens is None:
            # materialise synthetic prompts once so every chunk (and any
            # post-preemption recompute) sees identical tokens
            req.prompt_tokens = self._rng.integers(
                0, self.cfg.vocab_size, req.prompt_len)
        return self.sched.submit(req)

    def has_work(self) -> bool:
        return self.sched.has_work()

    def running(self) -> List[Request]:
        return self.sched.running()

    @property
    def queue(self):
        return self.sched.waiting

    def set_budget(self, n: int) -> None:
        self.sched.set_budget(n)

    def step(self) -> StepReport:
        kind = self.sched.plan()
        if kind == "prefill":
            rep = self._step_prefill()
            if rep is not None:
                return rep
            kind = "decode" if self.sched.active else "idle"
        if kind == "decode":
            return self._step_decode()
        return StepReport(kind="idle")

    # ------------------------------------------------------------ internals
    def _step_prefill(self) -> Optional[StepReport]:
        seq, start, clen = self.sched.next_chunk()
        req = seq.req
        ok, victims = self.sched.reserve_for_prefill(seq, start + clen)
        if not ok:
            if victims:      # partial eviction still happened: surface it
                rep = StepReport(kind="idle")
                rep.preempted = [s.req for s in victims]
                return rep
            return None     # every page held by more-urgent work; decode on
        # bucket the padded chunk width and the block-table width to the
        # actual work (powers of two -> bounded recompiles), so a short
        # prompt/chunk doesn't pay the full chunk_tokens x seq_cap forward
        cb = min(self.chunk,
                 self.page * _next_pow2(self.kv.pages_needed(clen)))
        width = min(self.pps, _next_pow2(self.kv.pages_needed(start + cb)))
        bt = jnp.asarray(self.kv.block_table(req.req_id, width))
        toks = np.zeros(cb, np.int32)
        toks[:clen] = np.asarray(req.prompt_tokens, np.int32)[start:start + clen]
        t0 = time.perf_counter()
        logits, self.pools = self._prefill_fn(
            self.params, self.pools, jnp.asarray(toks), np.int32(start),
            np.int32(clen), bt)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.sched.finish_chunk(seq, clen)
        report = StepReport(kind="prefill", compute_s=dt, tokens=clen)
        report.preempted = [s.req for s in victims]
        if seq.prefilled >= req.prompt_len:        # final chunk: first token
            first = int(jnp.argmax(logits))
            seq.last_token = first
            req.generated = 1
            req.output_tokens.append(first)
            # a restart after preemption regenerates the SAME first token,
            # so only a fresh emission defines TTFT (no second sample)
            if req.prefill_done < 0:
                report.prefilled = req
            if req.generated >= req.max_new_tokens:
                self.sched.complete(seq)
                report.completed.append(req)
        return report

    def _step_decode(self) -> StepReport:
        ready, preempted = self.sched.reserve_for_decode()
        report = StepReport(kind="decode")
        report.preempted = [s.req for s in preempted]
        if not ready:
            report.kind = "idle"
            return report
        b = self.max_slots
        tokens = np.zeros(b, np.int32)
        positions = np.zeros(b, np.int32)
        lengths = np.ones(b, np.int32)
        active = np.zeros(b, bool)
        max_pages = 1
        for i, s in enumerate(ready):
            pos = s.req.prompt_len + s.req.generated - 1
            tokens[i] = s.last_token
            positions[i] = pos
            lengths[i] = pos + 1
            active[i] = True
            max_pages = max(max_pages, self.kv.pages_needed(pos + 1))
        # bucket the block-table width so decode cost tracks the longest
        # LIVE sequence (few power-of-two recompiles), not the seq cap
        width = min(self.pps, _next_pow2(max_pages))
        bts = np.zeros((b, width), np.int32)
        for i, s in enumerate(ready):
            bts[i] = self.kv.block_table(s.req.req_id, width)
        t0 = time.perf_counter()
        logits, self.pools = self._decode_fn(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(bts), jnp.asarray(lengths),
            jnp.asarray(active))
        logits = jax.block_until_ready(logits)
        report.compute_s = time.perf_counter() - t0
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for i, s in enumerate(ready):
            self.sched.commit_decode(s)
            tok = int(next_tokens[i])
            s.last_token = tok
            s.req.generated += 1
            s.req.output_tokens.append(tok)
            report.tokens += 1
            report.decoded.append(s.req)
            if s.req.generated >= s.req.max_new_tokens:
                self.sched.complete(s)
                report.completed.append(s.req)
        return report
