"""Cluster-wide KV reuse: prefix-cache directory, cache-aware routing,
and a cross-request response cache.

Each replica's ``PagedKVCache`` prefix cache is private — without this
layer a tenant with R replicas re-prefills the same system prompt R
times, and the least-loaded dispatcher is blind to which replica already
holds a request's KV.  Three pieces close that gap:

* :class:`PrefixDirectory` — a per-tenant map from **content-hashed**
  page-aligned prefix chains to the replicas holding them.  Hashes are
  derived from token *content* (chained blake2b per page), so two
  replicas that independently prefilled the same prompt publish the
  same key, and the dispatcher can compare holdings across replicas
  without ever seeing a page id.  The directory is fed by
  ``PagedKVCache`` listener events (``commit_prefix`` publishes,
  cached-page eviction retracts) and is **stale-but-safe by
  construction**: a stale "holds" entry routes a request to a replica
  that merely misses its prefix cache (tokens are unaffected — the
  prefix cache itself re-verifies content by chain key), and a missing
  entry just falls back to least-loaded.  ``defer_events=True`` buffers
  events until :meth:`~PrefixDirectory.sync` — the directory's pending
  backlog is its *staleness* measure, which the router bounds.

* :class:`CacheAwareRouter` — route-to-longest-held-prefix dispatch
  with least-loaded fallback.  The cache route is taken only when the
  directory is fresh enough (``staleness_bound``) and the target's load
  lead over the least-loaded replica is within ``imbalance_bound``;
  every decision is counted (routed vs each fallback reason) so the
  policy is observable.  All tie-breaks are a **strict total order**
  ending in the replica index, so identical traces route identically.

* :class:`ResponseCache` — (tenant, prompt-hash, params) -> the
  committed output tokens of a finished request.  On a later identical
  request it auto-primes ``Request.draft_hints``, so templated
  production traffic rides the existing NgramDrafter/verify path at
  near-100% acceptance *without client cooperation* — the model still
  verifies every drafted token, so a stale cached response costs
  rejected draft rows, never a wrong output token.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serving.metrics import DirectoryStats, RoutingStats
from repro.serving.request import Request

_EMPTY_HASH = 0


def _page_hash(parent: int, chunk) -> int:
    """Content hash of one more page chained onto ``parent``'s hash.
    blake2b (not Python ``hash``) so the value is stable across
    processes — a real deployment gossips these between hosts."""
    data = parent.to_bytes(8, "little") + \
        np.asarray(chunk, np.int64).tobytes()
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "little")


def prefix_hashes(tokens, page_size: int,
                  max_pages: Optional[int] = None) -> List[int]:
    """Chained content hash of each full page-aligned prefix of
    ``tokens`` (element ``p`` covers pages ``0..p``).  Matches the hash
    a :class:`PagedKVCache` listener derives for the same content via
    :func:`chain_key_hash`, so the dispatcher can compute a request's
    keys from its prompt alone."""
    if tokens is None:
        return []
    n = len(tokens) // page_size
    if max_pages is not None:
        n = min(n, max_pages)
    out: List[int] = []
    h = _EMPTY_HASH
    for p in range(n):
        h = _page_hash(h, tokens[p * page_size:(p + 1) * page_size])
        out.append(h)
    return out


def chain_key_hash(key: tuple) -> int:
    """The same content hash, derived from a ``PagedKVCache`` prefix
    chain key (the recursive ``(parent_key, page_tokens)`` tuple)."""
    chunks = []
    while key is not None:
        key, chunk = key
        chunks.append(chunk)
    h = _EMPTY_HASH
    for chunk in reversed(chunks):
        h = _page_hash(h, chunk)
    return h


def prompt_hash(tokens) -> int:
    """Stable content hash of a whole prompt (response-cache key)."""
    data = np.asarray(tokens, np.int64).tobytes()
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "little")


class _CacheListener:
    """Binds one replica's ``PagedKVCache`` events to the directory."""

    def __init__(self, directory: "PrefixDirectory", tenant: str,
                 replica: int):
        self.directory = directory
        self.tenant = tenant
        self.replica = replica

    def on_commit(self, chain_key: tuple, upto_tokens: int) -> None:
        self.directory.publish(self.tenant, self.replica,
                               chain_key_hash(chain_key))

    def on_evict(self, chain_key: tuple) -> None:
        self.directory.retract(self.tenant, self.replica,
                               chain_key_hash(chain_key))


class PrefixDirectory:
    """Per-tenant map: page-chain content hash -> replicas holding it.

    ``defer_events=True`` models the distributed reality (the directory
    service lags the replicas): events queue until :meth:`sync`, and
    ``staleness()`` — the pending backlog — is what the router bounds.
    The default applies events immediately (staleness 0)."""

    def __init__(self, page_size: int, defer_events: bool = False):
        self.page_size = page_size
        self.defer_events = defer_events
        self._holders: Dict[Tuple[str, int], Set[int]] = {}
        self._pending: Deque[Tuple[str, str, int, int]] = deque()
        self.stats = DirectoryStats()

    # ----------------------------------------------------------- wiring
    def attach(self, tenant: str, replica: int, kv) -> None:
        """Subscribe to one replica's prefix-cache commit/evict events."""
        kv.listener = _CacheListener(self, tenant, replica)

    # ----------------------------------------------------------- events
    def publish(self, tenant: str, replica: int, h: int) -> None:
        if self.defer_events:
            self._pending.append(("pub", tenant, replica, h))
        else:
            self._apply("pub", tenant, replica, h)

    def retract(self, tenant: str, replica: int, h: int) -> None:
        if self.defer_events:
            self._pending.append(("ret", tenant, replica, h))
        else:
            self._apply("ret", tenant, replica, h)

    def _apply(self, op: str, tenant: str, replica: int, h: int) -> None:
        key = (tenant, h)
        if op == "pub":
            self._holders.setdefault(key, set()).add(replica)
            self.stats.published += 1
        else:
            rs = self._holders.get(key)
            if rs is not None:
                rs.discard(replica)
                if not rs:
                    del self._holders[key]
            self.stats.retracted += 1

    def retract_replica(self, tenant: str, replica: int) -> int:
        """Replica death: drop every holding of ``replica`` under
        ``tenant`` immediately.  Applied authoritatively — it bypasses
        ``defer_events`` and also purges the dead replica's *pending*
        events, so a queued publish cannot resurrect a dead holder at
        the next :meth:`sync`.  Returns the chains retracted."""
        n = 0
        for key in list(self._holders):
            if key[0] != tenant:
                continue
            rs = self._holders[key]
            if replica in rs:
                rs.discard(replica)
                self.stats.retracted += 1
                n += 1
                if not rs:
                    del self._holders[key]
        if self._pending:
            self._pending = deque(
                ev for ev in self._pending
                if not (ev[1] == tenant and ev[2] == replica))
        return n

    def staleness(self) -> int:
        """Pending (unapplied) events — 0 unless ``defer_events``."""
        return len(self._pending)

    def sync(self) -> int:
        """Apply all pending events; returns how many were applied."""
        n = len(self._pending)
        while self._pending:
            self._apply(*self._pending.popleft())
        return n

    # ----------------------------------------------------------- lookup
    def holders(self, tenant: str, h: int) -> Set[int]:
        return set(self._holders.get((tenant, h), ()))

    def lookup(self, tenant: str, tokens) -> Dict[int, int]:
        """Replica -> prompt tokens held as a CONTIGUOUS page-aligned
        prefix (a replica whose chain has a gap only counts up to the
        gap — exactly what ``match_prefix`` would attach).  At least the
        final token is always left uncovered, mirroring the prefix
        cache's TTFT = O(tail) contract."""
        self.stats.lookups += 1
        if tokens is None:
            return {}
        max_pages = (len(tokens) - 1) // self.page_size
        held: Dict[int, int] = {}
        alive: Optional[Set[int]] = None
        for i, h in enumerate(prefix_hashes(tokens, self.page_size,
                                            max_pages)):
            rs = self._holders.get((tenant, h), ())
            alive = set(rs) if alive is None else alive & set(rs)
            if not alive:
                break
            for r in alive:
                held[r] = (i + 1) * self.page_size
        if held:
            self.stats.hits += 1
        return held


@dataclass
class RouterConfig:
    """Bounds past which the cache route yields to least-loaded."""
    # max load lead (queue + active) the cache target may have over the
    # least-loaded replica before the router falls back — bounds how
    # much queue imbalance prefix affinity is allowed to create
    imbalance_bound: int = 4
    # max pending directory events before the directory is considered
    # too stale to trust (only nonzero under ``defer_events``)
    staleness_bound: int = 256


class CacheAwareRouter:
    """Route-to-longest-held-prefix dispatch over one tenant's replicas.

    ``route`` picks a replica index given the request and the replicas'
    current loads.  ``cache_aware=False`` is the blind baseline (pure
    least-loaded) — the A/B arm.  Every tie-break ends in the replica
    index, so the selection is a strict total order and identical
    traces replay identically."""

    def __init__(self, directory: PrefixDirectory, tenant: str,
                 cfg: Optional[RouterConfig] = None,
                 cache_aware: bool = True):
        self.directory = directory
        self.tenant = tenant
        self.cfg = cfg or RouterConfig()
        self.cache_aware = cache_aware
        self.stats = RoutingStats()
        self._dead: Set[int] = set()

    def mark_dead(self, replica: int) -> None:
        """Replica death: never route here again (the gateway also
        masks dead replicas with infinite load, which this guards even
        for held-prefix candidates)."""
        self._dead.add(replica)

    def route(self, req: Request, loads: Sequence[int]) -> int:
        """Replica index for ``req``.  Strict total orders:
        least-loaded = min (load, index); cache route = min
        (-held tokens, load, index) over the holding replicas."""
        live = [j for j in range(len(loads))
                if j not in self._dead and loads[j] != float("inf")]
        if not live:            # defensive: the gateway gates this case
            live = list(range(len(loads)))
        least = min(live, key=lambda j: (loads[j], j))
        if not self.cache_aware:
            self.stats.routed_blind += 1
            return least
        if self.directory.staleness() > self.cfg.staleness_bound:
            self.stats.fallback_stale += 1
            return least
        held = self.directory.lookup(self.tenant, req.prompt_tokens)
        held = {j: t for j, t in held.items() if j in live}
        if not held:
            self.stats.fallback_miss += 1
            return least
        best = min(held, key=lambda j: (-held[j], loads[j], j))
        if loads[best] - loads[least] > self.cfg.imbalance_bound:
            self.stats.fallback_imbalance += 1
            return least
        self.stats.routed_cache += 1
        return best


class ResponseCache:
    """LRU of committed outputs, keyed by (tenant, prompt-hash, params).

    ``params`` is the request's generation-parameter tuple — under this
    stack's greedy decode that is ``max_new_tokens`` (the rusets
    semantic-cache key shape: model+prompt+params; the model is fixed
    per engine fleet).  ``record`` stores a finished request's output;
    ``prime`` fills a later identical request's ``draft_hints`` so the
    n-gram drafter replays the cached completion and the model merely
    verifies it.  Client-supplied hints are never overwritten.  Shared
    safely across replicas: one replica's completion primes every
    replica's speculation."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._store: "OrderedDict[tuple, List[int]]" = OrderedDict()
        self.lookups = 0
        self.hits = 0
        self.inserts = 0
        self.evictions = 0
        self.partial_skips = 0

    @staticmethod
    def _key(req: Request) -> tuple:
        return (req.tenant, prompt_hash(req.prompt_tokens),
                req.max_new_tokens)

    def __len__(self) -> int:
        return len(self._store)

    def record(self, req: Request) -> None:
        """Remember a finished request's committed output (idempotent —
        greedy decode makes re-records identical).

        Terminal-verdict guard: only a *completed* generation records.
        Expired, preempted, or crash-drained partials carry real-looking
        ``output_tokens`` shorter than the request asked for; caching
        one would prime later identical requests with a truncated
        completion (rejected draft rows — wasted verify compute) and,
        worse, present the partial as a cached response."""
        if req.prompt_tokens is None or not req.output_tokens:
            return
        if req.generated < req.max_new_tokens and not req.done:
            self.partial_skips += 1
            return
        key = self._key(req)
        self._store.pop(key, None)
        self._store[key] = list(req.output_tokens)
        self.inserts += 1
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def prime(self, req: Request) -> bool:
        """Fill ``req.draft_hints`` from a cached completion of the same
        (tenant, prompt, params).  Returns whether it hit.  Requests
        that already carry client hints are left untouched (and not
        counted — the cache was never consulted)."""
        if req.prompt_tokens is None or req.draft_hints is not None:
            return False
        self.lookups += 1
        key = self._key(req)
        hit = self._store.get(key)
        if hit is None:
            return False
        self._store.move_to_end(key)
        self.hits += 1
        req.draft_hints = np.asarray(hit, np.int64)
        return True

    def hit_rate(self) -> float:
        if not self.lookups:
            return 0.0
        return self.hits / self.lookups
