"""Continuous-batching serving engine with two interchangeable backends.

One engine instance serves one tenant's model on one slice.  The engine
performs *one unit of work* per ``step()`` call — a prefill (or, paged
backend, one prefill *chunk*) or one batched decode step — and reports the
measured compute seconds.  The harness (real-time driver or the cluster
simulator) decides what wall/virtual time the step consumed (e.g. adding
PS-fabric transfer delay) and then calls ``finalize_step`` so TTFT and
completion timestamps reflect the environment.

Backends (``backend=`` ctor arg, same public API either way):

* ``"dense"`` — the original slot cache: ``[max_slots, seq_cap]`` KV per
  layer, whole-prompt prefill, prompt+max_new pages reserved at submit
  (admission rejects when the pool is full).
* ``"paged"`` — the block-table runtime (``serving/paged_runtime.py``):
  KV lives in a page pool addressed through ``PagedKVCache`` block tables,
  prompts prefill in chunks interleaved with decode, and pool exhaustion
  triggers SLO-aware preemption instead of submit-time rejection.

Guardrail hook (paper §2.2, MPS-quota analogue): ``set_quota(frac)`` caps
the engine's concurrency — the number of active decode slots and the
prefill admission rate scale with the quota, bounding MXU occupancy the
way CUDA_MPS_ACTIVE_THREAD_PERCENTAGE bounds SM occupancy.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import NO_POLICY
from repro.models.model import Model, decode_step, prefill
from repro.models.params import P, specs_from_plan
from repro.serving.kvcache import PagedKVCache
from repro.serving.metrics import TenantMetrics
from repro.serving.request import (ADMITTED, POOL_EXHAUSTED, Request,
                                   SubmitOutcome)


def init_cache_from_plan(plan):
    """Zero-initialised cache (pos arrays get -1)."""
    def leaf(p: P):
        if p.dtype == "int32":
            return jnp.full(p.shape, -1, jnp.int32)
        return jnp.zeros(p.shape, jnp.dtype(p.dtype))
    return jax.tree.map(leaf, plan, is_leaf=lambda x: isinstance(x, P))


@dataclass
class StepReport:
    kind: str                 # "prefill" | "decode" | "mixed" | "idle"
    compute_s: float = 0.0
    tokens: int = 0                      # total tokens this step
    prefill_tokens: int = 0              # prompt tokens written this step
    decode_tokens: int = 0               # decode tokens emitted this step
    # requests whose first token was emitted this step (TTFT events);
    # a fused mixed step can complete several prefills at once
    prefilled: List[Request] = field(default_factory=list)
    decoded: List[Request] = field(default_factory=list)
    completed: List[Request] = field(default_factory=list)
    # paged backend: sequences evicted (pages released, requeued for a full
    # restart) by SLO-aware preemption during this step
    preempted: List[Request] = field(default_factory=list)
    # paged backend: prompt tokens served from the shared prefix cache
    # while planning this step (prefill compute skipped entirely)
    prefix_hit_tokens: int = 0
    # speculative decode lanes (paged backend, spec_k > 0): draft rows
    # verified this step, and how many of them the model accepted —
    # decode_tokens already counts every committed token (base + accepted)
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    # --- flight-recorder detail (serving/trace.py) -----------------------
    # prefill work per request this step: (req, token_start, chunk_len,
    # chunk_index) — the dense backend reports its whole-prompt prefill
    # as chunk 0, the paged runtime one entry per planned chunk
    chunks: List[tuple] = field(default_factory=list)
    # per-lane speculative verify outcome: (req, drafted, accepted),
    # only for lanes that carried a draft this step
    spec: List[tuple] = field(default_factory=list)
    # preemption detail: (victim_req_id, beneficiary_req_id) pairs, the
    # same tuples the scheduler appends to its preempt_log this step
    preempt_pairs: List[tuple] = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, max_slots: int = 8,
                 seq_cap: int = 256, page_size: int = 16, seed: int = 0,
                 policy=NO_POLICY, backend: str = "dense",
                 pool_pages: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 step_tokens: Optional[int] = None, attn_impl: str = "auto",
                 kv_dtype: str = "auto", prefix_cache: bool = True,
                 spec_k: int = 0, spec_ngram: int = 3,
                 response_cache=None):
        if backend not in ("dense", "paged"):
            raise ValueError(f"unknown backend {backend!r}")
        if kv_dtype != "auto" and backend == "dense":
            raise ValueError(
                "kv_dtype applies to the paged backend's page pools; the "
                "dense slot cache quantizes via REPRO_KV_INT8=1")
        if spec_k and backend == "dense":
            raise ValueError(
                "speculative decode lanes (spec_k) need the paged "
                "runtime's ragged verify step; use backend='paged'")
        if response_cache is not None and response_cache is not False \
                and backend == "dense":
            raise ValueError(
                "the response cache primes speculative draft hints at "
                "submit, which needs the paged scheduler; use "
                "backend='paged'")
        # response_cache: None/False = off, True = a private cache,
        # or a serving/directory.ResponseCache instance — pass ONE
        # instance to every replica of a tenant so a completion on any
        # replica primes speculation fleet-wide.  Identity checks, not
        # truthiness: an EMPTY cache instance is falsy (len() == 0) but
        # very much wanted.
        if response_cache is True:
            from repro.serving.directory import ResponseCache
            response_cache = ResponseCache()
        elif response_cache is False:
            response_cache = None
        self.response_cache = response_cache
        self.cfg = cfg
        self.model = Model(cfg)
        self.policy = policy
        if params is None:
            params = self.model.init(jax.random.key(seed))
        self.params = params
        self.max_slots = max_slots
        self.seq_cap = seq_cap
        self.backend = backend
        self.quota = 1.0
        self.metrics = TenantMetrics()
        # optional serving/trace.FlightRecorder: ``finalize_step`` folds
        # each step into per-request timelines.  None (the default) is
        # the zero-cost path — a single guard, no recorder calls.
        self.tracer = None
        self._rng = np.random.default_rng(seed)
        if backend == "paged":
            from repro.serving.paged_runtime import PagedRuntime
            self.runtime = PagedRuntime(
                cfg, self.params, max_slots=max_slots, seq_cap=seq_cap,
                page_size=page_size, pool_pages=pool_pages,
                chunk_tokens=chunk_tokens, step_tokens=step_tokens,
                policy=policy, attn_impl=attn_impl, kv_dtype=kv_dtype,
                prefix_cache=prefix_cache, spec_k=spec_k,
                spec_ngram=spec_ngram, response_cache=self.response_cache,
                seed=seed)
            self.kv = self.runtime.kv
            # the scheduler's waiting deque doubles as the engine queue
            # (same object for the lifetime of the engine, so load-based
            # dispatch `len(engine.queue)` works on either backend)
            self.queue = self.runtime.queue
            return
        self.runtime = None
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.positions = np.zeros(max_slots, np.int32)
        self.last_token = np.zeros(max_slots, np.int32)
        # paged accounting mirrors the dense slot cache capacity
        self.kv = PagedKVCache(num_pages=max_slots * (seq_cap // page_size),
                               page_size=page_size)
        cplan = self.model.cache_plan(max_slots, seq_cap, policy)
        self.cache = init_cache_from_plan(cplan)
        self._decode_fn = jax.jit(
            lambda p, c, t, q: decode_step(p, cfg, c, t, q, policy))
        self._prefill_fn = jax.jit(
            lambda p, b: prefill(p, cfg, b, policy, seq_cap=seq_cap))

    # ------------------------------------------------------------------ API
    def set_quota(self, frac: float) -> None:
        self.quota = float(np.clip(frac, 0.1, 1.0))
        if self.runtime is not None:
            self.runtime.set_budget(self.active_slot_budget)

    @property
    def active_slot_budget(self) -> int:
        return max(1, int(np.ceil(self.quota * self.max_slots)))

    def submit(self, req: Request) -> SubmitOutcome:
        """Returns a falsy :class:`SubmitOutcome` if rejected by admission
        control (``outcome.reason`` says why, ``outcome.transient``
        whether a retry may succeed).  The dense backend rejects whenever
        the conservative prompt+max_new page reservation does not fit —
        transient, the pool drains as requests finish; the paged backend
        only rejects requests that could NEVER fit and resolves pressure
        by preemption."""
        if self.runtime is not None:
            return self.runtime.submit(req)
        if not self.kv.can_admit(req.prompt_len, req.max_new_tokens):
            return POOL_EXHAUSTED
        self.kv.allocate(req.req_id, req.prompt_len,
                         req.prompt_len + req.max_new_tokens)
        self.queue.append(req)
        return ADMITTED

    def active(self) -> List[Request]:
        if self.runtime is not None:
            return self.runtime.running()
        return [r for r in self.slots if r is not None]

    def has_work(self) -> bool:
        if self.runtime is not None:
            return self.runtime.has_work()
        return bool(self.queue) or any(s is not None for s in self.slots)

    def drain_requests(self, ship_state: bool = False):
        """Replica death / planned drain: release every KV page and return
        the resident requests (queued, prefilling and decoding alike) so
        the dispatcher can redrive them onto surviving replicas.  Requests
        come back rolled to a restartable state (outputs cleared, original
        ``prefill_done`` stamp kept so TTFT is not double-counted).

        ``ship_state=True`` returns ``serving/migrate.LaneManifest``
        objects instead of bare requests: each resident lane's KV pages
        are serialized (with chain hashes) BEFORE the drain resets its
        cursors, so a ``PageImporter`` on another replica can resume the
        lane warm — and any lane that fails the import's verification
        degrades to the cold redrive exactly as if ``ship_state`` were
        False.  The dense backend holds no shippable page chains, so its
        manifests are always cold (recompute is the only path)."""
        if self.runtime is not None:
            manifests = None
            if ship_state:
                from repro.serving.migrate import PageExporter
                manifests = PageExporter(self.runtime).export_all()
            drained = self.runtime.drain_for_redrive()
            self.kv.release_all()        # safety net: no page outlives death
            return manifests if manifests is not None else drained
        drained = list(self.queue)
        self.queue.clear()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slots[i] = None
            drained.append(req)
        for req in drained:
            req.generated = 0
            req.slot = -1
            req.output_tokens.clear()
            req.decode_times.clear()
        self.kv.release_all()
        if ship_state:
            from repro.serving.migrate import LaneManifest
            return [LaneManifest(
                req=r,
                prompt_tokens=np.asarray(r.prompt_tokens, np.int64)
                if r.prompt_tokens is not None else np.zeros(0, np.int64))
                for r in drained]
        return drained

    # ----------------------------------------------------------------- step
    def step(self) -> StepReport:
        """One unit of work.  Compute time measured with a real clock."""
        report = self._step_backend()
        self.metrics.observe_kv(self.kv.used_pages, self.kv.reserved_pages,
                                self.kv.num_pages)
        self.metrics.observe_prefill(report.prefill_tokens,
                                     report.prefix_hit_tokens)
        self.metrics.observe_spec(report.drafted_tokens,
                                  report.accepted_tokens)
        if self.runtime is not None:
            self.metrics.observe_response_cache(self.runtime.sched.rc_lookups,
                                                self.runtime.sched.rc_hits)
        return report

    def _step_backend(self) -> StepReport:
        if self.runtime is not None:
            return self.runtime.step()
        free = [i for i, s in enumerate(self.slots) if s is None]
        n_active = self.max_slots - len(free)
        if self.queue and free and n_active < self.active_slot_budget:
            return self._do_prefill(free[0])
        if n_active:
            return self._do_decode()
        return StepReport(kind="idle")

    def finalize_step(self, report: StepReport, end_time: float,
                      start_time: Optional[float] = None) -> None:
        """Record timestamps using the harness-provided completion time.

        ``start_time`` (optional) is the step's virtual start stamp —
        only the flight recorder consumes it, to open this step's spans
        at the step boundary instead of each request's previous event;
        metrics observe ``end_time`` exactly as before."""
        for req in report.prefilled:
            req.prefill_done = end_time
            # door-measured TTFT: from arrival at the front door (includes
            # any gateway-queue wait) — the SLO the paper's per-tenant
            # attainment is measured against
            self.metrics.latency.observe(end_time, (end_time - req.arrival),
                                         slo=(req.slo_ms or 0) / 1e3 or None,
                                         req_id=req.req_id)
            # engine-measured TTFT: from the moment the gateway handed the
            # request to this engine (absent a gateway, never observed)
            if req.submitted >= 0:
                self.metrics.engine_ttft.observe(
                    end_time, end_time - req.submitted, req_id=req.req_id)
        for req in report.decoded:
            # per-token decode timestamp: the gap to the previous emission
            # (prefill for the first decode) is this token's ITL
            prev = req.decode_times[-1] if req.decode_times \
                else req.prefill_done
            req.decode_times.append(end_time)
            if prev >= 0:
                self.metrics.itl.observe(end_time, end_time - prev,
                                         req_id=req.req_id)
        for req in report.completed:
            req.finished = end_time
        if report.tokens:
            self.metrics.observe_tokens(end_time, report.tokens)
        if self.tracer is not None:
            self.tracer.on_step(report, start_time, end_time,
                                engine=self.backend)

    # ------------------------------------------------------------ internals
    def _merge_slot_cache(self, cache1, slot: int) -> None:
        """Merge a single-sequence prefill cache into the batched slot
        cache.  Prefix-layer leaves are [batch, ...] but period leaves are
        stacked [repeats, batch, ...] — indexing them with ``at[slot]``
        would hit the repeats axis and broadcast one request's KV across
        every slot (and silently drop merges for slot >= repeats), so the
        two groups must be merged along different axes."""
        new = dict(self.cache)
        if "prefix" in self.cache:
            new["prefix"] = jax.tree.map(
                lambda full, one: full.at[slot].set(one[0]),
                self.cache["prefix"], cache1["prefix"])
        if "period" in self.cache:
            new["period"] = jax.tree.map(
                lambda full, one: full.at[:, slot].set(one[:, 0]),
                self.cache["period"], cache1["period"])
        self.cache = new

    def _prompt_tokens(self, req: Request):
        if req.prompt_tokens is not None:
            return jnp.asarray(req.prompt_tokens, jnp.int32)[None]
        toks = self._rng.integers(0, self.cfg.vocab_size, req.prompt_len)
        return jnp.asarray(toks, jnp.int32)[None]

    def _do_prefill(self, slot: int) -> StepReport:
        req = self.queue.popleft()
        batch = {"tokens": self._prompt_tokens(req)}
        if self.cfg.frontend.kind == "vision":
            batch["embeds"] = jnp.zeros(
                (1, self.cfg.frontend.num_prefix, self.cfg.frontend.embed_dim),
                jnp.bfloat16)
        if self.cfg.encoder is not None:
            batch["frames"] = jnp.zeros((1, req.prompt_len,
                                         self.cfg.frontend.embed_dim),
                                        jnp.bfloat16)
            batch["tokens"] = jnp.ones((1, 1), jnp.int32)    # BOS
        t0 = time.perf_counter()
        logits, cache1 = self._prefill_fn(self.params, batch)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        first_tok = int(jnp.argmax(logits[0]))
        self._merge_slot_cache(cache1, slot)
        req.slot = slot
        req.generated = 1
        req.output_tokens.append(first_tok)
        self.slots[slot] = req
        self.positions[slot] = req.prompt_len
        self.last_token[slot] = first_tok
        report = StepReport(kind="prefill", compute_s=dt, tokens=req.prompt_len,
                            prefill_tokens=req.prompt_len, prefilled=[req])
        report.chunks.append((req, 0, req.prompt_len, 0))
        if req.generated >= req.max_new_tokens:
            self._retire(req, report)
        return report

    def _do_decode(self) -> StepReport:
        toks = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.positions)
        t0 = time.perf_counter()
        logits, self.cache = self._decode_fn(self.params, self.cache, toks, pos)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        report = StepReport(kind="decode", compute_s=dt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.positions[i] += 1
            self.last_token[i] = int(next_tokens[i])
            req.generated += 1
            req.output_tokens.append(int(next_tokens[i]))
            self.kv.append_token(req.req_id)
            report.tokens += 1
            report.decode_tokens += 1
            report.decoded.append(req)
            if req.generated >= req.max_new_tokens:
                self._retire(req, report)
        return report

    def _retire(self, req: Request, report: StepReport) -> None:
        if req.slot >= 0:
            self.slots[req.slot] = None
        self.kv.release(req.req_id)
        report.completed.append(req)
