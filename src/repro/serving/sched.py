"""Scheduler for the paged runtime: fused mixed prefill+decode batch
composition + SLO-aware, refcount-aware preemption over a shared KV page
pool.

Host-side policy only — no jax in this module, so the scheduling logic is
unit-testable without touching a device.  The runtime
(``serving/paged_runtime.py``) asks for one :class:`MixedPlan` per engine
step and executes it as a single fused forward pass.

Three policies live here:

* **Continuous batching under a per-step token budget** (the core lever in
  SLO-aware batch composition): every step's batch starts from ALL
  decode-ready lanes (one token each) and the remaining budget
  (``step_tokens - n_decode``) is filled with prefill chunk tokens — the
  in-flight chunked prompts first, then new admissions.  Decode lanes
  therefore never stall on an admission: a new prompt only shrinks the
  prefill share of the step, never displaces a decode token, which is what
  keeps ITL tails flat under churn (the PR 3 interleave instead alternated
  whole steps, stalling every decode lane for a full chunk).

* **Prefix-cache sharing**: when a prompt is first scheduled, the longest
  cached page-aligned prefix is mapped straight into its block table
  (``PagedKVCache.match_prefix``) and those tokens are never prefilled —
  TTFT for shared-prefix workloads drops from O(prompt) to O(tail).  Fully
  prefilled pages are published back (``commit_prefix``) as chunks finish.

* **SLO-aware preemption** (serving mixed loads with SLO guarantees):
  page-pool exhaustion evicts the least-SLO-urgent page holder — lowest
  ``Request.priority`` first, then the furthest deadline
  (``arrival + slo``) — releases its *references*, and requeues it for a
  full restart (recompute-style preemption: greedy decode regenerates the
  same tokens).  Refcount-awareness is structural: eviction only drops the
  victim's references, so a page with live sharers is never freed, and a
  victim whose pages are all shared yields nothing — the loop then moves
  to the next victim in the strict total order (no livelock).  Admission-
  time prefill may only preempt victims strictly less urgent than the
  beneficiary, which keeps eviction thrash-free; decode of already-running
  sequences may evict any holder (including, as a last resort, the least
  urgent of the decoding set itself).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request

_INF = float("inf")


@dataclass
class SchedConfig:
    chunk_tokens: int = 64        # per-seq prefill chunk cap per step
    max_active: int = 8           # lane cap (decode + prefill rows)
    # fused per-step token budget (decode lanes + prefill chunk tokens);
    # None = max_active + chunk_tokens, i.e. a full decode batch never
    # forfeits prefill progress and vice versa
    step_tokens: Optional[int] = None


@dataclass(eq=False)          # identity semantics for in/remove on lists
class SeqState:
    """Runtime state of one request inside the paged scheduler."""
    req: Request
    prefilled: int = 0            # prompt tokens already written to pages
    preemptions: int = 0
    last_token: int = 0           # feedback token for the next decode step
    prefix_hit: int = 0           # prompt tokens served from the prefix cache

    def deadline(self) -> float:
        if self.req.slo_ms is None:
            return _INF
        return self.req.arrival + self.req.slo_ms / 1e3


@dataclass
class MixedPlan:
    """One fused engine step: decode lanes + prefill chunks, all pages
    reserved, composed under the step token budget."""
    decodes: List[SeqState] = field(default_factory=list)
    prefills: List[Tuple[SeqState, int, int]] = \
        field(default_factory=list)           # (seq, start, chunk_len)
    preempted: List[SeqState] = field(default_factory=list)
    prefix_hit_tokens: int = 0                # matched while planning

    @property
    def total_tokens(self) -> int:
        return len(self.decodes) + sum(c for _, _, c in self.prefills)

    @property
    def empty(self) -> bool:
        return not self.decodes and not self.prefills


def _urgency_key(s: SeqState) -> Tuple[float, float, float, float]:
    """Greater tuple = more SLO-urgent: higher priority, then sooner
    deadline, then older arrival, then older req_id.  ``min`` over this
    key picks the eviction victim; the strict ``<`` comparison gates
    admission-time preemption.  The req_id tie-break makes the order a
    strict TOTAL order — without it two equal-urgency sequences on an
    overcommitted pool can self-evict alternately forever (each decode
    evicting its own requester), and the deterministic pecking order is
    what guarantees progress."""
    return (s.req.priority, -s.deadline(), -s.req.arrival, -s.req.req_id)


class PagedScheduler:
    """Owns the waiting queue, the in-flight chunked prefills, the
    decode-active set, and all page accounting against one PagedKVCache."""

    def __init__(self, kv: PagedKVCache, cfg: SchedConfig):
        self.kv = kv
        self.cfg = cfg
        self.waiting: Deque[SeqState] = deque()
        self.prefilling: List[SeqState] = []
        self.active: List[SeqState] = []
        self.budget = cfg.max_active
        self.preempt_log: List[Tuple[int, int]] = []   # (victim, beneficiary)

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> bool:
        """Queue a request.  Only requests that could never fit (their total
        footprint exceeds the whole pool, or the block-table width) are
        rejected — pool pressure is handled later by preemption, not here."""
        total = req.prompt_len + req.max_new_tokens
        if self.kv.pages_needed(total) > self.kv.num_pages:
            return False
        self.waiting.append(SeqState(req))
        return True

    def set_budget(self, budget: int) -> None:
        self.budget = max(1, budget)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.prefilling) \
            or bool(self.active)

    def running(self) -> List[Request]:
        return [s.req for s in self.active] + \
            [s.req for s in self.prefilling]

    def step_token_budget(self) -> int:
        if self.cfg.step_tokens is not None:
            return max(1, self.cfg.step_tokens)
        return self.cfg.max_active + self.cfg.chunk_tokens

    # ----------------------------------------------------------------- plan
    def plan(self) -> MixedPlan:
        """Compose one fused step: every decode-ready lane plus as many
        prefill chunk tokens as fit under the step token budget, all with
        pages reserved.  Eviction during planning can remove a
        previously-planned lane — the final filters keep the plan
        consistent with what actually still holds pages."""
        plan = MixedPlan()
        plan.decodes = self._reserve_decodes(plan.preempted)
        budget = self.step_token_budget() - len(plan.decodes)
        # no separate lane cap: concurrency is already bounded by the
        # admission gate below (active + prefilling < self.budget), so an
        # in-flight chunk keeps progressing even with every slot decoding.
        # iterate a snapshot: a reservation below can evict an earlier
        # member of self.prefilling, and a live index would then skip the
        # next in-flight prompt for the step
        candidates = list(self.prefilling)
        idx = 0
        while budget > 0:
            if idx < len(candidates):
                seq = candidates[idx]
                idx += 1
                if seq not in self.prefilling:   # evicted while planning
                    continue
            elif self.waiting and (len(self.active) + len(self.prefilling)
                                   < self.budget):
                seq = self.waiting.popleft()
                self.prefilling.append(seq)
                if seq.prefilled == 0:
                    matched = self.kv.match_prefix(seq.req.req_id,
                                                   seq.req.prompt_tokens)
                    if matched:
                        seq.prefilled = matched
                        seq.prefix_hit = matched
                        plan.prefix_hit_tokens += matched
            else:
                break
            clen = min(self.cfg.chunk_tokens, budget,
                       seq.req.prompt_len - seq.prefilled)
            if clen <= 0:
                continue
            ok, victims = self._reserve_prefill(seq, seq.prefilled + clen)
            plan.preempted.extend(victims)
            if not ok:
                break       # no eligible victim; decode-only step
            plan.prefills.append((seq, seq.prefilled, clen))
            budget -= clen
        # eviction during later reservations may have unplanned earlier work
        plan.decodes = [s for s in plan.decodes if s in self.active]
        plan.prefills = [(s, a, c) for (s, a, c) in plan.prefills
                         if s in self.prefilling]
        return plan

    # ------------------------------------------------------------- prefill
    def _reserve_prefill(self, seq: SeqState,
                         target_tokens: int) -> Tuple[bool, List[SeqState]]:
        """Reserve pages for the next chunk, evicting strictly-less-urgent
        holders if needed.  Returns (ok, victims-this-call); ok=False (with
        ``seq`` left queued in the prefilling set) means no eligible victim
        exists — the planner falls back to decode-only and retries."""
        victims: List[SeqState] = []
        while True:
            try:
                self.kv.reserve(seq.req.req_id, target_tokens)
                return True, victims
            except MemoryError:
                victim = self._pick_victim(
                    exclude=seq, strictly_less_urgent_than=seq)
                if victim is None:
                    return False, victims
                self.preempt(victim, beneficiary=seq)
                victims.append(victim)

    def finish_chunk(self, seq: SeqState, n_tokens: int) -> None:
        """``n_tokens`` of prompt were written by the fused step; publish
        the completed full pages to the prefix index so later requests
        sharing this prompt skip their prefill."""
        self.kv.extend(seq.req.req_id, seq.prefilled + n_tokens)
        seq.prefilled += n_tokens
        self.kv.commit_prefix(seq.req.req_id, seq.req.prompt_tokens,
                              seq.prefilled)
        if seq.prefilled >= seq.req.prompt_len:
            self.prefilling.remove(seq)
            self.active.append(seq)

    # -------------------------------------------------------------- decode
    def _reserve_decodes(self,
                         preempted: List[SeqState]) -> List[SeqState]:
        """Reserve one more token of pages for every decode-active
        sequence, most urgent first.  Under an exhausted pool the least
        urgent holders are evicted until the rest fit."""
        ready: List[SeqState] = []
        for seq in sorted(self.active, key=_urgency_key, reverse=True):
            if seq not in self.active:      # evicted by an earlier reserve
                continue
            done = False
            while not done:
                try:
                    self.kv.reserve(seq.req.req_id, self._tokens_of(seq) + 1)
                    ready.append(seq)
                    done = True
                except MemoryError:
                    victim = self._pick_victim(exclude=None)
                    if victim is None:      # pool smaller than one seq
                        raise
                    self.preempt(victim, beneficiary=seq)
                    preempted.append(victim)
                    if victim is seq:
                        done = True
        return [s for s in ready if s in self.active]

    def commit_decode(self, seq: SeqState) -> None:
        """One token was appended by the fused step."""
        self.kv.extend(seq.req.req_id, self._tokens_of(seq) + 1)

    def _tokens_of(self, seq: SeqState) -> int:
        """Tokens currently in the cache: the prompt plus every generated
        token except the newest (which is only appended by the next decode
        step, mirroring the dense engine's position bookkeeping)."""
        return seq.req.prompt_len + max(0, seq.req.generated - 1)

    # ---------------------------------------------------------- preemption
    def _pick_victim(self, exclude: Optional[SeqState],
                     strictly_less_urgent_than: Optional[SeqState] = None
                     ) -> Optional[SeqState]:
        holders = [s for s in self.active if s is not exclude]
        holders += [s for s in self.prefilling if s is not exclude]
        holders = [s for s in holders if s.req.req_id in self.kv.tables]
        if strictly_less_urgent_than is not None:
            bar = _urgency_key(strictly_less_urgent_than)
            holders = [s for s in holders if _urgency_key(s) < bar]
        if not holders:
            return None
        return min(holders, key=_urgency_key)

    def preempt(self, victim: SeqState,
                beneficiary: Optional[SeqState] = None) -> None:
        """Release the victim's page references and requeue it for a full
        restart.  Shared pages survive (their other sharers keep them, or
        they park on the prefix cache), so a preempted shared-prefix
        request usually restarts with a prefix hit instead of a cold
        prefill.

        ``prefill_done`` is deliberately kept: greedy recompute regenerates
        the *same* tokens, so the original first-token emission remains the
        request's TTFT and the restart must not observe a second sample
        (the runtime only reports ``prefilled`` for a fresh first token).
        The preemption stall still shows up honestly — the first
        regenerated decode gap is measured from the original emission."""
        if victim.req.req_id in self.kv.tables:
            self.kv.release(victim.req.req_id)
        if victim in self.prefilling:
            self.prefilling.remove(victim)
        if victim in self.active:
            self.active.remove(victim)
        r = victim.req
        victim.prefilled = 0
        victim.prefix_hit = 0
        victim.preemptions += 1
        r.generated = 0
        r.slot = -1
        r.output_tokens.clear()
        r.decode_times.clear()
        self.preempt_log.append(
            (r.req_id, beneficiary.req.req_id if beneficiary else -1))
        self.waiting.appendleft(victim)

    # ------------------------------------------------------------- retire
    def complete(self, seq: SeqState) -> None:
        if seq.req.req_id in self.kv.tables:
            self.kv.release(seq.req.req_id)
        if seq in self.active:
            self.active.remove(seq)
