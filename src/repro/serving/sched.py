"""Scheduler for the paged decode runtime: chunked prefill + SLO-aware
preemption over a shared KV page pool.

Host-side policy only — no jax in this module, so the scheduling logic is
unit-testable without touching a device.  The runtime
(``serving/paged_runtime.py``) asks for one unit of work per engine step
and executes the forward passes.

Two policies live here:

* **Chunked prefill** (predictable-latency scheduling of prefill vs decode
  work): prompts are prefilled in ``chunk_tokens``-sized pieces
  (a ``page_size`` multiple), and when decode-active sequences exist the
  planner alternates prefill chunks with decode steps, so a long prompt
  adds at most one chunk of compute between consecutive decode steps
  instead of head-of-line-blocking every running sequence for the whole
  prompt (TTFT *and* ITL tails both stay bounded).

* **SLO-aware preemption** (serving mixed loads with SLO guarantees):
  page-pool exhaustion evicts the least-SLO-urgent page holder — lowest
  ``Request.priority`` first, then the furthest deadline
  (``arrival + slo``) — releases its pages, and requeues it for a full
  restart (recompute-style preemption: greedy decode regenerates the same
  tokens).  Admission-time prefill may only preempt victims strictly less
  urgent than the beneficiary, which makes eviction thrash-free; decode of
  already-running sequences may evict any holder (including, as a last
  resort, the least urgent of the decoding set itself).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request

_INF = float("inf")


@dataclass
class SchedConfig:
    chunk_tokens: int = 64        # per-step prefill token budget
    max_active: int = 8           # decode-concurrency cap (engine slots)


@dataclass(eq=False)          # identity semantics for in/remove on lists
class SeqState:
    """Runtime state of one request inside the paged scheduler."""
    req: Request
    prefilled: int = 0            # prompt tokens already written to pages
    preemptions: int = 0
    last_token: int = 0           # feedback token for the next decode step

    def deadline(self) -> float:
        if self.req.slo_ms is None:
            return _INF
        return self.req.arrival + self.req.slo_ms / 1e3


def _urgency_key(s: SeqState) -> Tuple[float, float, float, float]:
    """Greater tuple = more SLO-urgent: higher priority, then sooner
    deadline, then older arrival, then older req_id.  ``min`` over this
    key picks the eviction victim; the strict ``<`` comparison gates
    admission-time preemption.  The req_id tie-break makes the order a
    strict TOTAL order — without it two equal-urgency sequences on an
    overcommitted pool can self-evict alternately forever (each decode
    evicting its own requester), and the deterministic pecking order is
    what guarantees progress."""
    return (s.req.priority, -s.deadline(), -s.req.arrival, -s.req.req_id)


class PagedScheduler:
    """Owns the waiting queue, the single in-flight chunked prefill, the
    decode-active set, and all page accounting against one PagedKVCache."""

    def __init__(self, kv: PagedKVCache, cfg: SchedConfig):
        self.kv = kv
        self.cfg = cfg
        self.waiting: Deque[SeqState] = deque()
        self.prefilling: Optional[SeqState] = None
        self.active: List[SeqState] = []
        self.budget = cfg.max_active
        self.preempt_log: List[Tuple[int, int]] = []   # (victim, beneficiary)
        self._prefer_decode = False    # alternation toggle for interleaving

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> bool:
        """Queue a request.  Only requests that could never fit (their total
        footprint exceeds the whole pool, or the block-table width) are
        rejected — pool pressure is handled later by preemption, not here."""
        total = req.prompt_len + req.max_new_tokens
        if self.kv.pages_needed(total) > self.kv.num_pages:
            return False
        self.waiting.append(SeqState(req))
        return True

    def set_budget(self, budget: int) -> None:
        self.budget = max(1, budget)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.prefilling is not None \
            or bool(self.active)

    def running(self) -> List[Request]:
        out = [s.req for s in self.active]
        if self.prefilling is not None:
            out.append(self.prefilling.req)
        return out

    # ----------------------------------------------------------------- plan
    def plan(self) -> str:
        """Pick the next unit of work: "prefill" | "decode" | "idle".

        When both a prefill and decode work are pending the planner
        alternates, which is exactly the chunked-prefill interleave: each
        engine step is either ONE chunk of prefill or ONE batched decode
        step, never an unbounded prompt."""
        can_start = (self.prefilling is not None or
                     (bool(self.waiting) and
                      len(self.active) + 1 <= self.budget))
        if can_start and (not self.active or not self._prefer_decode):
            if self.prefilling is None:
                self.prefilling = self.waiting.popleft()
            self._prefer_decode = True
            return "prefill"
        if self.active:
            self._prefer_decode = False
            return "decode"
        if can_start:
            if self.prefilling is None:
                self.prefilling = self.waiting.popleft()
            return "prefill"
        return "idle"

    # ------------------------------------------------------------- prefill
    def next_chunk(self) -> Tuple[SeqState, int, int]:
        """(seq, start, chunk_len) for the in-flight prefill."""
        seq = self.prefilling
        assert seq is not None
        start = seq.prefilled
        return seq, start, min(self.cfg.chunk_tokens,
                               seq.req.prompt_len - start)

    def reserve_for_prefill(self, seq: SeqState,
                            target_tokens: int) -> Tuple[bool, List[SeqState]]:
        """Reserve pages for the next chunk, evicting strictly-less-urgent
        holders if needed.  Returns (ok, victims-this-call); ok=False (with
        ``seq`` left queued as the in-flight prefill) means no eligible
        victim exists — the planner falls back to decode and retries."""
        victims: List[SeqState] = []
        while True:
            try:
                self.kv.reserve(seq.req.req_id, target_tokens)
                return True, victims
            except MemoryError:
                victim = self._pick_victim(
                    exclude=seq, strictly_less_urgent_than=seq)
                if victim is None:
                    return False, victims
                self.preempt(victim, beneficiary=seq)
                victims.append(victim)

    def finish_chunk(self, seq: SeqState, n_tokens: int) -> None:
        self.kv.extend(seq.req.req_id, seq.prefilled + n_tokens)
        seq.prefilled += n_tokens
        if seq.prefilled >= seq.req.prompt_len:
            self.prefilling = None
            self.active.append(seq)

    # -------------------------------------------------------------- decode
    def reserve_for_decode(self) -> Tuple[List[SeqState], List[SeqState]]:
        """Reserve one more token of pages for every decode-active
        sequence, most urgent first.  Under an exhausted pool the least
        urgent holders are evicted until the rest fit.  Returns
        (ready, preempted-this-call)."""
        preempted: List[SeqState] = []
        ready: List[SeqState] = []
        for seq in sorted(self.active, key=_urgency_key, reverse=True):
            if seq not in self.active:      # evicted by an earlier reserve
                continue
            done = False
            while not done:
                try:
                    self.kv.reserve(seq.req.req_id, self._tokens_of(seq) + 1)
                    ready.append(seq)
                    done = True
                except MemoryError:
                    victim = self._pick_victim(exclude=None)
                    if victim is None:      # pool smaller than one seq
                        raise
                    self.preempt(victim, beneficiary=seq)
                    preempted.append(victim)
                    if victim is seq:
                        done = True
        ready = [s for s in ready if s in self.active]
        return ready, preempted

    def commit_decode(self, seq: SeqState) -> None:
        """One token was appended by the decode step."""
        self.kv.extend(seq.req.req_id, self._tokens_of(seq) + 1)

    def _tokens_of(self, seq: SeqState) -> int:
        """Tokens currently in the cache: the prompt plus every generated
        token except the newest (which is only appended by the next decode
        step, mirroring the dense engine's position bookkeeping)."""
        return seq.req.prompt_len + max(0, seq.req.generated - 1)

    # ---------------------------------------------------------- preemption
    def _pick_victim(self, exclude: Optional[SeqState],
                     strictly_less_urgent_than: Optional[SeqState] = None
                     ) -> Optional[SeqState]:
        holders = [s for s in self.active if s is not exclude]
        if self.prefilling is not None and self.prefilling is not exclude:
            holders.append(self.prefilling)
        holders = [s for s in holders if s.req.req_id in self.kv.tables]
        if strictly_less_urgent_than is not None:
            bar = _urgency_key(strictly_less_urgent_than)
            holders = [s for s in holders if _urgency_key(s) < bar]
        if not holders:
            return None
        return min(holders, key=_urgency_key)

    def preempt(self, victim: SeqState,
                beneficiary: Optional[SeqState] = None) -> None:
        """Release the victim's pages and requeue it for a full restart.

        ``prefill_done`` is deliberately kept: greedy recompute regenerates
        the *same* tokens, so the original first-token emission remains the
        request's TTFT and the restart must not observe a second sample
        (the runtime only reports ``prefilled`` for a fresh first token).
        The preemption stall still shows up honestly — the first
        regenerated decode gap is measured from the original emission."""
        if victim.req.req_id in self.kv.tables:
            self.kv.release(victim.req.req_id)
        if victim is self.prefilling:
            self.prefilling = None
        if victim in self.active:
            self.active.remove(victim)
        r = victim.req
        victim.prefilled = 0
        victim.preemptions += 1
        r.generated = 0
        r.slot = -1
        r.output_tokens.clear()
        r.decode_times.clear()
        self.preempt_log.append(
            (r.req_id, beneficiary.req.req_id if beneficiary else -1))
        self.waiting.appendleft(victim)

    # ------------------------------------------------------------- retire
    def complete(self, seq: SeqState) -> None:
        if seq.req.req_id in self.kv.tables:
            self.kv.release(seq.req.req_id)
        if seq in self.active:
            self.active.remove(seq)
