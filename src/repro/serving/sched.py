"""Scheduler for the paged runtime: fused mixed prefill+decode batch
composition + SLO-aware, refcount-aware preemption over a shared KV page
pool.

Host-side policy only — no jax in this module, so the scheduling logic is
unit-testable without touching a device.  The runtime
(``serving/paged_runtime.py``) asks for one :class:`MixedPlan` per engine
step and executes it as a single fused forward pass.

Three policies live here:

* **Continuous batching under a per-step token budget** (the core lever in
  SLO-aware batch composition): every step's batch starts from ALL
  decode-ready lanes (one token each) and the remaining budget
  (``step_tokens - n_decode``) is filled with prefill chunk tokens — the
  in-flight chunked prompts first, then new admissions.  Decode lanes
  therefore never stall on an admission: a new prompt only shrinks the
  prefill share of the step, never displaces a decode token, which is what
  keeps ITL tails flat under churn (the PR 3 interleave instead alternated
  whole steps, stalling every decode lane for a full chunk).

* **Prefix-cache sharing**: when a prompt is first scheduled, the longest
  cached page-aligned prefix is mapped straight into its block table
  (``PagedKVCache.match_prefix``) and those tokens are never prefilled —
  TTFT for shared-prefix workloads drops from O(prompt) to O(tail).  Fully
  prefilled pages are published back (``commit_prefix``) as chunks finish.

* **Speculative multi-token decode lanes** (``SchedConfig.spec_k > 0``):
  a model-free **n-gram / prompt-lookup drafter** proposes up to ``k``
  continuation tokens per decode lane by matching the lane's recent token
  suffix against its own reference corpus (prompt + optional
  ``Request.draft_hints`` + generated output).  The planner attaches the
  draft AFTER decode lanes and prefill chunks have claimed their budget —
  drafts only consume *leftover* step-token budget, so speculation can
  never starve a prefill chunk or another lane, and under saturation it
  degrades to plain one-token decode automatically.  Draft page
  reservations never preempt anyone: on pool pressure the draft is simply
  dropped.  The runtime verifies the drafted rows in the SAME fused
  ragged step (the kernel already takes q_len>1 decode rows) and commits
  the longest model-agreeing prefix; a per-lane acceptance-rate EMA feeds
  the next step's ``k`` (EMA -> 0 drives q_len back to 1, i.e.
  speculation off, guaranteeing ITL is never structurally worse than
  non-speculative decode), with a periodic 1-token probe so a lane can
  rediscover predictability after a distribution shift.

* **SLO-aware preemption** (serving mixed loads with SLO guarantees):
  page-pool exhaustion evicts the least-SLO-urgent page holder — lowest
  ``Request.priority`` first, then the furthest deadline
  (``arrival + slo``) — releases its *references*, and requeues it for a
  full restart (recompute-style preemption: greedy decode regenerates the
  same tokens).  Refcount-awareness is structural: eviction only drops the
  victim's references, so a page with live sharers is never freed, and a
  victim whose pages are all shared yields nothing — the loop then moves
  to the next victim in the strict total order (no livelock).  Admission-
  time prefill may only preempt victims strictly less urgent than the
  beneficiary, which keeps eviction thrash-free; decode of already-running
  sequences may evict any holder (including, as a last resort, the least
  urgent of the decoding set itself).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.kvcache import PagedKVCache
from repro.serving.request import (ADMITTED, NEVER_FITS, Request,
                                   SubmitOutcome)

_INF = float("inf")


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def bucket_rows(n: int) -> int:
    """Row-count bucket for the flattened mixed batch: powers of two up
    to 16, then 16-token granules — bounded compile variants with <= 2x
    (and typically ~1.1x) padding waste.  Lives here (host-side, no jax)
    because the draft planner is bucket-aware: rows the runtime would
    pad anyway are compute-free and may carry draft tokens at zero
    step-budget cost."""
    if n <= 16:
        return next_pow2(n)
    return -(-n // 16) * 16


@dataclass
class SchedConfig:
    chunk_tokens: int = 64        # per-seq prefill chunk cap per step
    max_active: int = 8           # lane cap (decode + prefill rows)
    # fused per-step token budget (decode lanes + prefill chunk tokens);
    # None = max_active + chunk_tokens, i.e. a full decode batch never
    # forfeits prefill progress and vice versa
    step_tokens: Optional[int] = None
    # --- speculative multi-token decode lanes ---
    spec_k: int = 0               # max draft tokens per lane (0 = off)
    spec_ngram: int = 3           # suffix length the drafter matches on
                                  # (3 = the prompt-lookup literature
                                  # default; short enough to fire on
                                  # templates, long enough that random
                                  # vocab collisions are negligible)
    spec_ema_alpha: float = 0.3   # per-lane acceptance-rate EMA smoothing
    # when the EMA has driven a lane's k to 0, re-probe with a 1-token
    # draft every this-many verify opportunities (distribution shift)
    spec_probe_every: int = 32
    # bucket-boundary-aware draft funding: the runtime pads the step's
    # packed rows up to the (rows) compile bucket, so a draft row that
    # rides existing padding costs NO extra compute — fund those at zero
    # step-token cost even when the leftover budget is exhausted (the
    # step's bucket, and therefore its cost, is unchanged by them)
    spec_free_padding: bool = True


class NgramDrafter:
    """Model-free n-gram / prompt-lookup drafter.

    The lane's *reference corpus* is ``prompt_tokens ++ draft_hints ++
    output_tokens`` — its own history, optionally extended with hint
    tokens the frontend believes likely to continue the response (e.g.
    the completion previously observed for the same templated prompt;
    the hints are never trusted, only *verified* by the model, so a
    stale hint costs a rejected draft, never a wrong token).  Drafting
    matches the most recent ``ngram`` generated tokens against the
    corpus and proposes the tokens that followed the most recent prior
    occurrence.  Pure host-side numpy — no model, no device."""

    def __init__(self, ngram: int = 3):
        if ngram < 1:
            raise ValueError(f"ngram must be >= 1, got {ngram}")
        self.ngram = ngram

    def draft(self, corpus: Sequence[int], pattern: Sequence[int],
              k: int) -> List[int]:
        """Up to ``k`` proposed continuations: find the most recent
        occurrence of ``pattern`` (the lane's newest ``ngram`` tokens)
        inside ``corpus`` that has at least one token after it, and
        propose what followed.  Empty when no such occurrence exists —
        a miss costs nothing (no verify rows are added)."""
        n = self.ngram
        c = np.asarray(corpus, dtype=np.int64)
        p = np.asarray(pattern, dtype=np.int64)
        if k <= 0 or p.size != n or c.size < n + 1:
            return []
        # vectorized window match over shifted views; a window whose
        # continuation is empty (the corpus tail is usually the pattern
        # itself) is skipped below
        t = c.size - n + 1                  # window starts: [0, t)
        hit = np.ones(t, dtype=bool)
        for j in range(n):
            hit &= c[j:j + t] == p[j]
        starts = np.flatnonzero(hit)
        for s in starts[::-1]:              # most recent occurrence first
            cont = c[s + n:s + n + k]
            if cont.size:
                return [int(x) for x in cont]
        return []


@dataclass(eq=False)          # identity semantics for in/remove on lists
class SeqState:
    """Runtime state of one request inside the paged scheduler."""
    req: Request
    prefilled: int = 0            # prompt tokens already written to pages
    preemptions: int = 0
    last_token: int = 0           # feedback token for the next decode step
    prefix_hit: int = 0           # prompt tokens served from the prefix cache
    chunks_done: int = 0          # prefill chunks executed (trace span index;
    #                               resets with prefilled on preemption)
    # --- speculative decode lane state ---
    draft: List[int] = field(default_factory=list)   # this step's proposal
    accept_ema: float = 1.0       # acceptance-rate EMA (optimistic start)
    spec_probe: int = 0           # verify opportunities since k hit 0
    # lazily-built immutable drafter inputs (prompt/hints never change)
    _corpus_base: Optional[np.ndarray] = field(default=None, repr=False)
    _prompt_list: Optional[List[int]] = field(default=None, repr=False)

    def deadline(self) -> float:
        if self.req.slo_ms is None:
            return _INF
        return self.req.arrival + self.req.slo_ms / 1e3

    def corpus(self) -> np.ndarray:
        """The drafter's searchable reference corpus: prompt, then
        optional hints, then everything generated so far.  Hints sit
        BETWEEN prompt and output so a replay hint (the completion
        previously observed for this prompt) is adjacent to the prompt
        tail — the lookup then predicts the whole response from the
        first generated token on.  The immutable prompt+hints prefix is
        converted to int64 once and cached: this runs per lane per
        planning step, squarely inside the per-step fixed cost
        speculation exists to shrink, so the per-step work is one
        memcpy plus converting the (short) output tail."""
        if self._corpus_base is None:
            parts = []
            if self.req.prompt_tokens is not None:
                parts.append(np.asarray(self.req.prompt_tokens, np.int64))
            if self.req.draft_hints is not None:
                parts.append(np.asarray(self.req.draft_hints, np.int64))
            self._corpus_base = (np.concatenate(parts) if parts
                                 else np.zeros(0, np.int64))
        return np.concatenate(
            [self._corpus_base,
             np.asarray(self.req.output_tokens, np.int64)])

    def pattern(self, n: int) -> List[int]:
        """The lane's true trailing ``n``-gram — the tail of
        prompt+output (hints are searchable context, never part of the
        actual history)."""
        out = self.req.output_tokens
        if len(out) >= n:
            return out[-n:]
        if self._prompt_list is None:
            self._prompt_list = [int(t) for t in self.req.prompt_tokens] \
                if self.req.prompt_tokens is not None else []
        return (self._prompt_list + out)[-n:]


@dataclass
class MixedPlan:
    """One fused engine step: decode lanes (each with an optional draft —
    ``seq.draft`` — making its q_len 1+k verify rows) + prefill chunks,
    all pages reserved, composed under the step token budget."""
    decodes: List[SeqState] = field(default_factory=list)
    prefills: List[Tuple[SeqState, int, int]] = \
        field(default_factory=list)           # (seq, start, chunk_len)
    preempted: List[SeqState] = field(default_factory=list)
    prefix_hit_tokens: int = 0                # matched while planning
    draft_tokens: int = 0                     # speculative rows this step
    free_draft_tokens: int = 0                # drafts riding bucket padding

    @property
    def total_tokens(self) -> int:
        return len(self.decodes) + self.draft_tokens \
            + sum(c for _, _, c in self.prefills)

    @property
    def empty(self) -> bool:
        return not self.decodes and not self.prefills


def _urgency_key(s: SeqState) -> Tuple[float, float, float, float]:
    """Greater tuple = more SLO-urgent: higher priority, then sooner
    deadline, then older arrival, then older req_id.  ``min`` over this
    key picks the eviction victim; the strict ``<`` comparison gates
    admission-time preemption.  The req_id tie-break makes the order a
    strict TOTAL order — without it two equal-urgency sequences on an
    overcommitted pool can self-evict alternately forever (each decode
    evicting its own requester), and the deterministic pecking order is
    what guarantees progress."""
    return (s.req.priority, -s.deadline(), -s.req.arrival, -s.req.req_id)


class PagedScheduler:
    """Owns the waiting queue, the in-flight chunked prefills, the
    decode-active set, and all page accounting against one PagedKVCache."""

    def __init__(self, kv: PagedKVCache, cfg: SchedConfig,
                 drafter: Optional[NgramDrafter] = None,
                 response_cache=None):
        self.kv = kv
        self.cfg = cfg
        # injectable for tests (oracle / adversarial drafters); the
        # default is the model-free prompt-lookup drafter
        self.drafter = drafter or NgramDrafter(cfg.spec_ngram)
        # optional serving/directory.ResponseCache (may be shared across
        # replicas): completed outputs are recorded, and later identical
        # submits self-prime draft_hints — templated traffic then rides
        # the speculative verify path with no client-supplied hints
        self.response_cache = response_cache
        self.rc_lookups = 0        # engine-local prime counters (the
        self.rc_hits = 0           # cache object's own are fleet-wide)
        self.waiting: Deque[SeqState] = deque()
        self.prefilling: List[SeqState] = []
        self.active: List[SeqState] = []
        self.budget = cfg.max_active
        self.preempt_log: List[Tuple[int, int]] = []   # (victim, beneficiary)
        # req_ids of hung lanes (fault injection / a real stuck
        # collective): they keep their slot and pages but are excluded
        # from step plans, so they emit no tokens until the stuck-lane
        # watchdog preempts them through the normal refcount-safe path
        self.stuck: set = set()

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> SubmitOutcome:
        """Queue a request.  Only requests that could never fit (their total
        footprint exceeds the whole pool, or the block-table width) are
        rejected — pool pressure is handled later by preemption, not here.
        The rejection is therefore NON-transient (``NEVER_FITS``): no
        amount of waiting makes the pool bigger, so a gateway should
        reject-fast instead of requeueing."""
        total = req.prompt_len + req.max_new_tokens
        if self.kv.pages_needed(total) > self.kv.num_pages:
            return NEVER_FITS
        if self.response_cache is not None and req.draft_hints is None \
                and req.prompt_tokens is not None:
            self.rc_lookups += 1
            self.rc_hits += bool(self.response_cache.prime(req))
        self.waiting.append(SeqState(req))
        return ADMITTED

    def set_budget(self, budget: int) -> None:
        self.budget = max(1, budget)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.prefilling) \
            or bool(self.active)

    def running(self) -> List[Request]:
        return [s.req for s in self.active] + \
            [s.req for s in self.prefilling]

    def step_token_budget(self) -> int:
        if self.cfg.step_tokens is not None:
            return max(1, self.cfg.step_tokens)
        return self.cfg.max_active + self.cfg.chunk_tokens

    # ----------------------------------------------------------------- plan
    def plan(self) -> MixedPlan:
        """Compose one fused step: every decode-ready lane plus as many
        prefill chunk tokens as fit under the step token budget, all with
        pages reserved.  Eviction during planning can remove a
        previously-planned lane — the final filters keep the plan
        consistent with what actually still holds pages."""
        plan = MixedPlan()
        plan.decodes = self._reserve_decodes(plan.preempted)
        budget = self.step_token_budget() - len(plan.decodes)
        # no separate lane cap: concurrency is already bounded by the
        # admission gate below (active + prefilling < self.budget), so an
        # in-flight chunk keeps progressing even with every slot decoding.
        # iterate a snapshot: a reservation below can evict an earlier
        # member of self.prefilling, and a live index would then skip the
        # next in-flight prompt for the step
        candidates = list(self.prefilling)
        idx = 0
        while budget > 0:
            if idx < len(candidates):
                seq = candidates[idx]
                idx += 1
                if seq not in self.prefilling:   # evicted while planning
                    continue
                if seq.req.req_id in self.stuck:  # hung mid-prefill
                    continue
            elif self.waiting and (len(self.active) + len(self.prefilling)
                                   < self.budget):
                seq = self.waiting.popleft()
                self.prefilling.append(seq)
                if seq.prefilled == 0:
                    matched = self.kv.match_prefix(seq.req.req_id,
                                                   seq.req.prompt_tokens)
                    if matched:
                        seq.prefilled = matched
                        seq.prefix_hit = matched
                        plan.prefix_hit_tokens += matched
            else:
                break
            clen = min(self.cfg.chunk_tokens, budget,
                       seq.req.prompt_len - seq.prefilled)
            if clen <= 0:
                continue
            ok, victims = self._reserve_prefill(seq, seq.prefilled + clen)
            plan.preempted.extend(victims)
            if not ok:
                break       # no eligible victim; decode-only step
            plan.prefills.append((seq, seq.prefilled, clen))
            budget -= clen
        # eviction during later reservations may have unplanned earlier work
        plan.decodes = [s for s in plan.decodes if s in self.active]
        plan.prefills = [(s, a, c) for (s, a, c) in plan.prefills
                         if s in self.prefilling]
        # speculative drafts LAST: only the budget neither decode lanes
        # nor prefill chunks claimed may fund draft rows — plus rows the
        # runtime's bucket padding makes compute-free — so speculation
        # never starves either (under saturation it self-disables)
        base_rows = len(plan.decodes) + sum(c for _, _, c in plan.prefills)
        plan.draft_tokens, plan.free_draft_tokens = \
            self._plan_drafts(plan.decodes, budget, base_rows)
        return plan

    # ------------------------------------------------------------- drafting
    def _plan_drafts(self, decodes: List[SeqState], budget: int,
                     base_rows: int) -> Tuple[int, int]:
        """Attach a draft (``seq.draft``) to each decode lane, bounded by
        the lane's adaptive k and the LEFTOVER step budget, round-robin so
        one lane cannot monopolise the speculative share.  A draft row
        that would not push the step past its current (rows) compile
        bucket rides the padding the runtime pays for anyway — it is
        funded at ZERO budget cost (``spec_free_padding``), so even a
        fully-claimed budget drafts for free up to the bucket boundary.
        Returns (total draft rows, rows funded by padding).  Draft page
        reservations never evict anyone: on pool pressure the draft is
        trimmed instead (speculation is opportunistic by contract)."""
        for seq in decodes:
            seq.draft = []
        if self.cfg.spec_k <= 0 or not decodes:
            return 0, 0
        free_ok = self.cfg.spec_free_padding
        if budget <= 0 and not free_ok:
            return 0, 0
        want: List[Tuple[SeqState, List[int]]] = []
        for seq in decodes:
            k = self._adaptive_k(seq)
            # never draft past the request's remaining token allowance
            # (the base token always commits one, so only room-1 draft
            # rows can ever be useful)
            k = min(k, seq.req.max_new_tokens - seq.req.generated - 1)
            if k <= 0:
                continue
            d = self.drafter.draft(seq.corpus(),
                                   seq.pattern(self.drafter.ngram), k)
            if d:
                want.append((seq, d))
        total = 0
        free = 0
        # the step's own token budget already pays for this compile
        # bucket; drafts may fill it but never grow the device batch
        # past it (budgeted rows could otherwise open the NEXT bucket
        # and padding would then "freely" fill that too, blowing the
        # per-step compute ceiling the budget exists to bound)
        ceiling = bucket_rows(base_rows + max(budget, 0))
        progressed = True
        while progressed:                    # round-robin, one row per
            progressed = False               # lane per pass
            for seq, d in want:
                # padding first: a free ride never crosses the bucket
                # boundary, so it preserves budget for rows that must
                rows = base_rows + total
                if rows + 1 > ceiling:
                    break
                is_free = free_ok and bucket_rows(rows + 1) == \
                    bucket_rows(rows)
                if not is_free and budget <= 0:
                    continue
                # each lane extends its own contiguous prefix (a failed
                # reservation stays failed within this plan — the free
                # list only shrinks — so the lane just stops growing)
                depth = len(seq.draft)
                if depth < len(d) and self._reserve_draft(seq, depth + 1):
                    seq.draft.append(d[depth])
                    if is_free:
                        free += 1
                    else:
                        budget -= 1
                    total += 1
                    progressed = True
        return total, free

    def _adaptive_k(self, seq: SeqState) -> int:
        """Acceptance-EMA-driven draft depth.  EMA -> 0 turns the lane's
        q_len back to 1 (speculation off); a periodic 1-token probe lets a
        disabled lane rediscover predictability."""
        k = int(round(seq.accept_ema * self.cfg.spec_k))
        if k > 0:
            return min(k, self.cfg.spec_k)
        seq.spec_probe += 1
        if seq.spec_probe >= self.cfg.spec_probe_every:
            seq.spec_probe = 0
            return 1
        return 0

    def _reserve_draft(self, seq: SeqState, n_draft: int) -> bool:
        """Reserve pages for the lane's base token + ``n_draft`` draft
        tokens.  Unlike prefill/decode reservations this NEVER preempts
        — and it only draws on truly-FREE pages: ``reserve`` would
        otherwise evict refcount-zero cached prefix pages (killing their
        index entries) before raising, and speculation must not spend
        the prefix cache's reclaimable capacity either — a draft is
        worth at most k tokens, a cached prefix page saves a whole
        prefill."""
        target = self._tokens_of(seq) + 1 + n_draft
        entry = self.kv.tables.get(seq.req.req_id)
        held = len(entry.pages) if entry is not None else 0
        if self.kv.pages_needed(target) - held > len(self.kv.free):
            return False
        self.kv.reserve(seq.req.req_id, target)
        return True

    # ------------------------------------------------------------- prefill
    def _reserve_prefill(self, seq: SeqState,
                         target_tokens: int) -> Tuple[bool, List[SeqState]]:
        """Reserve pages for the next chunk, evicting strictly-less-urgent
        holders if needed.  Returns (ok, victims-this-call); ok=False (with
        ``seq`` left queued in the prefilling set) means no eligible victim
        exists — the planner falls back to decode-only and retries."""
        victims: List[SeqState] = []
        while True:
            try:
                self.kv.reserve(seq.req.req_id, target_tokens)
                return True, victims
            except MemoryError:
                victim = self._pick_victim(
                    exclude=seq, strictly_less_urgent_than=seq)
                if victim is None:
                    return False, victims
                self.preempt(victim, beneficiary=seq)
                victims.append(victim)

    def finish_chunk(self, seq: SeqState, n_tokens: int) -> None:
        """``n_tokens`` of prompt were written by the fused step; publish
        the completed full pages to the prefix index so later requests
        sharing this prompt skip their prefill."""
        self.kv.extend(seq.req.req_id, seq.prefilled + n_tokens)
        seq.prefilled += n_tokens
        seq.chunks_done += 1
        self.kv.commit_prefix(seq.req.req_id, seq.req.prompt_tokens,
                              seq.prefilled)
        if seq.prefilled >= seq.req.prompt_len:
            self.prefilling.remove(seq)
            self.active.append(seq)

    # -------------------------------------------------------------- decode
    def _reserve_decodes(self,
                         preempted: List[SeqState]) -> List[SeqState]:
        """Reserve one more token of pages for every decode-active
        sequence, most urgent first.  Under an exhausted pool the least
        urgent holders are evicted until the rest fit."""
        ready: List[SeqState] = []
        for seq in sorted(self.active, key=_urgency_key, reverse=True):
            if seq not in self.active:      # evicted by an earlier reserve
                continue
            if seq.req.req_id in self.stuck:
                continue                    # hung lane: holds pages, no rows
            done = False
            while not done:
                try:
                    self.kv.reserve(seq.req.req_id, self._tokens_of(seq) + 1)
                    ready.append(seq)
                    done = True
                except MemoryError:
                    victim = self._pick_victim(exclude=None)
                    if victim is None:      # pool smaller than one seq
                        raise
                    self.preempt(victim, beneficiary=seq)
                    preempted.append(victim)
                    if victim is seq:
                        done = True
        return [s for s in ready if s in self.active]

    def commit_decode(self, seq: SeqState) -> None:
        """One token was appended by the fused step (no speculation)."""
        self.kv.extend(seq.req.req_id, self._tokens_of(seq) + 1)

    def commit_verified(self, seq: SeqState, committed: int,
                        drafted: int, accepted: int) -> None:
        """A verify step committed ``committed`` tokens (the base token
        plus ``accepted`` model-agreeing draft tokens) out of a
        ``drafted``-token draft.  Marks the committed tokens live, rolls
        the over-extended pages of REJECTED draft tokens back to the pool
        (page-granular, refcount-safe — shared pages live below any
        decode position and are never dropped), and folds the acceptance
        rate into the lane's EMA, which feeds the next step's adaptive k.
        Must be called BEFORE ``req.generated`` is advanced (same
        contract as :meth:`commit_decode`)."""
        assert 1 <= committed <= drafted + 1
        target = self._tokens_of(seq) + committed
        self.kv.extend(seq.req.req_id, target)
        self.kv.truncate(seq.req.req_id, target)
        rate = accepted / drafted if drafted else 0.0
        a = self.cfg.spec_ema_alpha
        seq.accept_ema = (1.0 - a) * seq.accept_ema + a * rate

    def _tokens_of(self, seq: SeqState) -> int:
        """Tokens currently in the cache: the prompt plus every generated
        token except the newest (which is only appended by the next decode
        step, mirroring the dense engine's position bookkeeping)."""
        return seq.req.prompt_len + max(0, seq.req.generated - 1)

    # ---------------------------------------------------------- preemption
    def _pick_victim(self, exclude: Optional[SeqState],
                     strictly_less_urgent_than: Optional[SeqState] = None
                     ) -> Optional[SeqState]:
        holders = [s for s in self.active if s is not exclude]
        holders += [s for s in self.prefilling if s is not exclude]
        holders = [s for s in holders if s.req.req_id in self.kv.tables]
        if strictly_less_urgent_than is not None:
            bar = _urgency_key(strictly_less_urgent_than)
            holders = [s for s in holders if _urgency_key(s) < bar]
        if not holders:
            return None
        return min(holders, key=_urgency_key)

    def preempt(self, victim: SeqState,
                beneficiary: Optional[SeqState] = None) -> None:
        """Release the victim's page references and requeue it for a full
        restart.  Shared pages survive (their other sharers keep them, or
        they park on the prefix cache), so a preempted shared-prefix
        request usually restarts with a prefix hit instead of a cold
        prefill.

        ``prefill_done`` is deliberately kept: greedy recompute regenerates
        the *same* tokens, so the original first-token emission remains the
        request's TTFT and the restart must not observe a second sample
        (the runtime only reports ``prefilled`` for a fresh first token).
        The preemption stall still shows up honestly — the first
        regenerated decode gap is measured from the original emission."""
        if victim.req.req_id in self.kv.tables:
            self.kv.release(victim.req.req_id)
        if victim in self.prefilling:
            self.prefilling.remove(victim)
        if victim in self.active:
            self.active.remove(victim)
        r = victim.req
        victim.prefilled = 0
        victim.prefix_hit = 0
        victim.chunks_done = 0
        victim.draft = []        # stale proposals die with the eviction
        victim.preemptions += 1
        r.generated = 0
        r.slot = -1
        r.output_tokens.clear()
        r.decode_times.clear()
        self.preempt_log.append(
            (r.req_id, beneficiary.req.req_id if beneficiary else -1))
        # a requeued lane is a FRESH lane: the hang was a property of the
        # stuck execution, not of the request, so recovery-by-preemption
        # converges instead of re-sticking forever
        self.stuck.discard(r.req_id)
        self.waiting.appendleft(victim)

    # ------------------------------------------------------ fault recovery
    def find(self, req_id: int) -> Optional[SeqState]:
        for pool in (self.active, self.prefilling, self.waiting):
            for seq in pool:
                if seq.req.req_id == req_id:
                    return seq
        return None

    def mark_stuck(self, req_id: int) -> None:
        self.stuck.add(req_id)

    def drain_for_redrive(self) -> List[Request]:
        """Replica death: release every resident page and hand back every
        resident request (in-service first, then queued) for the gateway
        to redrive to a survivor.  Request state resets exactly like
        :meth:`preempt` — outputs cleared for a full greedy regeneration,
        ``prefill_done`` kept so the original first emission remains the
        TTFT sample — but the lane objects are NOT requeued here: the
        survivor's ``submit`` builds fresh ones.  Afterwards this
        scheduler holds nothing (``kv.reserved_pages == 0``)."""
        seqs = list(self.prefilling) + list(self.active) \
            + list(self.waiting)
        self.prefilling.clear()
        self.active.clear()
        self.waiting.clear()
        self.stuck.clear()
        out: List[Request] = []
        for seq in seqs:
            r = seq.req
            if r.req_id in self.kv.tables:
                self.kv.release(r.req_id)
            r.generated = 0
            r.slot = -1
            r.output_tokens.clear()
            r.decode_times.clear()
            out.append(r)
        return out

    # ------------------------------------------------------------- retire
    def complete(self, seq: SeqState) -> None:
        if seq.req.req_id in self.kv.tables:
            self.kv.release(seq.req.req_id)
        if seq in self.active:
            self.active.remove(seq)
        self.stuck.discard(seq.req.req_id)
        if self.response_cache is not None:
            # record only finished outputs: greedy decode makes the
            # committed token sequence a pure function of (prompt,
            # params), so the entry is safe to replay as draft hints
            self.response_cache.record(seq.req)
