"""Latency metrics: windowed tail percentiles, SLO miss-rate, EMA with
hysteresis — the controller's primary signal source (paper §2.1)."""
from __future__ import annotations

import bisect
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

# Prometheus-style cumulative histogram boundaries (seconds).  Chosen to
# straddle the repo's operating points: sub-ms ITL gaps up through
# multi-second door waits under a reconfigure pause.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.2, 0.4, 0.8,
    1.6, 3.2, 6.4)


class LatencyWindow:
    """Sliding window of (time, latency) samples with tail quantiles.

    Times are kept sorted: producers almost always observe in monotone
    order (append-only fast path), but parallel replicas can finalize
    steps out of order — those samples are insort-ed so the
    recent-horizon lookup stays a valid bisect over the time array (the
    controller samples every second — this is the simulator's hot path).

    Alongside the bounded sample window the class keeps *cumulative*
    histogram bucket counts (never trimmed): windowed p99 gauges cannot
    be aggregated across replicas or scrape intervals, but cumulative
    ``le``-bucket counters sum correctly — the ``gateway_*_bucket``
    series ``Gateway.prometheus()`` exports.
    """

    def __init__(self, max_samples: int = 4096, horizon_s: float = 60.0,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.max_samples = max_samples
        self.horizon_s = horizon_s
        self._times: list = []
        self._vals: list = []
        self.total = 0
        self.misses = 0
        self.buckets = tuple(buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +Inf last
        self.sum = 0.0
        # per-bucket OpenMetrics exemplar: the SLOWEST sample that landed
        # in each bucket, as (latency, req_id, time) — the request a
        # dashboard user drills into from a bucket is the one closest to
        # spilling into the next, i.e. the bucket's worst case.  Only
        # samples observed with a req_id are retained.
        self.exemplars: List[Optional[Tuple[float, int, float]]] = \
            [None] * (len(self.buckets) + 1)

    @property
    def samples(self):
        return list(zip(self._times, self._vals))

    def observe(self, now: float, latency: float,
                slo: Optional[float] = None,
                req_id: Optional[int] = None) -> None:
        if self._times and now < self._times[-1]:
            i = bisect.bisect_right(self._times, now)
            self._times.insert(i, now)
            self._vals.insert(i, latency)
        else:
            self._times.append(now)
            self._vals.append(latency)
        if len(self._times) > 2 * self.max_samples:
            # trim from the head of the time-sorted arrays: the dropped
            # samples are exactly the oldest ones, so a sample inside
            # horizon_s can only fall out after every older sample did
            # (tests/test_serving.py asserts this trim-vs-horizon order)
            self._times = self._times[-self.max_samples:]
            self._vals = self._vals[-self.max_samples:]
        self.total += 1
        self.sum += latency
        b = bisect.bisect_left(self.buckets, latency)
        self.bucket_counts[b] += 1
        if req_id is not None:
            ex = self.exemplars[b]
            if ex is None or latency > ex[0]:
                self.exemplars[b] = (latency, req_id, now)
        if slo is not None and latency > slo:
            self.misses += 1

    def hist(self) -> List[Tuple[float, int]]:
        """Cumulative (le, count) pairs, ``+Inf`` last (== ``total``)."""
        out: List[Tuple[float, int]] = []
        acc = 0
        for le, n in zip(self.buckets, self.bucket_counts):
            acc += n
            out.append((le, acc))
        out.append((float("inf"), self.total))
        return out

    def _recent(self, now: Optional[float] = None) -> np.ndarray:
        if not self._times:
            return np.zeros(0)
        if now is None:
            return np.asarray(self._vals)
        lo = bisect.bisect_left(self._times, now - self.horizon_s)
        return np.asarray(self._vals[lo:])

    def quantile(self, q: float, now: Optional[float] = None) -> float:
        vals = self._recent(now)
        if vals.size == 0:
            return 0.0
        return float(np.quantile(vals, q))

    def p99(self, now: Optional[float] = None) -> float:
        return self.quantile(0.99, now)

    def p999(self, now: Optional[float] = None) -> float:
        return self.quantile(0.999, now)

    def miss_rate(self, slo: float, now: Optional[float] = None) -> float:
        vals = self._recent(now)
        if vals.size == 0:
            return 0.0
        return float(np.mean(vals > slo))

    def count(self, now: Optional[float] = None) -> int:
        return int(self._recent(now).size)


@dataclass
class EMA:
    """Exponential moving average with hysteresis (paper §2.1: signals are
    smoothed with EMAs and hysteresis to reduce spurious triggers)."""
    alpha: float = 0.3
    hysteresis: float = 0.05            # relative dead-band
    value: float = 0.0
    _initialised: bool = False

    def update(self, x: float) -> float:
        if not self._initialised:
            self.value = x
            self._initialised = True
            return self.value
        candidate = self.alpha * x + (1 - self.alpha) * self.value
        # dead-band: ignore sub-hysteresis wiggles.  Guarded on
        # abs(value) so smoothing works for negative-valued signals too
        # (a ``> 0`` guard silently disabled the dead-band for signals
        # like headroom deltas or error terms that live below zero)
        if abs(self.value) > 0 and abs(candidate - self.value) < \
                self.hysteresis * abs(self.value):
            return self.value
        self.value = candidate
        return self.value


@dataclass
class DirectoryStats:
    """Cluster prefix-cache directory counters (``serving/directory.py``):
    publish/retract event totals plus lookup hit rate — a *hit* is a
    dispatch-time lookup that found at least one replica holding a
    page-aligned prefix of the request's prompt."""
    published: int = 0
    retracted: int = 0
    lookups: int = 0
    hits: int = 0

    def hit_rate(self) -> float:
        if not self.lookups:
            return 0.0
        return self.hits / self.lookups

    def as_dict(self) -> Dict[str, float]:
        return {"published": self.published, "retracted": self.retracted,
                "lookups": self.lookups, "hits": self.hits,
                "hit_rate": self.hit_rate()}


@dataclass
class RoutingStats:
    """Cache-aware dispatch counters: every routing decision is exactly
    one of these, so routed + fallbacks + blind == requests dispatched
    (the routed-vs-fallback invariant the tests assert)."""
    routed_cache: int = 0          # sent to a prefix-holding replica
    routed_blind: int = 0          # cache_aware=False baseline decisions
    fallback_miss: int = 0         # no replica holds any prefix
    fallback_imbalance: int = 0    # holder's load lead exceeded the bound
    fallback_stale: int = 0        # directory backlog exceeded the bound

    @property
    def total(self) -> int:
        return (self.routed_cache + self.routed_blind + self.fallback_miss
                + self.fallback_imbalance + self.fallback_stale)

    def cache_route_rate(self) -> float:
        if not self.total:
            return 0.0
        return self.routed_cache / self.total

    def as_dict(self) -> Dict[str, float]:
        return {"routed_cache": self.routed_cache,
                "routed_blind": self.routed_blind,
                "fallback_miss": self.fallback_miss,
                "fallback_imbalance": self.fallback_imbalance,
                "fallback_stale": self.fallback_stale,
                "cache_route_rate": self.cache_route_rate()}


@dataclass
class TenantMetrics:
    """Bundle of per-tenant signals the controller samples every delta s."""
    # door-relative TTFT: prefill_done - arrival, where arrival is the
    # *front-door* timestamp — this window includes any gateway-queue
    # wait, so it is what a client actually experiences
    latency: LatencyWindow = field(default_factory=LatencyWindow)
    # engine-relative TTFT: prefill_done - submitted, observed only for
    # requests that carried a gateway submit stamp.  The gap between the
    # two windows' tails is exactly the door-queue wait — the quantity
    # the --door benchmark arm reports side by side
    engine_ttft: LatencyWindow = field(default_factory=LatencyWindow)
    # inter-token latency (decode cadence): one sample per decoded token,
    # measured between consecutive token-emission timestamps — makes
    # TPOT/ITL observable to the controller, not just TTFT
    itl: LatencyWindow = field(default_factory=LatencyWindow)
    # token-throughput samples inside the retention horizon, plus their
    # running sum: ``throughput()`` runs every controller tick for every
    # tenant, so it must not rescan the whole window each call.  Samples
    # older than ``throughput_horizon_s`` are lazily expired from the
    # left (the deque is time-ordered — ``observe_tokens`` stamps come
    # from the monotone per-engine step clock).
    throughput_window: Deque[Tuple[float, int]] = field(
        default_factory=deque)
    throughput_horizon_s: float = 10.0
    _thr_sum: int = 0
    # KV page-pool gauges (latest sample): ``kv_used_pages`` counts pages
    # holding live KV, ``kv_reserved_pages`` counts pages off the free list
    # (live + reserved-but-unwritten) — under the dense backend's
    # prompt+max_new reservation these diverge, and admission/utilisation
    # signals must distinguish them
    kv_used_pages: int = 0
    kv_reserved_pages: int = 0
    kv_total_pages: int = 0
    # prefix-cache sharing (paged backend): prompt tokens whose prefill
    # compute ran vs tokens served straight from shared prefix pages —
    # their ratio is the prefix-hit rate the --shared-prefix benchmark
    # arm reports
    prefill_tokens_total: int = 0
    prefix_hit_tokens_total: int = 0
    # speculative decode lanes (paged backend): draft rows verified vs
    # accepted by the model — their ratio is the accept rate the --spec
    # benchmark arm reports, and the adaptive-k policy's global analogue
    drafted_tokens_total: int = 0
    accepted_tokens_total: int = 0
    # response cache (paged backend): submits that consulted the
    # engine's ResponseCache vs those that found a cached completion
    # and self-primed draft_hints — the templated-traffic lever that
    # turns speculation on without client cooperation
    response_cache_lookups: int = 0
    response_cache_hits: int = 0

    def observe_tokens(self, now: float, n: int) -> None:
        self.throughput_window.append((now, n))
        self._thr_sum += n
        self._expire_tokens(now - self.throughput_horizon_s)

    def _expire_tokens(self, lo: float) -> None:
        w = self.throughput_window
        while w and w[0][0] < lo:
            _, n = w.popleft()
            self._thr_sum -= n

    def observe_prefill(self, computed: int, prefix_hits: int) -> None:
        self.prefill_tokens_total += computed
        self.prefix_hit_tokens_total += prefix_hits

    def observe_spec(self, drafted: int, accepted: int) -> None:
        self.drafted_tokens_total += drafted
        self.accepted_tokens_total += accepted

    def observe_response_cache(self, lookups: int, hits: int) -> None:
        """Latest cumulative prime counters (engine-local, so a cache
        shared across replicas still yields per-engine rates)."""
        self.response_cache_lookups = lookups
        self.response_cache_hits = hits

    def response_hit_rate(self) -> float:
        """Fraction of cache-consulting submits that self-primed."""
        if not self.response_cache_lookups:
            return 0.0
        return self.response_cache_hits / self.response_cache_lookups

    def accept_rate(self) -> float:
        """Fraction of speculative draft tokens the model accepted."""
        if not self.drafted_tokens_total:
            return 0.0
        return self.accepted_tokens_total / self.drafted_tokens_total

    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens served from the prefix cache."""
        total = self.prefill_tokens_total + self.prefix_hit_tokens_total
        if not total:
            return 0.0
        return self.prefix_hit_tokens_total / total

    def observe_kv(self, used: int, reserved: int, total: int) -> None:
        self.kv_used_pages = used
        self.kv_reserved_pages = reserved
        self.kv_total_pages = total

    def kv_utilisation(self) -> float:
        """Reserved fraction of the pool (capacity pressure)."""
        if not self.kv_total_pages:
            return 0.0
        return self.kv_reserved_pages / self.kv_total_pages

    def kv_live_utilisation(self) -> float:
        if not self.kv_total_pages:
            return 0.0
        return self.kv_used_pages / self.kv_total_pages

    def itl_p99(self, now: Optional[float] = None) -> float:
        return self.itl.quantile(0.99, now)

    def throughput(self, now: float,
                   horizon_s: Optional[float] = None) -> float:
        """Tokens/s over the trailing horizon.  The default horizon is
        the retention horizon — an O(1) read of the running sum (after
        lazily expiring stale samples).  A narrower ``horizon_s`` scans
        only the tail of the already-bounded window; a wider one is
        capped at the retention horizon (older samples are gone —
        raise ``throughput_horizon_s`` up front if you need them)."""
        if horizon_s is None or horizon_s >= self.throughput_horizon_s:
            h = self.throughput_horizon_s
            self._expire_tokens(now - h)
            return self._thr_sum / (horizon_s or h)
        self._expire_tokens(now - self.throughput_horizon_s)
        lo = now - horizon_s
        w = self.throughput_window
        tot = 0
        for t, n in reversed(w):
            if t < lo:
                break
            tot += n
        return tot / horizon_s
