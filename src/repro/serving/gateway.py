"""Request-plane front door: bounded admission queues, explicit
backpressure, and a conservation ledger over request verdicts.

The serving loop used to hand arrivals straight to an engine and ignore
the submit result — a full pool silently *dropped* the request, so the
per-tenant accounting (`offered == completed + shed`) quietly stopped
balancing under pressure.  The gateway makes every request's fate
explicit.  Each request that passes the front door ends in **exactly
one** terminal verdict:

    OFFERED ──► REJECTED   (fast: queue full / rate limit / never fits)
        │
        ├────► SHED        (tenant paused by the controller at arrival)
        │
        └──► ACCEPTED ──► EXPIRED    (queued past its dispatch deadline)
                     ├──► COMPLETED  (final token delivered)
                     └──► (redriven) (replica died: re-enqueued, still
                                      ACCEPTED — not a verdict, and it
                                      keeps its full requeue credit)

and the per-tenant ledger maintains the conservation invariant

    offered == completed + rejected + shed + expired + in_flight

at every instant (``check()`` asserts it; the test-suite property test
drives random traffic + tenant churn against it).

Backpressure policy — the 429/503 split:

* **REJECT fast** (the 429 analogue) when waiting cannot help: the
  bounded door queue is full, the tenant's Kingman-derived rate limiter
  says the arrival rate alone would blow rho past the bound, or the
  engine reports a *structural* rejection (``never_fits`` /
  ``exceeds_seq_cap``).
* **QUEUE with a deadline** (the 503 analogue) when the shortage is
  transient: the request waits in the door queue for an engine slot,
  retried each dispatch round (requeue-once on a transient
  ``pool_exhausted``), and becomes EXPIRED if the deadline passes first.

Token streaming: the gateway mirrors every engine-side token emission
into a per-request :class:`TokenStream` with the *harness* timestamp, so
a client observing the stream measures exactly the inter-token gaps that
land in ``TenantMetrics.itl`` — including the preemption-restart
subtlety where the first regenerated token's gap is measured from the
original first emission (the stream rolls back, it does not forget).
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serving.engine import ServingEngine, StepReport
from repro.serving.request import Request


class Verdict(enum.Enum):
    ACCEPTED = "accepted"        # non-terminal: in the door queue / running
    REJECTED = "rejected"        # terminal, fast-fail (429 analogue)
    SHED = "shed"                # terminal, controller pause at arrival
    EXPIRED = "expired"          # terminal, queued past deadline (503)
    COMPLETED = "completed"      # terminal, final token delivered


TERMINAL = (Verdict.REJECTED, Verdict.SHED, Verdict.EXPIRED,
            Verdict.COMPLETED)


@dataclass(frozen=True)
class DoorConfig:
    """Per-tenant front-door policy."""
    max_queue: int = 1024        # bounded admission queue (429 past this)
    deadline_s: Optional[float] = None   # queue residency bound (503)
    max_attempts: int = 2        # submit tries per request (requeue once)
    rate_limiter: Optional[object] = None   # core.admission.RateLimiter


class TokenStream:
    """Client-visible token stream with per-token timestamps.

    ``gaps`` accumulates the inter-token latencies a streaming client
    would measure; by construction they match the samples the engine
    pushes into ``TenantMetrics.itl`` (same timestamps, same
    prev-emission bookkeeping, including across preemption restarts —
    pre-preemption gaps stay recorded, mirroring the metrics window).
    """

    def __init__(self, req: Request):
        self.req = req
        self.events: List[tuple] = []    # (token, time) in delivery order
        self.gaps: List[float] = []      # inter-token latencies observed
        self.first_time: Optional[float] = None
        self.sent = 0                    # tokens delivered this "attempt"
        self._last: Optional[float] = None

    def first(self, token: int, t: float) -> None:
        self.events.append((token, t))
        self.first_time = t
        self._last = t
        self.sent = 1

    def emit(self, token: int, t: float) -> None:
        self.events.append((token, t))
        if self._last is not None:
            self.gaps.append(t - self._last)
        self._last = t
        self.sent += 1

    def rollback(self) -> None:
        """Preemption: the engine will regenerate from the first token.

        The next emitted gap is measured from the *original* first
        emission — exactly how ``finalize_step`` measures it (cleared
        ``decode_times`` fall back to the retained ``prefill_done``).
        """
        if self.sent > 0:
            self.sent = 1
            self._last = self.first_time
        # never prefilled: nothing delivered, nothing to roll back


@dataclass
class _Entry:
    req: Request
    deadline: Optional[float]
    attempts: int = 0                    # pool-exhaustion submit tries
    # recovery bookkeeping, deliberately NOT ``attempts``: a request
    # redriven after a replica death keeps its full pool-exhaustion
    # requeue credit — backpressure and recovery must not alias
    redrives: int = 0
    last_attempt: float = float("-inf")


class TenantDoor:
    """Per-tenant admission queue + verdict ledger."""

    def __init__(self, name: str, cfg: DoorConfig = DoorConfig()):
        self.name = name
        self.cfg = cfg
        self.queue: deque = deque()          # _Entry, FIFO
        self.streams: Dict[int, TokenStream] = {}
        self._state: Dict[int, Verdict] = {}     # req_id -> latest verdict
        # the ledger
        self.offered = 0
        self.rejected = 0
        self.shed = 0
        self.expired = 0
        self.completed = 0
        self.in_flight = 0
        # non-terminal transition counter: requests re-enqueued after a
        # replica death (they stay ACCEPTED/in-flight, so conservation
        # is untouched — this only counts the recovery traffic)
        self.redriven = 0
        self.reject_reasons: Dict[str, int] = {}

    # ------------------------------------------------------------- verdicts
    def _terminal(self, req: Request, verdict: Verdict,
                  reason: str = "") -> None:
        prev = self._state.get(req.req_id)
        if prev in TERMINAL:
            raise AssertionError(
                f"request {req.req_id} ({self.name}) got a second terminal "
                f"verdict {verdict.value} after {prev.value}")
        self._state[req.req_id] = verdict
        if verdict is Verdict.REJECTED:
            self.rejected += 1
            self.reject_reasons[reason] = \
                self.reject_reasons.get(reason, 0) + 1
        elif verdict is Verdict.SHED:
            self.shed += 1
        elif verdict is Verdict.EXPIRED:
            self.expired += 1
        elif verdict is Verdict.COMPLETED:
            self.completed += 1
        if prev is Verdict.ACCEPTED:
            self.in_flight -= 1

    def verdict_of(self, req_id: int) -> Optional[Verdict]:
        return self._state.get(req_id)

    def check(self) -> None:
        """Conservation invariant — every offered request is accounted."""
        balance = (self.completed + self.rejected + self.shed
                   + self.expired + self.in_flight)
        assert self.offered == balance, (
            f"verdict conservation violated for {self.name!r}: offered="
            f"{self.offered} != completed={self.completed} + rejected="
            f"{self.rejected} + shed={self.shed} + expired={self.expired}"
            f" + in_flight={self.in_flight}")
        assert self.in_flight >= len(self.queue), (
            f"{self.name!r}: {len(self.queue)} queued but only "
            f"{self.in_flight} in flight")

    def counters(self) -> Dict[str, int]:
        return {"offered": self.offered, "completed": self.completed,
                "rejected": self.rejected, "shed": self.shed,
                "expired": self.expired, "in_flight": self.in_flight,
                "redriven": self.redriven, "queued": len(self.queue)}


class Gateway:
    """The request-plane front door over a fleet of per-tenant replicas.

    Shares the *live* ``engines`` / ``routers`` dicts with the serving
    loop, so tenants admitted mid-run (tenant-plane admission control)
    get doors on first offer without re-wiring.
    """

    def __init__(self, engines: Dict[str, List[ServingEngine]],
                 routers: Optional[Dict[str, object]] = None, *,
                 door_cfgs: Optional[Dict[str, DoorConfig]] = None,
                 default_cfg: DoorConfig = DoorConfig(),
                 paused_until: Optional[Callable[[str], float]] = None,
                 tracer: Optional[object] = None):
        self.engines = engines
        self.routers = routers if routers is not None else {}
        self.door_cfgs = door_cfgs or {}
        self.default_cfg = default_cfg
        self.paused_until = paused_until or (lambda name: 0.0)
        self.doors: Dict[str, TenantDoor] = {}
        # replicas declared dead by the recovery path: masked out of
        # routing (infinite load) and never submitted to again
        self._dead: Dict[str, set] = {}
        # serving.trace.FlightRecorder (or None): door-side span sources —
        # offer/admit/expire/reject; engine-side spans flow via
        # finalize_step's own hook
        self.tracer = tracer

    def door(self, name: str) -> TenantDoor:
        d = self.doors.get(name)
        if d is None:
            d = TenantDoor(name, self.door_cfgs.get(name, self.default_cfg))
            self.doors[name] = d
        return d

    # ---------------------------------------------------------------- offer
    def offer(self, req: Request, now: float) -> Verdict:
        """Front-door decision for one arrival.  Never blocks: the
        request is SHED (paused tenant), REJECTED fast, or ACCEPTED into
        the bounded queue for dispatch."""
        door = self.door(req.tenant)
        door.offered += 1
        verdict = Verdict.ACCEPTED
        if req.arrival < self.paused_until(req.tenant):
            door._terminal(req, Verdict.SHED)
            verdict = Verdict.SHED
        elif (lim := door.cfg.rate_limiter) is not None \
                and not lim.allow(now):
            door._terminal(req, Verdict.REJECTED, "rate_limit")
            verdict = Verdict.REJECTED
        elif len(door.queue) >= door.cfg.max_queue:
            door._terminal(req, Verdict.REJECTED, "queue_full")
            verdict = Verdict.REJECTED
        else:
            door._state[req.req_id] = Verdict.ACCEPTED
            door.in_flight += 1
            deadline = None if door.cfg.deadline_s is None \
                else now + door.cfg.deadline_s
            door.queue.append(_Entry(req, deadline))
            door.streams[req.req_id] = TokenStream(req)
        if self.tracer is not None:
            self.tracer.on_offer(req, now, verdict.value)
        return verdict

    # ------------------------------------------------------------ recovery
    def mark_dead(self, name: str, idx: int) -> None:
        """Stop routing/submitting tenant ``name`` to replica ``idx``."""
        self._dead.setdefault(name, set()).add(idx)

    def mark_live(self, name: str, idx: int) -> None:
        """Readmit a replica to routing — a gray-failed replica that was
        evacuated (quarantined) can come back once its slowdown window
        passes, unlike a crashed one."""
        self._dead.get(name, set()).discard(idx)

    def live_replicas(self, name: str) -> List[int]:
        dead = self._dead.get(name, ())
        return [j for j in range(len(self.engines.get(name, [])))
                if j not in dead]

    def redrive(self, name: str, reqs: List[Request], now: float, *,
                from_engine: int = -1) -> int:
        """Re-enqueue a dead replica's in-flight requests for dispatch
        to a survivor.  The requests stay ACCEPTED — no verdict is
        spent, so conservation holds by construction — and each fresh
        entry carries a **full** pool-exhaustion requeue credit
        (``attempts=0``): recovery must never eat into backpressure's
        budget.  Partially-streamed requests roll their stream back
        exactly like a preemption (regeneration re-emits from the
        original first-token clock).  Returns the number redriven."""
        door = self.door(name)
        n = 0
        for req in reversed(reqs):     # appendleft: preserve FIFO order
            if door._state.get(req.req_id) is not Verdict.ACCEPTED:
                continue               # already terminal: nothing to save
            st = door.streams.get(req.req_id)
            if st is not None:
                st.rollback()
            deadline = None if door.cfg.deadline_s is None \
                else now + door.cfg.deadline_s
            door.queue.appendleft(_Entry(req, deadline, redrives=1))
            door.redriven += 1
            n += 1
            if self.tracer is not None:
                self.tracer.on_redrive(req, now, from_engine=from_engine)
        return n

    def adopt_warm(self, name: str, reqs: List[Request], now: float,
                   arrive_time: float, *, from_engine: int = -1,
                   to_engine: int = -1) -> int:
        """Live migration landed: ``reqs`` are already resident on the
        destination replica (their KV pages shipped and verified), so
        unlike :meth:`redrive` they do NOT re-enter the door queue and
        their token streams do NOT roll back — the lane resumes where it
        left off, TTFT stamp conserved.  Still ACCEPTED, still in
        flight: conservation is untouched.  The flight recorder's
        handoff segment spans the transfer (``now`` → ``arrive_time``).
        Returns the number adopted."""
        door = self.door(name)
        n = 0
        for req in reqs:
            if door._state.get(req.req_id) is not Verdict.ACCEPTED:
                continue
            door.redriven += 1
            n += 1
            if self.tracer is not None:
                self.tracer.on_redrive(req, now, from_engine=from_engine)
                self.tracer.on_admit(req, arrive_time, engine=to_engine)
        return n

    def abandon(self, name: str, reqs: List[Request], now: float, *,
                reason: str = "replica_crash") -> int:
        """Recovery-off path: a dead replica's in-flight requests are
        SHED (their single terminal verdict) instead of redriven."""
        door = self.door(name)
        n = 0
        for req in reqs:
            if door._state.get(req.req_id) is not Verdict.ACCEPTED:
                continue
            door.streams.pop(req.req_id, None)
            door._terminal(req, Verdict.SHED)
            n += 1
            if self.tracer is not None:
                self.tracer.on_terminal(req, now, "shed", reason=reason)
        return n

    # ------------------------------------------------------------- dispatch
    def _route(self, name: str, req: Request) -> int:
        engs = self.engines[name]
        dead = self._dead.get(name, ())
        loads = [float("inf") if j in dead
                 else len(e.queue) + len(e.active())
                 for j, e in enumerate(engs)]
        router = self.routers.get(name)
        if router is not None:
            return router.route(req, loads)
        return int(np.argmin(loads))

    def dispatch(self, now: float) -> int:
        """Drain door queues into engines.  Returns submits that landed.

        Head-of-line per tenant: expired entries fall out first, then
        the head is submitted at most once per dispatch round; a
        transient rejection (pool exhausted) leaves it queued for a
        retry (bounded by ``max_attempts``), a structural one or an
        exhausted retry budget turns into a REJECTED verdict.
        """
        landed = 0
        for name, door in list(self.doors.items()):
            while door.queue:
                entry = door.queue[0]
                if entry.deadline is not None and now >= entry.deadline:
                    door.queue.popleft()
                    door.streams.pop(entry.req.req_id, None)
                    door._terminal(entry.req, Verdict.EXPIRED)
                    if self.tracer is not None:
                        self.tracer.on_terminal(entry.req, now, "expired")
                    continue
                if entry.last_attempt >= now:
                    break                   # already tried this instant
                if name not in self.engines or not self.engines[name]:
                    break                   # replicas not wired yet
                if not self.live_replicas(name):
                    break                   # every replica is dead
                entry.attempts += 1
                entry.last_attempt = now
                idx = self._route(name, entry.req)
                outcome = self.engines[name][idx].submit(entry.req)
                if outcome:
                    entry.req.submitted = now
                    door.queue.popleft()
                    landed += 1
                    if self.tracer is not None:
                        self.tracer.on_admit(entry.req, now, engine=idx)
                    continue
                if not outcome.transient \
                        or entry.attempts >= door.cfg.max_attempts:
                    door.queue.popleft()
                    door.streams.pop(entry.req.req_id, None)
                    door._terminal(entry.req, Verdict.REJECTED,
                                   outcome.reason)
                    if self.tracer is not None:
                        self.tracer.on_terminal(entry.req, now, "rejected",
                                                reason=outcome.reason)
                    continue
                break       # transient shortage: hold the line, retry later
        return landed

    # ------------------------------------------------------------- finalize
    def finalize(self, name: str, eng: ServingEngine, report: StepReport,
                 end_time: float,
                 start_time: Optional[float] = None) -> None:
        """Timestamp an engine step *and* mirror it into door state:
        engine metrics first (the authoritative clocks), then streams
        (first token / per-token emissions / preemption rollbacks) and
        terminal COMPLETED verdicts.  ``start_time`` (the step's launch
        instant on the virtual clock) flows to the engine's trace hook so
        prefill-chunk spans cover the step window rather than a point."""
        eng.finalize_step(report, end_time, start_time)
        door = self.doors.get(name)
        if door is None:
            return
        for req in report.preempted:
            st = door.streams.get(req.req_id)
            if st is not None:
                st.rollback()
        for req in report.prefilled:
            st = door.streams.get(req.req_id)
            if st is not None and req.output_tokens:
                st.first(req.output_tokens[0], end_time)
        for req in report.decoded:
            # one entry per committed token (spec bursts repeat the
            # request) — emit each, preserving multiplicity so stream
            # gaps match the metrics window sample-for-sample
            st = door.streams.get(req.req_id)
            if st is not None:
                idx = min(st.sent, len(req.output_tokens) - 1)
                st.emit(req.output_tokens[idx], end_time)
        for req in report.completed:
            if door._state.get(req.req_id) is Verdict.ACCEPTED:
                door._terminal(req, Verdict.COMPLETED)

    # ------------------------------------------------------------ inventory
    def queued_total(self) -> int:
        return sum(len(d.queue) for d in self.doors.values())

    def check(self) -> None:
        for door in self.doors.values():
            door.check()

    def counters(self) -> Dict[str, Dict[str, int]]:
        return {name: d.counters() for name, d in self.doors.items()}

    # ----------------------------------------------------------- prometheus
    @staticmethod
    def _pool_p99(windows, now: Optional[float]) -> float:
        vals: List[float] = []
        for w in windows:
            vals.extend(v for _, v in w.samples)
        if not vals:
            return 0.0
        return float(np.quantile(np.asarray(vals), 0.99))

    def prometheus(self, now: Optional[float] = None) -> str:
        """Prometheus text exposition of the gateway's view of the
        fleet: verdict ledger, queue/lane gauges, cache-efficacy rates,
        and the door- vs engine-measured TTFT tails."""
        lines: List[str] = []

        def emit(metric: str, help_: str, typ: str, rows) -> None:
            lines.append(f"# HELP {metric} {help_}")
            lines.append(f"# TYPE {metric} {typ}")
            for labels, value in rows:
                lab = ",".join(f'{k}="{v}"' for k, v in labels.items())
                lines.append(f"{metric}{{{lab}}} {value:g}")

        names = sorted(set(self.doors) | set(self.engines))
        doors = {n: self.door(n) for n in names}
        engs = {n: self.engines.get(n, []) for n in names}

        emit("gateway_offered_total", "Requests offered at the front door.",
             "counter", [({"tenant": n}, doors[n].offered) for n in names])
        emit("gateway_verdict_total", "Terminal verdicts by type.", "counter",
             [({"tenant": n, "verdict": v}, getattr(doors[n], v))
              for n in names
              for v in ("completed", "rejected", "shed", "expired")]
             + [({"tenant": n, "verdict": "accepted"},
                 doors[n].in_flight + doors[n].completed) for n in names])
        emit("gateway_queue_depth", "Requests waiting in the door queue.",
             "gauge", [({"tenant": n}, len(doors[n].queue)) for n in names])
        emit("gateway_in_flight", "Accepted requests not yet terminal.",
             "gauge", [({"tenant": n}, doors[n].in_flight) for n in names])

        active = {n: sum(len(e.active()) for e in engs[n]) for n in names}
        cap = {n: sum(e.max_slots for e in engs[n]) for n in names}
        emit("gateway_active_lanes", "Decode lanes currently occupied.",
             "gauge", [({"tenant": n}, active[n]) for n in names])
        emit("gateway_saturation", "Active lanes / lane capacity.", "gauge",
             [({"tenant": n}, active[n] / cap[n] if cap[n] else 0.0)
              for n in names])

        def rate(n: str, num_attr: str, den_attr: str,
                 den_plus_num: bool = False) -> float:
            num = sum(getattr(e.metrics, num_attr) for e in engs[n])
            den = sum(getattr(e.metrics, den_attr) for e in engs[n])
            if den_plus_num:
                den += num
            return num / den if den else 0.0

        emit("gateway_prefix_hit_rate",
             "Prompt tokens served from the shared prefix cache.", "gauge",
             [({"tenant": n},
               rate(n, "prefix_hit_tokens_total", "prefill_tokens_total",
                    den_plus_num=True)) for n in names])
        emit("gateway_spec_accept_rate",
             "Speculative draft tokens accepted by the model.", "gauge",
             [({"tenant": n},
               rate(n, "accepted_tokens_total", "drafted_tokens_total"))
              for n in names])
        emit("gateway_response_cache_hit_rate",
             "Submits self-primed from the response cache.", "gauge",
             [({"tenant": n},
               rate(n, "response_cache_hits", "response_cache_lookups"))
              for n in names])

        emit("gateway_door_ttft_p99_seconds",
             "TTFT p99 measured from front-door arrival.", "gauge",
             [({"tenant": n},
               self._pool_p99([e.metrics.latency for e in engs[n]], now))
              for n in names])
        emit("gateway_engine_ttft_p99_seconds",
             "TTFT p99 measured from engine submit.", "gauge",
             [({"tenant": n},
               self._pool_p99([e.metrics.engine_ttft for e in engs[n]], now))
              for n in names])

        # cumulative histograms: unlike the windowed p99 gauges above,
        # bucket counts are never trimmed, so they aggregate correctly
        # across replicas and scrape intervals (rate() / histogram_quantile)
        def emit_hist(metric: str, help_: str, attr: str) -> None:
            lines.append(f"# HELP {metric} {help_}")
            lines.append(f"# TYPE {metric} histogram")
            for n in names:
                windows = [getattr(e.metrics, attr) for e in engs[n]]
                acc: List[List[float]] = []
                total_sum = 0.0
                # per-bucket exemplar: slowest retained sample across the
                # tenant's replicas (OpenMetrics `# {req_id="..."} v ts`
                # suffix on the bucket line) — the request a dashboard
                # drill-down from this bucket should land on
                exemplars: List[Optional[tuple]] = []
                for w in windows:
                    h = w.hist()
                    if not acc:
                        acc = [[le, float(c)] for le, c in h]
                        exemplars = list(w.exemplars)
                    else:
                        for i, (_, c) in enumerate(h):
                            acc[i][1] += c
                        for i, ex in enumerate(w.exemplars):
                            if ex is not None and (exemplars[i] is None
                                                   or ex[0] > exemplars[i][0]):
                                exemplars[i] = ex
                    total_sum += w.sum
                count = acc[-1][1] if acc else 0.0
                for i, (le, c) in enumerate(acc):
                    tag = "+Inf" if le == float("inf") else f"{le:g}"
                    line = f'{metric}_bucket{{tenant="{n}",le="{tag}"}} {c:g}'
                    ex = exemplars[i] if i < len(exemplars) else None
                    if ex is not None:
                        val, rid, ts = ex
                        line += f' # {{req_id="{rid}"}} {val:g} {ts:g}'
                    lines.append(line)
                lines.append(f'{metric}_sum{{tenant="{n}"}} {total_sum:g}')
                lines.append(f'{metric}_count{{tenant="{n}"}} {count:g}')

        emit_hist("gateway_door_ttft_seconds",
                  "TTFT from front-door arrival to first token.", "latency")
        emit_hist("gateway_engine_ttft_seconds",
                  "TTFT from engine submit to first token.", "engine_ttft")
        emit_hist("gateway_itl_seconds",
                  "Inter-token latency between streamed emissions.", "itl")
        return "\n".join(lines) + "\n"
