"""Multi-pod dry-run: lower + compile every (architecture x input shape)
against the production mesh, and extract the roofline terms.

MUST set the placeholder device count before ANY other import — jax locks
the device count on first init.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax             # noqa: E402
import numpy as np     # noqa: E402

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, InputShape,  # noqa: E402
                                ModelConfig, get_config)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402
from repro.models.model import model_plan  # noqa: E402
from repro.models.params import (count_params, param_bytes,  # noqa: E402
                                 shardings_from_plan, specs_from_plan)
from repro.training.optimizer import state_plan  # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def collective_bytes_from_hlo(hlo: str, scan_multipliers: Dict[str, int]
                              ) -> Dict[str, float]:
    """Sum result sizes of every collective op in the compiled HLO.

    Collectives inside ``while`` bodies (lax.scan over layers) execute once
    per trip; we multiply ops found in non-entry computations matching a
    known scan by its trip count (the layer-stack ``repeats``).
    """
    totals = {c: 0.0 for c in _COLLECTIVES}
    current_comp = ""
    default_mult = max(scan_multipliers.values()) if scan_multipliers else 1
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.startswith("%") and "{" in stripped and "(" in stripped \
                and "=" not in stripped.split("(")[0]:
            current_comp = stripped.split(" ")[0]
            continue
        if stripped.startswith("ENTRY"):
            current_comp = "ENTRY"
            continue
        for coll in _COLLECTIVES:
            if f" {coll}(" in stripped or f"{coll}-start(" in stripped:
                m = _SHAPE_RE.search(stripped)
                if not m:
                    continue
                dtype, dims = m.group(1), m.group(2)
                size = _DTYPE_BYTES.get(dtype, 2)
                if dims:
                    size *= int(np.prod([int(d) for d in dims.split(",")]))
                mult = 1
                if current_comp != "ENTRY" and (
                        "body" in current_comp or "while" in current_comp
                        or "scan" in current_comp):
                    mult = default_mult
                totals[coll] += float(size) * mult
                break
    return totals


def roofline_terms(cost: dict, coll_bytes: float, num_chips: int,
                   scan_mult: int = 1) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0)) * scan_mult
    hbm = float(cost.get("bytes accessed", 0.0)) * scan_mult
    return {
        "hlo_flops": flops,
        "hlo_bytes": hbm,
        "collective_bytes": coll_bytes,
        "t_compute": flops / (num_chips * PEAK_FLOPS),
        "t_memory": hbm / (num_chips * HBM_BW),
        "t_collective": coll_bytes / (num_chips * ICI_BW),
    }


def _leaf_bytes_per_device(plan, mesh) -> int:
    """Analytic per-device bytes for a plan tree under its resolved specs."""
    import jax.numpy as jnp
    from repro.models.params import P, resolve_pspec, _axis_size

    def leaf(p: P) -> int:
        spec = resolve_pspec(mesh, p)
        n = 1
        for dim, entry in zip(p.shape, tuple(spec) + (None,) * len(p.shape)):
            ext = _axis_size(mesh, entry) if entry is not None else 1
            n *= -(-dim // max(ext, 1))
        return n * jnp.dtype(p.dtype).itemsize

    leaves = jax.tree.leaves(plan, is_leaf=lambda x: isinstance(x, P))
    return int(sum(leaf(p) for p in leaves))


def analytic_memory(cfg: ModelConfig, shape: InputShape, mesh,
                    policy=None) -> Dict[str, float]:
    """TPU-faithful per-device HBM estimate (bf16 params/activations).

    The CPU backend's memory_analysis() over-reports because XLA-on-CPU
    promotes bf16 compute to f32 and hoists whole-residual-stack converts
    out of loops (measured 3x on the saved activation stacks).  This model
    reconstructs the TPU budget from the plans: params (+grads +Adam
    moments for train), the remat residual stack, decode caches, and a
    working-set allowance.
    """
    from repro.launch.shardings import make_policy
    from repro.launch.specs import decode_arg_plans
    from repro.models.model import model_plan as _mp

    policy = policy or make_policy(cfg, shape, mesh)
    pplan = _mp(cfg)
    params_b = _leaf_bytes_per_device(pplan, mesh)
    out: Dict[str, float] = {"params": params_b}
    data_shards = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            data_shards *= mesh.shape[a]
    if shape.mode == "train":
        out["grads"] = params_b
        out["adam_moments"] = 4 * count_params(pplan) // max(
            np.prod([mesh.shape[a] for a in mesh.axis_names]), 1) * 2
        b_local = max(1, shape.global_batch // data_shards)
        # one bf16 residual per scanned layer (jax.checkpoint saves carries);
        # sequence parallelism shards the saved stack over `model`
        seq_shards = 1
        if policy.act and len(policy.act) > 1 and policy.act[1] == "model":
            seq_shards = mesh.shape.get("model", 1)
        out["residual_stack"] = (cfg.num_layers * b_local * shape.seq_len
                                 * cfg.d_model * 2 // seq_shards)
        out["working_set"] = 2 << 30
    else:
        cplan, _, _ = decode_arg_plans(cfg, shape, mesh)
        out["kv_cache"] = _leaf_bytes_per_device(cplan, mesh)
        out["working_set"] = 1 << 30
    out = {k: float(v) for k, v in out.items()}
    out["total"] = float(sum(out.values()))
    return out


def analytic_terms(cfg: ModelConfig, shape: InputShape, num_chips: int,
                   q_chunk: int = 512) -> Dict[str, float]:
    """Exact per-step FLOPs/bytes from the architecture math (bf16 on TPU).

    Needed because XLA's cost_analysis counts each while body ONCE: the
    layer scan is corrected by `repeats`, but *nested* scans (the chunked
    attention) would need per-while trip counts the text dump doesn't
    carry.  The analytic model is exact for the dense algebra and is the
    §Roofline/§Perf metric of record; HLO terms are the cross-check.
    """
    mode = shape.mode
    tokens = shape.global_batch * (shape.seq_len if mode != "decode" else 1)
    d = cfg.d_model
    flops = 0.0
    hbm = 0.0
    cap_of = lambda w: min(shape.seq_len, w) if w else shape.seq_len
    for layer in cfg.layer_specs():
        # ---- mixer ----
        if layer.mixer == "attn":
            a = cfg.attn
            if a.kind == "mla":
                qk = a.nope_head_dim + a.rope_head_dim
                proj = (d * a.q_lora_rank + a.q_lora_rank * a.num_heads * qk
                        + d * (a.kv_lora_rank + a.rope_head_dim)
                        + a.kv_lora_rank * a.num_heads
                        * (a.nope_head_dim + a.v_head_dim)
                        + a.num_heads * a.v_head_dim * d)
                hd_eff = qk
                kv_bytes_tok = (a.kv_lora_rank + a.rope_head_dim) * 2
                heads = a.num_heads
            else:
                proj = d * (a.num_heads + 2 * a.num_kv_heads) * a.head_dim \
                    + a.num_heads * a.head_dim * d
                hd_eff = a.head_dim
                kv_bytes_tok = 2 * a.num_kv_heads * a.head_dim * 2
                heads = a.num_heads
            flops += 2 * tokens * proj
            if mode == "decode":
                span = cap_of(layer.window)
                flops += 4 * shape.global_batch * heads * hd_eff * span
                hbm += shape.global_batch * span * kv_bytes_tok  # cache read
            else:
                # chunked causal attention; windowed layers clip to the span
                if layer.window and layer.window + q_chunk < shape.seq_len:
                    span = layer.window + q_chunk
                    flops += 4 * shape.global_batch * heads * hd_eff \
                        * shape.seq_len * span
                else:
                    flops += 4 * shape.global_batch * heads * hd_eff \
                        * shape.seq_len * (shape.seq_len + 1) / 2
        elif layer.mixer == "mamba":
            m = cfg.mamba
            d_in = m.expand * d
            dt_rank = m.dt_rank or -(-d // 16)
            proj = d * 2 * d_in + d_in * (dt_rank + 2 * m.d_state) \
                + dt_rank * d_in + d_in * d
            flops += 2 * tokens * proj + 6 * tokens * d_in * m.d_state
        elif layer.mixer == "rwkv6":
            r = cfg.rwkv
            flops += 2 * tokens * (5 * d * d + d * r.decay_lora * 2) \
                + 4 * tokens * d * r.head_dim
        # ---- ffn ----
        f = cfg.ffn_spec_for(layer)
        if layer.ffn == "dense":
            flops += 2 * tokens * 3 * d * f.d_ff
        elif layer.ffn == "moe":
            active = f.top_k + f.num_shared_experts
            flops += 2 * tokens * (d * f.num_experts
                                   + active * 3 * d * f.d_ff)
        elif layer.ffn == "rwkv_cm":
            flops += 2 * tokens * (2 * d * d + 2 * d * cfg.rwkv.d_ffn)
    # embeddings / logits
    flops += 2 * tokens * d * cfg.vocab_size if mode != "decode" else \
        2 * shape.global_batch * d * cfg.vocab_size
    if cfg.encoder is not None and mode != "decode":
        enc_tok = shape.global_batch * shape.seq_len
        enc = cfg.encoder
        per = 2 * (4 * d * d + 3 * d * enc.d_ff)
        flops += enc.num_layers * (enc_tok * per
                                   + 4 * enc_tok * shape.seq_len * d)
    if mode == "train":
        flops *= 3.0          # fwd + bwd (2x) ; remat recompute folded into hbm
    # memory: weights read once per step + activation IO (2 passes bf16)
    params_bytes = param_bytes(model_plan(cfg))
    hbm += params_bytes * (3.0 if mode == "train" else 1.0)
    hbm += tokens * d * 2 * cfg.num_layers * (4.0 if mode == "train" else 2.0)
    return {
        "flops_analytic": flops,
        "hbm_bytes_analytic": hbm,
        "t_compute_analytic": flops / (num_chips * PEAK_FLOPS),
        "t_memory_analytic": hbm / (num_chips * HBM_BW),
    }


def dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
           verbose: bool = True, policy_override=None,
           extra_tag: str = "") -> Optional[dict]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        if verbose:
            print(f"SKIP {arch} x {shape_name}: full-attention arch "
                  f"(sub-quadratic rule, see DESIGN.md)")
        return None
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with mesh:
        step_fn, args, shardings, out_shardings, donate = build_step(
            cfg, shape, mesh, policy_override=policy_override)
        lowered = jax.jit(step_fn, in_shardings=shardings,
                          out_shardings=out_shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    scan_mult = max(cfg.repeats, 1)
    colls = collective_bytes_from_hlo(hlo, {"layers": scan_mult})
    coll_total = sum(colls.values())
    # cost_analysis on CPU counts while bodies once; scale by trip count
    terms = roofline_terms(cost, coll_total, num_chips, scan_mult=scan_mult)
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "num_chips": num_chips, "mode": shape.mode,
        "params": count_params(model_plan(cfg)),
        "compile_s": round(compile_s, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "total_peak": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": colls,
        "analytic_memory": analytic_memory(cfg, shape, mesh,
                                           policy=policy_override),
        **terms,
        **analytic_terms(cfg, shape, num_chips,
                         q_chunk=int(os.environ.get("REPRO_Q_CHUNK", "512"))),
    }
    if verbose:
        gb = result["bytes_per_device"]["total_peak"] / 2**30
        agb = result["analytic_memory"]["total"] / 2**30
        dom = max(("t_compute_analytic", "t_memory_analytic",
                   "t_collective"), key=lambda k: result[k])
        print(f"{arch:24s} {shape_name:12s} chips={num_chips:3d} "
              f"compile={compile_s:6.1f}s peak/dev={gb:7.2f}GiB "
              f"(tpu-est {agb:6.2f}GiB) "
              f"Tc={result['t_compute_analytic']*1e3:8.3f}ms "
              f"Tm={result['t_memory_analytic']*1e3:8.3f}ms "
              f"Tx={result['t_collective']*1e3:8.3f}ms dom={dom} "
              f"[hlo Tc={result['t_compute']*1e3:.2f} "
              f"Tm={result['t_memory']*1e3:.2f}]")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod and multi-pod")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                try:
                    res = dryrun(arch, shape, multi_pod=mp)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append((tag, repr(e)[:200]))
                    print(f"FAIL {tag}: {repr(e)[:200]}")
                    continue
                if res is not None:
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(res, f, indent=2)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nAll dry-runs passed.")


if __name__ == "__main__":
    main()
