"""ShapeDtypeStruct input stand-ins + shardings for every model input —
the dry-run's contract: weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import InputShape, ModelConfig
from repro.launch.shardings import _bd, make_policy
from repro.models.model import cache_plan
from repro.models.params import P, shardings_from_plan, specs_from_plan
from repro.training.optimizer import state_plan


def _decoder_text_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Text positions for multimodal models so total tokens == seq_len."""
    if cfg.frontend.kind == "vision":
        return shape.seq_len - cfg.frontend.num_prefix
    if cfg.encoder is not None:
        # enc-dec: encoder consumes seq_len frames; decoder gets a prompt
        return min(128, shape.seq_len) if shape.mode == "prefill" \
            else shape.seq_len
    return shape.seq_len


def batch_plan(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, P]:
    bd = _bd(mesh)
    b = shape.global_batch
    tok = (bd, None) if b > 1 else ()
    s_text = _decoder_text_len(cfg, shape)
    plan: Dict[str, P] = {}
    if cfg.encoder is not None:
        plan["frames"] = P((b, shape.seq_len, cfg.frontend.embed_dim),
                           dtype="bfloat16", pspec=tok + (None,))
        plan["tokens"] = P((b, s_text), dtype="int32", pspec=tok)
    elif cfg.frontend.kind == "vision":
        plan["embeds"] = P((b, cfg.frontend.num_prefix,
                            cfg.frontend.embed_dim), dtype="bfloat16",
                           pspec=tok + (None,))
        plan["tokens"] = P((b, s_text), dtype="int32", pspec=tok)
    else:
        plan["tokens"] = P((b, shape.seq_len), dtype="int32", pspec=tok)
    if shape.mode == "train":
        plan["labels"] = P(plan["tokens"].shape, dtype="int32", pspec=tok)
    return plan


def decode_arg_plans(cfg: ModelConfig, shape: InputShape, mesh):
    """(cache_plan, token_plan, positions_plan) for serve_step lowering."""
    policy = make_policy(cfg, shape, mesh)
    b = shape.global_batch
    bd = _bd(mesh)
    tok = (bd,) if b > 1 else ()
    enc_len = shape.seq_len if cfg.encoder is not None else 0
    cplan = cache_plan(cfg, b, shape.seq_len, policy, enc_len=enc_len)
    return (cplan,
            P((b,), dtype="int32", pspec=tok),
            P((b,), dtype="int32", pspec=tok))


def input_specs(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, Any]:
    """All ShapeDtypeStruct stand-ins + NamedShardings for one combo.

    Returns {"args": tuple, "shardings": tuple, "policy": ShardPolicy} where
    args excludes params/opt-state (those come from the model plan).
    """
    policy = make_policy(cfg, shape, mesh)
    if shape.mode in ("train", "prefill"):
        bplan = batch_plan(cfg, shape, mesh)
        return {
            "args": (specs_from_plan(bplan),),
            "shardings": (shardings_from_plan(bplan, mesh),),
            "policy": policy,
        }
    cplan, tplan, pplan = decode_arg_plans(cfg, shape, mesh)
    args = (specs_from_plan(cplan), specs_from_plan(tplan),
            specs_from_plan(pplan))
    shardings = (shardings_from_plan(cplan, mesh),
                 shardings_from_plan(tplan, mesh),
                 shardings_from_plan(pplan, mesh))
    return {"args": args, "shardings": shardings, "policy": policy}
