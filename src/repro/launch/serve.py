"""Serving launcher: engines + controller co-deployed (the paper's
first-class integration), generalized to N latency tenants x R replicas.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b \
        --requests 32 --qps 4 [--tenants 2] [--replicas 2] \
        [--interfere] [--no-controller] [--admit 2] [--backend paged]

``--backend paged`` swaps every tenant-replica engine onto the
block-table paged runtime (chunked prefill + SLO-aware preemption over a
shared page pool) instead of the dense slot cache; the rest of the
harness — fabric, controller, admission — is unchanged.  ``--spec-k K``
additionally enables speculative multi-token decode lanes (n-gram
prompt-lookup drafts verified in the fused ragged step, adaptive per-lane
depth).

Replica dispatch is cache-aware by default: every paged replica
publishes its prefix cache into a per-tenant content-hash
``PrefixDirectory`` and requests route to the replica holding the
longest prefix of their prompt, falling back to least-loaded when the
directory misses, lags (``--route-staleness``), or the target's queue
lead exceeds ``--route-imbalance``.  ``--route load`` restores blind
least-loaded dispatch (the A/B baseline).  A per-tenant
``ResponseCache`` shared across replicas additionally primes
``draft_hints`` for repeated prompts (``--no-response-cache`` to
disable; only drafts anything when ``--spec-k`` > 0).

Runs one continuous-batching engine per tenant-replica on the reduced
config, all sharing a FabricState (the PS fabric model injects PCIe-class
interference when --interfere is set), with the multi-tenancy controller
steering quotas, placements and slice profiles per tenant.  Placement
state lives in a shared DeviceLedger built from the TenantRegistry, the
same bookkeeping the simulator uses — and ``--admit K`` exercises the
paper's §2.3 admission path: K late-arriving tenants are scored against
the live ledger mid-run; admitted ones get engines and traffic, the rest
queue or are rejected.  Virtual time: replicas run in parallel — each
engine owns an availability clock and the global clock advances to the
next event (arrival, sample, step finish, admission).
"""
from __future__ import annotations

import argparse


def warm_engine(eng, name: str, prompt_len: int) -> None:
    """Prime an engine's jit caches WITHOUT polluting observable state.

    The warm request (req_id=-1) runs at virtual time 0, so letting it
    touch shared state plants three lies: a zero-latency sample in
    ``TenantMetrics.latency`` (seeding the controller's p99 signal with
    a bogus 0), its output in the tenant's shared ``ResponseCache``
    (primeable by real traffic), and its prefix pages in the
    ``PrefixDirectory`` (cache-aware routing toward KV no request
    wants).  So: detach the directory listener and the response cache
    for the drain, then reset the engine's metrics to a clean slate.
    """
    from repro.serving.metrics import TenantMetrics
    from repro.serving.request import Request

    listener, eng.kv.listener = getattr(eng.kv, "listener", None), None
    sched = eng.runtime.sched if eng.runtime is not None else None
    rcache = None
    if sched is not None:
        rcache, sched.response_cache = sched.response_cache, None
    try:
        eng.submit(Request(req_id=-1, tenant=name, prompt_len=prompt_len,
                           max_new_tokens=2, arrival=0.0))
        while eng.has_work():
            eng.finalize_step(eng.step(), 0.0)
    finally:
        eng.kv.listener = listener
        if sched is not None:
            sched.response_cache = rcache
            sched.rc_lookups = 0
            sched.rc_hits = 0
    eng.metrics = TenantMetrics()


def serve(arch: str = "stablelm_3b", requests: int = 32, qps: float = 4.0,
          prompt_len: int = 48, max_new: int = 8, slots: int = 4,
          num_tenants: int = 1, replicas: int = 1, interfere: bool = False,
          with_controller: bool = True, seed: int = 0, verbose: bool = True,
          admit: int = 0, backend: str = "dense", kv_dtype: str = "auto",
          prefix_cache: bool = True, spec_k: int = 0, route: str = "cache",
          route_imbalance: int = 4, route_staleness: int = 256,
          response_cache: bool = True, listen: bool = False,
          door_queue: int = 64, door_deadline_ms: float = 1000.0,
          trace: bool = False, trace_out: str = None,
          chaos: bool = False, chaos_seed: int = None,
          recover: bool = True, faults=None,
          watchdog_timeout_s: float = 1.5,
          migrate: bool = False, drains=None,
          gray_threshold: float = 2.5, gray_cooldown_s: float = 2.0,
          det_timing: bool = False, exact_tokens: bool = False,
          unique_prompts: bool = False):
    """Virtual-time multi-tenant serving run; returns per-tenant stats.

    ``listen=True`` (the ``--listen`` flag) turns on the gateway's
    backpressure policy: bounded per-tenant door queues of
    ``door_queue``, a ``door_deadline_ms`` dispatch deadline (queued
    requests that outlive it are EXPIRED — the 503 path), and a
    Kingman-derived per-tenant rate limiter (arrivals past the rate
    that keeps rho under the admission bound are REJECTED fast — the
    429 path).  Without it the gateway still fronts every request with
    an effectively unbounded patient door, so the verdict-conservation
    ledger holds on both paths.

    ``trace=True`` (or ``trace_out=<path>``) arms the per-request
    flight recorder: every request accrues a span timeline
    (door_queued -> sched_queued -> prefill chunks -> decode, with
    preemption windows and speculative verify events) whose segments
    sum to its measured E2E, and every controller/actuator action lands
    on a shared virtual-clock timeline.  ``trace_out`` additionally
    dumps a Chrome/Perfetto ``trace_event`` JSON.  Disabled tracing is
    zero-cost (every call site is None-guarded) and tracing never
    perturbs the virtual clock — token output and timings are identical
    either way.

    ``chaos=True`` (or an explicit ``faults=FaultInjector(...)``) arms
    deterministic fault injection: a seeded virtual-clock schedule of
    replica crashes, actuator-call failures, stuck decode lanes and
    fabric degradation windows (``core/faults.py``).  With
    ``recover=True`` (default) a crashed replica's in-flight requests
    are drained and *redriven* onto survivors through the gateway (the
    prefix directory retracts the dead holder, the router stops routing
    to it, the device ledger releases its slots), actuator calls go
    through a bounded-retry wrapper with rollback-to-last-good, and a
    watchdog requeues hung lanes through the scheduler's refcount-safe
    preemption path.  ``recover=False`` keeps the same fault schedule
    but sheds the dead replica's requests — the A/B baseline the
    ``llm_ttft --chaos`` benchmark measures against.  Either way every
    request still gets exactly one terminal verdict and the gateway's
    conservation ledger holds.

    ``migrate=True`` upgrades recovery from recompute to *verified
    state transfer* (``serving/migrate.py``): a failing replica's lanes
    ship their KV page chains (chain-hashed, with int8 scales) to the
    least-loaded live peer, which recomputes every chain hash before
    committing — a mismatch silently degrades that lane to the
    recompute redrive, never a wrong token.  Three triggers: replica
    crash (warm adoption from the shared host pool), ``drains=``
    planned scale-downs (evacuate instead of shed), and gray failure —
    a tail-based detector compares per-token step cost across live
    peers and evacuates a degraded-but-alive replica (quarantined
    for ``gray_cooldown_s``, then readmitted) before the watchdog
    fires.  Transfer time is priced against the ledger's per-root
    fabric demand like any tenant flow.

    ``det_timing=True`` replaces the measured wall-clock step time with
    a deterministic per-token cost model.  Normally each step's
    ``compute_s`` is real measured time, so machine noise perturbs the
    virtual schedule (and with it batching, chunk boundaries and
    ultimately greedy argmax near-ties) run to run.  With the model,
    the whole run is bit-reproducible — which is what lets the
    ``llm_ttft --migrate`` A/B assert exact token parity between arms.
    ``exact_tokens=True`` additionally pins float32 weights and the
    reference attention path, making greedy output a pure function of
    the prompt: batch shape and chunk boundaries stop perturbing argmax
    near-ties, so even recomputed (re-prefilled) lanes regenerate
    byte-identical tokens — the same setup ``tests/test_faults.py``
    uses for its token-parity property.
    """
    from collections import deque

    import numpy as np
    from repro.configs.base import get_config, reduced
    from repro.serving.directory import (CacheAwareRouter, PrefixDirectory,
                                         ResponseCache, RouterConfig)
    from repro.serving.engine import ServingEngine
    from repro.serving.gateway import DoorConfig, Gateway
    from repro.serving.request import Request
    from repro.serving.actuator import FabricState, ServingActuator
    from repro.core.admission import (AdmissionController, AdmissionConfig,
                                      AdmissionVerdict, RateLimiter)
    from repro.core.controller import Controller, ControllerConfig
    from repro.core.faults import (FaultInjector, RetryingActuator,
                                   StuckLaneWatchdog)
    from repro.core.ledger import DeviceLedger
    from repro.core.policy import PolicyConfig
    from repro.core.profiles import A100_MIG
    from repro.core.signals import Snapshot, SystemSignals, TenantSignals
    from repro.core.tenancy import (BACKGROUND, TenantRegistry, TenantSpec)
    from repro.core.topology import Slot, make_p4d_cluster
    from repro.serving.metrics import LatencyWindow

    if num_tenants < 1 or replicas < 1:
        raise SystemExit("--tenants and --replicas must be >= 1")
    if route not in ("cache", "load"):
        raise SystemExit("--route must be 'cache' or 'load'")
    cfg = reduced(get_config(arch))
    if exact_tokens:
        # float32 + reference attention: greedy argmax becomes a pure
        # function of the prompt, independent of batch shape and chunk
        # boundaries — required for cross-arm token-parity asserts
        import dataclasses as _dc
        cfg = _dc.replace(cfg, dtype="float32")
    paged = backend == "paged"
    names = ["T1"] if num_tenants == 1 else [f"L{i}"
                                             for i in range(num_tenants)]
    # ---- failure domains: deterministic fault schedule ---------------
    injector = faults
    if injector is None and chaos:
        injector = FaultInjector.plan(
            chaos_seed if chaos_seed is not None else seed + 7,
            duration_s=max(1.0, requests / qps),
            tenants=list(names), replicas=replicas,
            # a crash needs a survivor to redrive onto
            crashes=1 if replicas > 1 else 0,
            actuator_failures=2, stuck_lanes=1, fabric_windows=1,
            # gray failure only matters when migration can evacuate it;
            # plain --chaos keeps the historical schedule bit-identical
            slow_replicas=1 if (migrate and replicas > 1) else 0)
    # spec_k is passed unconditionally: requesting speculation on the
    # dense backend must hit the engine's ValueError, not silently no-op
    eng_kw = dict(max_slots=slots, seq_cap=128, backend=backend,
                  spec_k=spec_k)
    if exact_tokens:
        eng_kw["attn_impl"] = "ref"
    if paged:
        eng_kw.update(kv_dtype=kv_dtype, prefix_cache=prefix_cache)
    # one response cache per tenant, SHARED across its replicas: a
    # completion on any replica primes speculation fleet-wide
    rcaches = {}

    def tenant_kw(name):
        kw = dict(eng_kw)
        if paged and response_cache:
            kw["response_cache"] = rcaches.setdefault(name, ResponseCache())
        return kw

    # one seed per TENANT, identical across its replicas: replicas of a
    # model serve the same weights, so a redriven (or page-shipped)
    # request regenerates the same greedy tokens on any of them
    engines = {name: [ServingEngine(cfg, seed=seed + 17 * i,
                                    **tenant_kw(name))
                      for j in range(replicas)]
               for i, name in enumerate(names)}
    # cluster-wide KV reuse: every paged replica publishes its prefix
    # cache into a per-tenant content-hash directory, and dispatch
    # routes to the longest held prefix (least-loaded on fallback).
    # Dense engines never publish, so their lookups all miss and the
    # router degrades to exactly the old least-loaded dispatch.
    directory = PrefixDirectory(page_size=16)
    rcfg = RouterConfig(imbalance_bound=route_imbalance,
                        staleness_bound=route_staleness)

    def wire_tenant(name):
        for j, eng in enumerate(engines[name]):
            if eng.runtime is not None:
                directory.attach(name, j, eng.kv)
        return CacheAwareRouter(directory, name, rcfg,
                                cache_aware=route == "cache")

    routers = {name: wire_tenant(name) for name in names}
    fabric = FabricState()
    fabric.t2_active = interfere
    topo = make_p4d_cluster(2)
    # Spread tenant-replicas over the topology's real slots (2 per
    # device), skipping the background tenants' fixed slots, breadth-
    # first across devices so no GPU hosts more than 2 x 2g.20gb slices
    # (4 units, within the per-GPU 7-unit budget).  The first devices
    # sit on the contended root; the rest see only ambient traffic.
    total = num_tenants * replicas
    reserved = {("h0:g1", 0), ("h0:g0", 1)}      # T2 / T3 below
    pool = [f"h{h}:g{d}" for h in range(2) for d in range(8)]
    free = [Slot(int(dev[1]), dev, idx)
            for idx in range(2) for dev in pool
            if (dev, idx) not in reserved]
    if total > len(free):
        raise SystemExit(
            f"{total} tenant-replicas exceed the cluster's capacity "
            f"({len(free)} free 2g slices)")
    # tenant identity as data: the run's registry pins the breadth-first
    # placement into each spec, and the shared ledger is built from it
    registry = TenantRegistry()
    placements = {}
    k = 0
    for i, name in enumerate(names):
        placements[name] = free[k:k + replicas]
        k += replicas
        registry.add(TenantSpec(
            name=name, replicas=replicas, rate=qps, slo_s=0.200,
            priority=1.0 + 0.25 * i,
            placement=tuple(s.key for s in placements[name])))
    registry.add(TenantSpec(
        name="T2", role=BACKGROUND, profile="7g.80gb", units=0,
        pcie_demand=fabric.t2_demand, ps_weight=fabric.t2_ps_weight,
        placement=("h0:g1:s0",)))
    registry.add(TenantSpec(
        name="T3", role=BACKGROUND, profile="2g.20gb", units=2,
        sm_util=0.95, placement=("h0:g0:s1",)))
    ledger = DeviceLedger.from_registry(
        topo, registry, A100_MIG,
        home_devices=("h0:g0",), ambient_units=3)
    # only tenants with a replica on the contended root (r0 = g0/g1)
    # share the hot fabric path
    contended = topo.root_of("h0:g1")
    for name in names:
        fabric.set_on_root(name, any(
            topo.root_of(s.device) == contended for s in placements[name]))
    now = [0.0]
    actuator = ServingActuator(engines, fabric, topo, lambda: now[0],
                               ledger=ledger,
                               rng=np.random.default_rng(seed + 1))
    # under chaos the controller actuates through the bounded-retry
    # wrapper: injected call failures back off in virtual time (charged
    # to the returned pause), exhaustion rolls back to last-known-good,
    # and retry cycles respect the controller's dwell/cooldown FSM
    # (``controller`` binds later; the lambda resolves at call time)
    retrying = None
    if injector is not None:
        retrying = RetryingActuator(
            actuator, lambda: now[0], faults=injector,
            fsm_for=lambda t: (controller.fsm_for(t)
                               if controller is not None else None))
    watchdog = (StuckLaneWatchdog(timeout_s=watchdog_timeout_s)
                if injector is not None else None)
    windows = {name: LatencyWindow() for name in names}

    # ---- request-plane front door -----------------------------------
    # The gateway fronts EVERY request (both paths), so the verdict
    # ledger always balances; --listen additionally arms backpressure:
    # bounded queues + dispatch deadlines + Kingman-derived rate limits.
    def door_cfg_for(spec):
        if not listen:
            return DoorConfig(max_queue=1_000_000, deadline_s=None)
        return DoorConfig(
            max_queue=door_queue, deadline_s=door_deadline_ms / 1e3,
            rate_limiter=RateLimiter.kingman(spec, AdmissionConfig()))

    door_cfgs = {name: door_cfg_for(registry[name]) for name in names}
    gateway = Gateway(engines, routers, door_cfgs=door_cfgs,
                      default_cfg=door_cfg_for(
                          TenantSpec(name="_default", rate=qps, slo_s=0.200)),
                      paused_until=actuator.paused_until)

    controller = None
    if with_controller:
        controller = Controller(topo, A100_MIG,
                                retrying if retrying is not None
                                else actuator,
                                ControllerConfig(policy=PolicyConfig(
                                    tau_s=0.200, persistence=2,
                                    dwell_obs=20, cooldown_obs=10)))
        controller.register_registry(registry, placements={
            **placements, "T2": [Slot(0, "h0:g1", 0)],
            "T3": [Slot(0, "h0:g0", 1)]})

    recorder = None
    if trace or trace_out:
        from repro.serving.trace import FlightRecorder
        recorder = FlightRecorder()

    def warm(name):
        for eng in engines[name]:
            warm_engine(eng, name, prompt_len)
        # attach the recorder only AFTER warming: the warm request
        # (req_id=-1, virtual time 0) must stay out of the trace just
        # like it stays out of metrics and the caches
        if recorder is not None:
            for eng in engines[name]:
                eng.tracer = recorder

    # warm the jit caches so compile time never enters the virtual clock
    # (warm_engine keeps the warm request out of metrics, the shared
    # response cache, and the prefix directory)
    for name in names:
        warm(name)
    if recorder is not None:
        gateway.tracer = recorder
        actuator.tracer = recorder
        if retrying is not None:
            retrying.tracer = recorder
        if controller is not None:
            controller.tracer = recorder

    rng = np.random.default_rng(seed)
    reqs = {name: [] for name in names}
    pending = {}
    # paged traffic draws each prompt as a shared per-tenant template
    # prefix (page-aligned, so replicas publish identical chain hashes)
    # plus a random tail — the workload shape cache-aware routing is
    # for.  Dense traffic keeps synthetic prompts (tokens unused).
    # unique_prompts drops the shared templates: every prompt is fully
    # distinct, so a crashed replica's KV is genuinely lost state (the
    # prefix directory cannot resurrect it on the survivors) — the
    # workload where page shipping vs recompute differs most honestly
    tmpl_len = (prompt_len * 2 // 3) // 16 * 16 \
        if paged and not unique_prompts else 0

    def make_prompt(templates):
        if unique_prompts:
            # real harness-drawn tokens, distinct per request: engines
            # synthesize from their own rng when handed None, and
            # identically-seeded replicas would then mint COLLIDING
            # prompts for different requests
            return rng.integers(0, cfg.vocab_size,
                                prompt_len).astype(np.int64)
        if templates is None:
            return None
        head = templates[int(rng.integers(len(templates)))]
        tail = rng.integers(0, cfg.vocab_size, prompt_len - tmpl_len)
        return np.concatenate([head, tail]).astype(np.int64)

    def gen_traffic(name, start=0.0):
        templates = (rng.integers(0, cfg.vocab_size, (4, tmpl_len))
                     if tmpl_len else None)
        arrivals = start + np.cumsum(rng.exponential(1.0 / qps, requests))
        reqs[name] = [Request(req_id=i, tenant=name, prompt_len=prompt_len,
                              max_new_tokens=max_new, arrival=float(t),
                              slo_ms=200.0,
                              prompt_tokens=make_prompt(templates))
                      for i, t in enumerate(arrivals)]
        pending[name] = deque(reqs[name])

    for name in names:
        gen_traffic(name)
    preempts = {name: 0 for name in names}
    # ---- lane-migration state ----------------------------------------
    migrations = []                       # completed-migration summaries
    redriven_ids = {name: set() for name in names}   # req_ids that moved
    drain_events = deque(sorted(drains)) if drains else deque()
    step_hist = {}       # (tenant, replica) -> deque of per-token cost
    quarantine = {}      # (tenant, replica) -> readmit time (gray)
    # per-engine availability clock: engines run in parallel
    avail = {(name, j): 0.0 for name in names for j in range(replicas)}
    next_sample = 1.0
    if verbose:
        print(f"serving {cfg.name}: {len(names)} tenant(s) x {replicas} "
              f"replica(s), {requests} req/tenant at {qps} qps "
              f"(backend={backend}, "
              f"interference={'on' if interfere else 'off'}, "
              f"controller={'on' if with_controller else 'off'})")

    # ---- §2.3 admission path: K late tenants arrive mid-run ----------
    admission = None
    admit_events = deque()
    admission_log = []
    if admit > 0:
        admission = AdmissionController(topo, registry, ledger,
                                        AdmissionConfig(), tracer=recorder)
        span = requests / qps
        admit_events = deque(
            (span * 0.3 + j * max(1.0, 1.0 / qps),
             TenantSpec(name=f"A{j}", replicas=1, rate=qps,
                        slo_s=0.200, priority=1.0))
            for j in range(admit))

    def on_admitted(spec, slots_, t):
        name = spec.name
        names.append(name)
        engines[name] = [ServingEngine(cfg, seed=seed + 1000 + len(names),
                                       **tenant_kw(name))]
        routers[name] = wire_tenant(name)
        actuator.engines[name] = engines[name]
        actuator.compute_scales.setdefault(name, 1.0)
        actuator.pauses.setdefault(name, 0.0)
        warm(name)
        windows[name] = LatencyWindow()
        gateway.door_cfgs[name] = door_cfg_for(spec)
        preempts[name] = 0
        redriven_ids[name] = set()
        avail[(name, 0)] = t
        fabric.set_on_root(name, any(
            topo.root_of(s.device) == contended for s in slots_))
        gen_traffic(name, start=t)
        if controller is not None:
            controller.register_tenant(name, "latency", slots_[0],
                                       A100_MIG[spec.profile],
                                       priority=spec.priority,
                                       slo_s=spec.slo_s, replicas=slots_)
        if verbose:
            print(f"  t={t:6.1f}s admitted {name} -> "
                  f"{[s.key for s in slots_]}")

    def run_admissions():
        while admit_events and admit_events[0][0] <= now[0]:
            t, spec = admit_events.popleft()
            verdict, slots_ = admission.decide(spec, now=t)
            admission_log.append((t, spec.name, verdict.value))
            if verdict == AdmissionVerdict.ADMIT:
                on_admitted(registry[spec.name], slots_, t)
            elif verbose:
                print(f"  t={t:6.1f}s {verdict.value} {spec.name}")
        # departures are rare in this harness, but retry anyway so a
        # queued tenant lands as soon as capacity appears
        if admission is not None and admission.queue:
            for spec, slots_ in admission.retry_queued(now=now[0]):
                admission_log.append((now[0], spec.name, "admit"))
                on_admitted(spec, slots_, now[0])

    # ---- failure-domain recovery handlers ----------------------------
    def migrate_replica(name, j, reason):
        """Evacuate replica ``j`` by KV-page shipping: drain its lanes
        WITH state, price the transfer against the fabric, and import
        each page chain into the least-loaded live peer.  Verified
        lanes are adopted warm (handoff span covers the transfer, TTFT
        stamp conserved); cold / checksum-rejected lanes take the
        recompute redrive — never a wrong token.  Returns
        ``(dst, transfer_s)`` or None when there is no live peer or the
        (possibly fault-injected) actuator call did not land."""
        live = [k for k in gateway.live_replicas(name) if k != j]
        if not live:
            return None
        dst = min(live, key=lambda k: (len(engines[name][k].queue)
                                       + len(engines[name][k].active()), k))
        n_before = len(actuator.migrations)
        act = retrying if retrying is not None else actuator
        act.migrate(name, j, dst)
        if len(actuator.migrations) == n_before:
            return None            # injected failure ate the call
        rec = actuator.migrations.pop()
        arrive = now[0] + rec["transfer_s"]
        moved = rec["warm"] + rec["cold"]
        gateway.adopt_warm(name, rec["warm"], now[0], arrive,
                           from_engine=j, to_engine=dst)
        gateway.redrive(name, rec["cold"], now[0], from_engine=j)
        redriven_ids[name].update(r.req_id for r in moved)
        if watchdog is not None:
            for r in moved:
                watchdog.forget((name, j, r.req_id))
        # the destination stalls for the transfer: migration is fabric
        # traffic like any tenant flow, and it pays in virtual time too
        avail[(name, dst)] = max(avail.get((name, dst), 0.0), arrive)
        migrations.append({
            "t": now[0], "tenant": name, "from": j, "to": dst,
            "reason": reason, "warm": len(rec["warm"]),
            "cold": len(rec["cold"]), "pages": rec["pages"],
            "bytes": rec["bytes"], "transfer_s": rec["transfer_s"],
            "attached_pages": rec["attached_pages"],
            "copied_pages": rec["copied_pages"],
            "verify_failures": rec["verify_failures"]})
        if verbose:
            print(f"  t={now[0]:6.1f}s MIGRATE {name}/r{j}->r{dst} "
                  f"({reason}): {len(rec['warm'])} warm "
                  f"({rec['attached_pages']} attached / "
                  f"{rec['copied_pages']} shipped pages, "
                  f"{rec['bytes'] / 1e6:.2f} MB in "
                  f"{rec['transfer_s'] * 1e3:.1f} ms), "
                  f"{len(rec['cold'])} recompute")
        return dst, rec["transfer_s"]

    def run_drains():
        """Planned scale-down: evacuate the replica's lanes (page
        shipping under ``migrate``, recompute redrive otherwise — never
        shed), then release its slots for good."""
        while drain_events and drain_events[0][0] <= now[0]:
            _, name, j = drain_events.popleft()
            if name not in engines or j >= len(engines[name]):
                continue
            if j not in gateway.live_replicas(name):
                continue
            if len(gateway.live_replicas(name)) <= 1:
                continue             # never drain the last live replica
            gateway.mark_dead(name, j)
            routers[name].mark_dead(j)
            directory.retract_replica(name, j)
            if recorder is not None:
                recorder.on_fault(now[0], "planned_drain", tenant=name,
                                  replica=j)
            res = migrate_replica(name, j, "drain") if migrate else None
            if res is None:
                drained = engines[name][j].drain_requests()
                redriven_ids[name].update(r.req_id for r in drained)
                if watchdog is not None:
                    for r in drained:
                        watchdog.forget((name, j, r.req_id))
                n = gateway.redrive(name, drained, now[0], from_engine=j)
                if verbose:
                    print(f"  t={now[0]:6.1f}s DRAIN {name}/r{j}: "
                          f"redrove {n} request(s) cold")
            ledger.release(name, replica=j)
            avail[(name, j)] = now[0]

    def run_gray_detector():
        """Tail-based gray-failure detection: a replica whose recent
        per-token step cost is ``gray_threshold`` x its best live
        peer's gets evacuated (warm, under ``migrate``) and quarantined
        before the per-lane watchdog would fire."""
        for name in list(names):
            live = [k for k in gateway.live_replicas(name)
                    if (name, k) not in quarantine]
            if len(live) < 2:
                continue
            means = {}
            for k in live:
                h = step_hist.get((name, k))
                if h is not None and len(h) >= 4:
                    means[k] = sum(h) / len(h)
            if len(means) < 2:
                continue
            best = min(means.values())
            if best <= 0:
                continue
            for k, m in sorted(means.items()):
                if m > gray_threshold * best:
                    evacuate_gray(name, k)
                    break            # one evacuation per tenant per tick

    def evacuate_gray(name, j):
        gateway.mark_dead(name, j)       # quarantine: reversible mask
        directory.retract_replica(name, j)
        if recorder is not None:
            recorder.on_fault(now[0], "gray_evacuate", tenant=name,
                              replica=j)
        res = migrate_replica(name, j, "gray")
        if res is None:
            drained = engines[name][j].drain_requests()
            redriven_ids[name].update(r.req_id for r in drained)
            if watchdog is not None:
                for r in drained:
                    watchdog.forget((name, j, r.req_id))
            gateway.redrive(name, drained, now[0], from_engine=j)
        quarantine[(name, j)] = now[0] + gray_cooldown_s
        step_hist.pop((name, j), None)
        if injector is not None:
            injector.log.append((now[0], "gray_evacuate", f"{name}/{j}"))
        if verbose:
            print(f"  t={now[0]:6.1f}s GRAY {name}/r{j}: evacuated, "
                  f"quarantined until t={quarantine[(name, j)]:.1f}s")

    def run_quarantine():
        for (name, j), until in list(quarantine.items()):
            if now[0] >= until:
                del quarantine[(name, j)]
                gateway.mark_live(name, j)
                avail[(name, j)] = max(avail[(name, j)], now[0])
                if verbose:
                    print(f"  t={now[0]:6.1f}s GRAY {name}/r{j}: "
                          f"readmitted")

    def crash_replica(name, j):
        """Replica death: mask it everywhere a request could still reach
        it, release every resource it held, then redrive (or, recovery
        off, shed) its in-flight requests.  Order matters: masking first
        so nothing routes to the corpse, drain releases the pages, the
        verdict/redrive decision comes last."""
        if name not in engines or j >= len(engines[name]):
            return
        live = gateway.live_replicas(name)
        if j not in live:
            if (name, j) in quarantine:
                # the quarantined gray replica died for real: make its
                # mask permanent instead of readmitting a corpse
                del quarantine[(name, j)]
                routers[name].mark_dead(j)
                ledger.release(name, replica=j)
                injector.log.append(
                    (now[0], "crash_in_quarantine", f"{name}/{j}"))
            return                       # already dead
        if len(live) <= 1:
            # never kill the last live replica: redriven work (and all
            # future arrivals) would have nowhere to land — log the
            # skip so replay identity still covers it
            injector.log.append(
                (now[0], "crash_skipped_last_replica", f"{name}/{j}"))
            return
        eng = engines[name][j]
        gateway.mark_dead(name, j)
        routers[name].mark_dead(j)
        directory.retract_replica(name, j)
        if recover and migrate:
            # warm standby adoption: the corpse's pages survive in the
            # shared host pool, so ship them instead of recomputing
            res = migrate_replica(name, j, "crash")
            if res is not None:
                ledger.release(name, replica=j)
                avail[(name, j)] = now[0]
                return
        drained = eng.drain_requests()
        ledger.release(name, replica=j)
        if watchdog is not None:
            for r in drained:
                watchdog.forget((name, j, r.req_id))
        if recover:
            n = gateway.redrive(name, drained, now[0], from_engine=j)
            redriven_ids[name].update(r.req_id for r in drained)
            verb = "redrove"
        else:
            n = gateway.abandon(name, drained, now[0])
            verb = "shed"
        avail[(name, j)] = now[0]        # dead engines never step again
        if verbose:
            print(f"  t={now[0]:6.1f}s CRASH {name}/r{j}: {verb} {n} "
                  f"in-flight request(s) "
                  f"({len(live) - 1} live replica(s) remain)")

    def stick_lane(name, j):
        """Hang one active decode lane (lowest req_id, deterministic) on
        the target replica; the watchdog detects the stalled progress
        and requeues it through the refcount-safe preemption path."""
        if name not in engines or j >= len(engines[name]):
            return
        if j not in gateway.live_replicas(name):
            return
        eng = engines[name][j]
        if eng.runtime is None:
            return
        sched = eng.runtime.sched
        lanes = [s.req.req_id for s in sched.active
                 if s.req.req_id not in sched.stuck]
        if not lanes:
            injector.log.append(
                (now[0], "stuck_skipped_no_lane", f"{name}/{j}"))
            return
        sched.mark_stuck(min(lanes))

    def apply_faults():
        for f in injector.due(now[0]):
            if recorder is not None:
                recorder.on_fault(now[0], f.kind, tenant=f.tenant,
                                  replica=f.replica, method=f.method)
            if f.kind == "replica_crash":
                crash_replica(f.tenant, f.replica)
            elif f.kind == "lane_stuck":
                stick_lane(f.tenant, f.replica)
            # actuator_fail / fabric_degrade armed inside the injector

    def run_watchdog():
        # feed every live lane's token progress, drop lanes that left
        # the active set (completed / preempted / drained), then requeue
        # whatever made no progress for the whole timeout
        live_keys = set()
        for name in names:
            for j in gateway.live_replicas(name):
                eng = engines[name][j]
                if eng.runtime is None:
                    continue
                for s in eng.runtime.sched.active:
                    key = (name, j, s.req.req_id)
                    live_keys.add(key)
                    watchdog.observe(key, s.req.generated, now[0])
        watchdog.prune(live_keys)
        for name, j, rid in watchdog.stale(now[0]):
            sched = engines[name][j].runtime.sched
            seq = sched.find(rid)
            if seq is None or seq in sched.waiting:
                continue
            if recorder is not None:
                recorder.on_preempt(seq.req, now[0],
                                    engine=f"{name}/r{j}")
            sched.preempt(seq)
            preempts[name] += 1
            injector.log.append(
                (now[0], "watchdog_requeue", f"{name}/{j}/{rid}"))
            if verbose:
                print(f"  t={now[0]:6.1f}s WATCHDOG {name}/r{j}: "
                      f"requeued stuck lane req {rid}")

    def submit_due():
        # front door first (SHED/REJECT/ACCEPT verdicts), then drain the
        # door queues into engines via the cache-aware router — a failed
        # engine submit is retried or turned into a REJECTED verdict,
        # never dropped on the floor
        for name in names:
            q = pending[name]
            while q and q[0].arrival <= now[0]:
                gateway.offer(q.popleft(), now[0])
        gateway.dispatch(now[0])

    def has_pending():
        return bool(admit_events) or any(pending[n] for n in names) or \
            gateway.queued_total() > 0 or \
            any(e.has_work() for n in names for e in engines[n])

    while has_pending():
        if admission is not None:
            run_admissions()
        if injector is not None:
            apply_faults()
        if drain_events:
            run_drains()
        if quarantine:
            run_quarantine()
        if injector is not None and migrate and recover:
            run_gray_detector()
        submit_due()
        if controller and now[0] >= next_sample:
            tenants = {}
            for name in names:
                w = windows[name]
                tenants[name] = TenantSignals(
                    p99=w.quantile(0.99, now[0]),
                    miss_rate=w.miss_rate(0.2, now[0]), rps=1.0,
                    ttft_p99=w.quantile(0.99, now[0]))
            sys = SystemSignals()
            for root in topo.roots():
                sys.pcie_bytes[root] = (fabric.t2_demand if fabric.t2_active
                                        and root == "h0:r0" else 1e9)
            controller.on_snapshot(Snapshot(now[0], tenants, sys))
            next_sample += 1.0
        # step every engine that is free, has work, and isn't paused
        stepped = False
        for name in names:
            if now[0] < actuator.paused_until(name):
                continue
            for j, eng in enumerate(engines[name]):
                if avail[(name, j)] > now[0] or not eng.has_work():
                    continue
                rep = eng.step()
                preempts[name] += len(rep.preempted)
                if rep.kind == "idle":
                    continue
                # only the prompt share of a (possibly mixed) step pays
                # fabric transfer
                transfer = (rep.prefill_tokens * 0.4e6
                            / fabric.bandwidth(name))
                # det_timing: deterministic token-cost model instead of
                # measured wall time — bit-reproducible schedules
                comp = (2e-4 + 2e-5 * rep.prefill_tokens
                        + 3e-4 * rep.decode_tokens) if det_timing \
                    else rep.compute_s
                dur = comp * actuator.compute_scale_of(name) + transfer
                if injector is not None:
                    base = dur
                    # transient fabric degradation inflates the step
                    dur *= injector.fabric_factor(now[0])
                    # gray failure: one replica quietly runs slow —
                    # per-replica, so the tail detector can see the
                    # skew against its live peers
                    dur *= injector.replica_factor(name, j, now[0])
                    # detector signal: measured step time over the
                    # model's own prediction.  Batch composition and
                    # tenant-wide effects (compute scale, fabric
                    # windows) hit every replica's ratio alike, so a
                    # sustained cross-replica skew is a sick replica
                    h = step_hist.setdefault((name, j), deque(maxlen=8))
                    h.append(dur / max(base, 1e-12))
                end = now[0] + dur
                avail[(name, j)] = end
                # gateway finalize = engine timestamps + token-stream
                # mirroring + terminal COMPLETED verdicts; start_time
                # lets the trace pin prefill-chunk spans to the step
                # window on the virtual clock
                gateway.finalize(name, eng, rep, end, start_time=now[0])
                for pr in rep.prefilled:
                    windows[name].observe(end, pr.ttft, slo=0.2)
                stepped = True
        if watchdog is not None:
            run_watchdog()
        if stepped:
            continue
        # nothing runnable now: hop to the next event
        horizon = []
        for name in names:
            if pending[name]:
                horizon.append(pending[name][0].arrival)
            if now[0] < actuator.paused_until(name) and \
                    any(e.has_work() for e in engines[name]):
                horizon.append(actuator.paused_until(name))
        horizon.extend(t for t in avail.values() if t > now[0])
        horizon.extend(t for t, _ in admit_events)
        horizon.extend(t for t, _, _ in drain_events)
        horizon.extend(t for t in quarantine.values() if t > now[0])
        # door-queued requests: retry a beat later, and never sleep past
        # a dispatch deadline (expiry is an event too)
        for door in gateway.doors.values():
            if door.queue:
                horizon.append(now[0] + 0.02)
                head = door.queue[0]
                if head.deadline is not None:
                    horizon.append(max(head.deadline, now[0] + 1e-9))
        if controller:
            horizon.append(next_sample)
        now[0] = min(horizon) if horizon else now[0] + 0.02

    out = {}
    for name in names:
        done = [r for r in reqs[name] if r.done]
        ttfts = np.array([r.ttft for r in done]) * 1e3
        itls = [v for r in done for v in r.itls]
        door = gateway.door(name)
        # every offered request carries exactly one verdict; the door's
        # ledger is the authoritative accounting (no silent drops)
        out[name] = {
            "completed": len(done),
            "offered": door.offered,
            "shed": door.shed,
            "rejected": door.rejected,
            "expired": door.expired,
            "reject_reasons": dict(door.reject_reasons),
            "redriven": door.redriven,
            "preempted": preempts[name],
            "ttft_p50_ms": float(np.quantile(ttfts, .5)) if len(done) else 0.0,
            "ttft_p99_ms": float(np.quantile(ttfts, .99)) if len(done) else 0.0,
            "itl_p99_ms": (float(np.quantile(np.array(itls) * 1e3, .99))
                           if itls else 0.0),
            # TTFTs of requests that survived an evacuation (warm or
            # cold) — what the migrate A/B compares — and the token
            # streams for exact-parity checks against a fault-free run
            "redriven_ids": sorted(int(i) for i in redriven_ids[name]),
            "redriven_ttft_ms": sorted(
                float(r.ttft * 1e3) for r in done
                if r.req_id in redriven_ids[name]),
            "outputs": {int(r.req_id): [int(t) for t in r.output_tokens]
                        for r in done},
            "ttft_by_id": {int(r.req_id): float(r.ttft * 1e3)
                           for r in done},
        }
        if verbose:
            print(f"  {name}: completed {len(done)}/{door.offered} "
                  f"(shed {door.shed} rejected {door.rejected} "
                  f"expired {door.expired}) "
                  f"TTFT p50={out[name]['ttft_p50_ms']:.1f}ms "
                  f"p99={out[name]['ttft_p99_ms']:.1f}ms "
                  f"ITL p99={out[name]['itl_p99_ms']:.1f}ms")
    out["routing"] = {name: routers[name].stats.as_dict() for name in names}
    if paged:
        out["directory"] = directory.stats.as_dict()
        if rcaches:
            out["response_cache"] = {
                name: {"hit_rate": rc.hit_rate(), "entries": len(rc)}
                for name, rc in rcaches.items()}
        if verbose:
            routed = sum(r.stats.routed_cache for r in routers.values())
            total = sum(r.stats.total for r in routers.values())
            print(f"routing: {routed}/{total} cache-routed "
                  f"(directory hit rate "
                  f"{directory.stats.hit_rate():.2f})")
    if admission is not None:
        out["admission"] = {"verdicts": admission.counts(),
                            "log": admission_log,
                            "still_queued": [s.name for s in admission.queue]}
        if verbose:
            print("admission verdicts:", out["admission"]["verdicts"])
    if controller:
        out["actions"] = controller.audit.counts()
        out["arbiter_max_units"] = controller.arbiter.max_used()
        if verbose:
            print("controller actions:", out["actions"])
    if injector is not None:
        out["faults"] = {
            "log": list(injector.log),
            "pending": injector.pending(),
            "recover": recover,
            "redriven": {name: gateway.door(name).redriven
                         for name in names},
            "watchdog_fired": watchdog.fired,
        }
        if retrying is not None:
            out["faults"]["actuator"] = dict(retrying.stats)
            out["faults"]["actuator_time_lost_s"] = retrying.time_lost_s
        if verbose and injector.log:
            print(f"faults: {len(injector.log)} event(s), "
                  f"redriven={out['faults']['redriven']}, "
                  f"watchdog_fired={watchdog.fired}, "
                  f"actuator={out['faults'].get('actuator')}")
    if migrations or migrate or drains:
        out["migrations"] = migrations
        if verbose and migrations:
            warm_n = sum(m["warm"] for m in migrations)
            cold_n = sum(m["cold"] for m in migrations)
            print(f"migrations: {len(migrations)} "
                  f"({warm_n} warm lane(s), {cold_n} recompute, "
                  f"{sum(m['bytes'] for m in migrations) / 1e6:.2f} MB "
                  f"shipped)")
    out["gateway"] = gateway.counters()
    out["prometheus"] = gateway.prometheus(now[0])
    gateway.check()     # offered == completed+rejected+shed+expired+in_flight
    ledger.check()
    if recorder is not None:
        recorder.check()    # per-request: segments sum to measured E2E
        out["trace"] = recorder.breakdown(now[0])
        if trace_out:
            recorder.dump(trace_out)
        if verbose:
            print(recorder.table())
            if trace_out:
                print(f"trace written to {trace_out}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--qps", type=float, default=4.0)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=1)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--interfere", action="store_true")
    ap.add_argument("--no-controller", action="store_true")
    ap.add_argument("--admit", type=int, default=0,
                    help="late-arriving tenants pushed through admission")
    ap.add_argument("--backend", choices=("dense", "paged"), default="dense",
                    help="engine KV backend: dense slot cache or the "
                         "block-table paged runtime")
    ap.add_argument("--kv-dtype", choices=("auto", "int8"), default="auto",
                    help="paged backend page-pool dtype; int8 quantizes "
                         "K/V pages with per-page-row scales")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request prefix-page sharing "
                         "(paged backend)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="paged backend: max speculative draft tokens per "
                         "decode lane (n-gram prompt-lookup drafter, "
                         "verified in the fused ragged step; 0 = off)")
    ap.add_argument("--route", choices=("cache", "load"), default="cache",
                    help="replica dispatch: route-to-longest-held-prefix "
                         "via the prefix directory ('cache') or pure "
                         "least-loaded ('load')")
    ap.add_argument("--route-imbalance", type=int, default=4,
                    help="max load lead of the cache-route target over "
                         "the least-loaded replica before falling back")
    ap.add_argument("--route-staleness", type=int, default=256,
                    help="max pending directory events before routing "
                         "falls back to least-loaded")
    ap.add_argument("--no-response-cache", action="store_true",
                    help="disable the per-tenant response cache that "
                         "self-primes speculative draft hints")
    ap.add_argument("--listen", action="store_true",
                    help="arm the gateway's backpressure policy: bounded "
                         "per-tenant door queues, dispatch deadlines "
                         "(EXPIRED past them — the 503 path) and Kingman-"
                         "derived rate limits (REJECTED fast — the 429 "
                         "path)")
    ap.add_argument("--door-queue", type=int, default=64,
                    help="--listen: bounded door-queue depth per tenant")
    ap.add_argument("--door-deadline-ms", type=float, default=1000.0,
                    help="--listen: queued requests not dispatched within "
                         "this deadline are EXPIRED")
    ap.add_argument("--trace", action="store_true",
                    help="arm the per-request flight recorder (span "
                         "timelines whose segments sum to measured E2E, "
                         "plus controller actions on a shared timeline)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON here "
                         "(implies --trace)")
    ap.add_argument("--chaos", action="store_true",
                    help="arm deterministic fault injection: a seeded "
                         "schedule of replica crashes, actuator failures, "
                         "stuck lanes and fabric degradation "
                         "(core/faults.py)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="fault-schedule seed (default: --seed + 7); the "
                         "same seed replays the same faults bit-identically")
    ap.add_argument("--no-recover", action="store_true",
                    help="keep the fault schedule but disable recovery: "
                         "crashed replicas shed their in-flight requests "
                         "instead of redriving them (A/B baseline)")
    ap.add_argument("--migrate", action="store_true",
                    help="recover by verified KV-page shipping instead of "
                         "recompute: crashed / drained / gray-failed "
                         "replicas ship their lanes' page chains to a live "
                         "peer, chain-hash-verified before commit "
                         "(serving/migrate.py)")
    ap.add_argument("--drain-at", action="append", default=[],
                    metavar="T:TENANT:REPLICA",
                    help="planned scale-down: at virtual time T evacuate "
                         "TENANT's replica REPLICA (repeatable; lanes are "
                         "migrated or redriven, never shed)")
    ap.add_argument("--det-timing", action="store_true",
                    help="deterministic per-token step-cost model instead "
                         "of measured wall time: bit-reproducible virtual "
                         "schedules (token-parity A/Bs need this)")
    ap.add_argument("--unique-prompts", action="store_true",
                    help="no shared prompt templates: each prompt is fully "
                         "distinct, so crashed-replica KV cannot be "
                         "resurrected from the prefix directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    drains = []
    for spec in args.drain_at:
        try:
            t, tenant, rep = spec.split(":")
            drains.append((float(t), tenant, int(rep)))
        except ValueError:
            raise SystemExit(f"--drain-at wants T:TENANT:REPLICA, "
                             f"got {spec!r}")
    serve(arch=args.arch, requests=args.requests, qps=args.qps,
          prompt_len=args.prompt_len, max_new=args.max_new,
          slots=args.slots, num_tenants=args.tenants,
          replicas=args.replicas, interfere=args.interfere,
          with_controller=not args.no_controller, seed=args.seed,
          admit=args.admit, backend=args.backend, kv_dtype=args.kv_dtype,
          prefix_cache=not args.no_prefix_cache, spec_k=args.spec_k,
          route=args.route, route_imbalance=args.route_imbalance,
          route_staleness=args.route_staleness,
          response_cache=not args.no_response_cache, listen=args.listen,
          door_queue=args.door_queue,
          door_deadline_ms=args.door_deadline_ms,
          trace=args.trace, trace_out=args.trace_out,
          chaos=args.chaos, chaos_seed=args.chaos_seed,
          recover=not args.no_recover,
          migrate=args.migrate, drains=drains or None,
          det_timing=args.det_timing,
          unique_prompts=args.unique_prompts)


if __name__ == "__main__":
    main()
