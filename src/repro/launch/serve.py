"""Serving launcher: engine + controller co-deployed (the paper's
first-class integration).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b \
        --requests 32 --qps 4 [--interfere] [--no-controller]

Runs the continuous-batching engine on the reduced config, with the PS
fabric model injecting PCIe-class interference when --interfere is set,
and the (unchanged) multi-tenancy controller managing quotas/placement/
slice profiles around it.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--qps", type=float, default=4.0)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--interfere", action="store_true")
    ap.add_argument("--no-controller", action="store_true")
    args = ap.parse_args()

    import numpy as np
    from repro.configs.base import get_config, reduced
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request
    from repro.serving.actuator import FabricState, ServingActuator
    from repro.core.controller import Controller, ControllerConfig
    from repro.core.policy import PolicyConfig
    from repro.core.profiles import A100_MIG
    from repro.core.signals import Snapshot, SystemSignals, TenantSignals
    from repro.core.topology import Slot, make_p4d_cluster
    from repro.serving.metrics import LatencyWindow

    cfg = reduced(get_config(args.arch))
    eng = ServingEngine(cfg, max_slots=args.slots, seq_cap=128)
    fabric = FabricState()
    fabric.t2_active = args.interfere
    topo = make_p4d_cluster(2)
    now = [0.0]
    actuator = ServingActuator(eng, fabric, topo, lambda: now[0])
    window = LatencyWindow()
    controller = None
    if not args.no_controller:
        controller = Controller(topo, A100_MIG, actuator,
                                ControllerConfig(policy=PolicyConfig(
                                    tau_s=0.200, persistence=2,
                                    dwell_obs=20, cooldown_obs=10)))
        controller.register_tenant("T1", "latency", Slot(0, "h0:g0", 0),
                                   A100_MIG["2g.20gb"])
        controller.register_tenant("T2", "background", Slot(0, "h0:g1", 0),
                                   A100_MIG["7g.80gb"])
        controller.register_tenant("T3", "background", Slot(0, "h0:g0", 1),
                                   A100_MIG["2g.20gb"])

    # warm the jit caches so compile time never enters the virtual clock
    eng.submit(Request(req_id=-1, tenant="T1", prompt_len=args.prompt_len,
                       max_new_tokens=2, arrival=0.0))
    while eng.has_work():
        eng.finalize_step(eng.step(), 0.0)

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, args.requests))
    reqs = [Request(req_id=i, tenant="T1", prompt_len=args.prompt_len,
                    max_new_tokens=args.max_new, arrival=float(t),
                    slo_ms=200.0) for i, t in enumerate(arrivals)]
    pending = list(reqs)
    next_sample = 1.0
    print(f"serving {cfg.name}: {args.requests} requests at {args.qps} qps "
          f"(interference={'on' if args.interfere else 'off'}, "
          f"controller={'off' if args.no_controller else 'on'})")
    while pending or eng.has_work():
        while pending and pending[0].arrival <= now[0]:
            eng.submit(pending.pop(0))
        if controller and now[0] >= next_sample:
            t1 = TenantSignals(p99=window.quantile(0.99, now[0]),
                               miss_rate=window.miss_rate(0.2, now[0]),
                               rps=1.0)
            sys = SystemSignals()
            for root in topo.roots():
                sys.pcie_bytes[root] = (fabric.t2_demand if fabric.t2_active
                                        and root == "h0:r0" else 1e9)
            controller.on_snapshot(Snapshot(now[0], {"T1": t1}, sys))
            next_sample += 1.0
        rep = eng.step()
        if rep.kind == "idle":
            now[0] += 0.02
            continue
        transfer = (rep.tokens * 0.4e6 / fabric.t1_bandwidth()
                    if rep.kind == "prefill" else 0.0)
        now[0] += rep.compute_s * actuator.compute_scale + transfer
        eng.finalize_step(rep, now[0])
        if rep.prefilled is not None:
            window.observe(now[0], rep.prefilled.ttft, slo=0.2)
    done = [r for r in reqs if r.done]
    ttfts = np.array([r.ttft for r in done]) * 1e3
    print(f"completed {len(done)}/{args.requests} "
          f"TTFT p50={np.quantile(ttfts, .5):.1f}ms "
          f"p99={np.quantile(ttfts, .99):.1f}ms")
    if controller:
        print("controller actions:", controller.audit.counts())


if __name__ == "__main__":
    main()
