"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b \
        --steps 200 --batch 8 --seq 128 [--reduced/--full]

``--reduced`` (default) trains the smoke-scale variant on local devices;
``--full`` lowers the full config against the production mesh (dry-run
compile only on CPU — real execution requires the TPU pod).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="lower the full config on the production mesh")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    from repro.configs.base import INPUT_SHAPES, get_config, reduced
    if args.full:
        from repro.launch.dryrun import dryrun   # sets 512 devices? no —
        # full-config execution is a dry-run on CPU
        dryrun(args.arch, "train_4k")
        return

    from repro.training.data import SyntheticTokenPipeline
    from repro.training.optimizer import AdamWConfig
    from repro.training.trainer import train
    cfg = reduced(get_config(args.arch))
    frontend = None
    if cfg.frontend.kind == "vision":
        frontend = {"kind": "vision", "num_prefix": cfg.frontend.num_prefix,
                    "embed_dim": cfg.frontend.embed_dim}
    elif cfg.frontend.kind == "audio":
        frontend = {"kind": "audio", "embed_dim": cfg.frontend.embed_dim}
    pipe = SyntheticTokenPipeline(cfg.vocab_size, args.batch, args.seq,
                                  frontend=frontend)
    print(f"training {cfg.name} for {args.steps} steps "
          f"(batch={args.batch}, seq={args.seq})")
    res = train(cfg, iter(pipe), args.steps,
                AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps),
                log_fn=lambda i, loss, gn:
                print(f"  step {i:4d}  loss={loss:.4f}  gnorm={gn:.2f}"))
    print(f"final loss: {res.losses[-1]:.4f} "
          f"(start {res.losses[0]:.4f})")
    if args.checkpoint:
        from repro.training import checkpoint
        n = checkpoint.save(args.checkpoint, res.final_params,
                            {"arch": args.arch, "steps": args.steps})
        print(f"checkpoint written: {args.checkpoint} ({n} bytes)")


if __name__ == "__main__":
    main()
