"""Step-function assembly for the dry-run and the launchers.

``build_step(cfg, shape, mesh)`` returns (fn, args, in_shardings,
out_shardings) ready for ``jax.jit(...).lower(*args).compile()``:
  * train_4k      -> train_step(params, opt_state, batch)
  * prefill_32k   -> prefill_step(params, batch)
  * decode shapes -> serve_step(params, cache, token, positions)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import InputShape, ModelConfig
from repro.launch.shardings import make_policy
from repro.launch.specs import decode_arg_plans, batch_plan, input_specs
from repro.models.model import decode_step, model_plan, prefill, train_loss
from repro.models.params import shardings_from_plan, specs_from_plan
from repro.training import optimizer as opt


def build_step(cfg: ModelConfig, shape: InputShape, mesh, *,
               policy_override=None, remat: bool = True,
               ocfg: Optional[opt.AdamWConfig] = None):
    policy = policy_override or make_policy(cfg, shape, mesh)
    pplan = model_plan(cfg)
    p_specs = specs_from_plan(pplan)
    p_shard = shardings_from_plan(pplan, mesh)
    replicated = NamedSharding(mesh, PartitionSpec())

    if shape.mode == "train":
        ocfg = ocfg or opt.AdamWConfig()
        splan = opt.state_plan(pplan)
        s_specs = specs_from_plan(splan)
        s_shard = shardings_from_plan(splan, mesh)
        bplan = batch_plan(cfg, shape, mesh)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                return train_loss(p, cfg, batch, policy, remat=remat)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2, opt2, diag = opt.apply_updates(ocfg, params, grads,
                                                    opt_state)
            return params2, opt2, {"loss": loss, **diag}

        args = (p_specs, s_specs, specs_from_plan(bplan))
        in_sh = (p_shard, s_shard, shardings_from_plan(bplan, mesh))
        out_sh = (p_shard, s_shard, None)
        return train_step, args, in_sh, out_sh, (0, 1)

    if shape.mode == "prefill":
        bplan = batch_plan(cfg, shape, mesh)
        cplan, _, _ = decode_arg_plans(cfg, shape, mesh)
        c_shard = shardings_from_plan(cplan, mesh)

        def prefill_step(params, batch):
            return prefill(params, cfg, batch, policy,
                           seq_cap=shape.seq_len)

        args = (p_specs, specs_from_plan(bplan))
        in_sh = (p_shard, shardings_from_plan(bplan, mesh))
        out_sh = (None, c_shard)
        return prefill_step, args, in_sh, out_sh, ()

    # decode
    cplan, tplan, qplan = decode_arg_plans(cfg, shape, mesh)
    c_shard = shardings_from_plan(cplan, mesh)

    def serve_step(params, cache, token, positions):
        return decode_step(params, cfg, cache, token, positions, policy)

    args = (p_specs, specs_from_plan(cplan), specs_from_plan(tplan),
            specs_from_plan(qplan))
    in_sh = (p_shard, c_shard, shardings_from_plan(tplan, mesh),
             shardings_from_plan(qplan, mesh))
    out_sh = (None, c_shard)
    return serve_step, args, in_sh, out_sh, (1,)
