"""Sharding policy resolution per (architecture x input shape x mesh).

Parameters: tensor-parallel over ``model`` (heads / FFN columns / experts),
FSDP over ``data`` (weights gathered at use), replicated over ``pod``.
Activations: batch over ("pod","data") when the batch permits; decode KV
caches shard KV-heads over ``model`` when divisible, otherwise the cache
*sequence* dimension takes the ``model`` axis (split-K decode); long_500k
(batch=1) shards sequence over ("data","model").
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.configs.base import InputShape, ModelConfig
from repro.models.common import ShardPolicy


def _bd(mesh) -> Tuple:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def make_policy(cfg: ModelConfig, shape: InputShape, mesh,
                seq_parallel: bool = False) -> ShardPolicy:
    """seq_parallel: Megatron-style sequence parallelism — the residual
    stream (and hence the remat-saved activation stack) is sharded over
    ``model`` along the sequence dimension; XLA turns the TP psums into
    reduce-scatter + all-gather pairs around attention/FFN.  §Perf lever."""
    bd = _bd(mesh)
    model_size = mesh.shape.get("model", 1)
    kv = cfg.attn.num_kv_heads
    kv_divisible = kv % model_size == 0 and kv >= model_size
    n_experts = cfg.moe.num_experts if cfg.moe is not None else 0
    # experts must divide the model axis for expert-parallel dispatch;
    # otherwise the buffer stays expert-replicated (TP-within-expert)
    e_divisible = n_experts >= model_size and n_experts % model_size == 0
    moe_buf = ("model", bd, None) if e_divisible else (None, bd, None)
    act_seq = "model" if seq_parallel else None

    if shape.mode in ("train", "prefill"):
        return ShardPolicy(
            act=(bd, act_seq, None),
            heads=(bd, None, "model", None),
            kv_cache=((bd, None, "model", None) if kv_divisible
                      else (bd, "model", None, None)),
            mla_cache=(bd, "model", None),
            state=(bd, "model", None),
            moe_buf=moe_buf,
            logits=(bd, None, "model"),
        )

    if shape.global_batch == 1:
        # long-context decode: batch unshardable — sequence-shard the cache
        seq_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
        return ShardPolicy(
            act=None,
            heads=(None, None, "model", None),
            kv_cache=(None, seq_axes, None, None) if not kv_divisible
            else (None, "data", "model", None),
            mla_cache=(None, seq_axes, None),
            state=(None, "model", None),
            moe_buf=("model", None, None),
            logits=(None, None, "model"),
        )

    # batched decode
    return ShardPolicy(
        act=(bd, None, None),
        heads=(bd, None, "model", None),
        kv_cache=((bd, None, "model", None) if kv_divisible
                  else (bd, "model", None, None)),
        mla_cache=(bd, "model", None),
        state=(bd, "model", None),
        moe_buf=moe_buf,
        logits=(bd, None, "model"),
    )
