"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.4.38
    from jax.sharding import AxisType

    def _mesh_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:                    # older jax: Auto is the only mode
    def _mesh_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_local_mesh():
    """Single-device mesh for CPU smoke runs of the distributed code path."""
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"), **_mesh_kwargs(2))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
