"""SeamlessM4T-large v2 — encoder-decoder multimodal translation
[arXiv:2308.11596].

24 layers (24 enc + 24 dec), d_model=1024, 16 heads (GQA kv=16), d_ff=8192,
vocab=256206.  The mel-spectrogram + conformer feature frontend is a STUB:
input_specs() supplies precomputed frame embeddings (w2v-BERT width=1024)
fed to the text-translation encoder; the decoder cross-attends to encoder
memory.
"""
from repro.configs.base import (AttentionSpec, EncoderSpec, FFNSpec,
                                FrontendSpec, LayerSpec, ModelConfig, register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        source="arXiv:2308.11596",
        d_model=1024,
        vocab_size=256206,
        period=(LayerSpec(mixer="attn", ffn="dense", cross_attn=True),),
        repeats=24,
        attn=AttentionSpec(num_heads=16, num_kv_heads=16, head_dim=64),
        ffn=FFNSpec(kind="dense", d_ff=8192, activation="gelu"),
        encoder=EncoderSpec(num_layers=24, d_model=1024, num_heads=16, d_ff=8192),
        frontend=FrontendSpec(kind="audio", embed_dim=1024, num_prefix=0),
        supports_long_context=False,    # enc-dec full attention; 500k decode out of envelope
    )
