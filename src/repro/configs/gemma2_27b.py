"""Gemma 2 27B — local/global alternating attention with logit softcaps
[arXiv:2408.00118].

46 layers, d_model=4608, 32 heads (GQA kv=16), d_ff=36864, vocab=256000.
Alternating sliding-window (4096) and global layers; attention logit
softcap 50, final logit softcap 30.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        source="arXiv:2408.00118",
        d_model=4608,
        vocab_size=256000,
        period=(
            LayerSpec(mixer="attn", ffn="dense", window=4096),  # local
            LayerSpec(mixer="attn", ffn="dense", window=0),     # global
        ),
        repeats=23,
        attn=AttentionSpec(num_heads=32, num_kv_heads=16, head_dim=128,
                           logit_softcap=50.0),
        ffn=FFNSpec(kind="dense", d_ff=36864, activation="gelu"),
        final_logit_softcap=30.0,
        tie_embeddings=True,
        # half the layers are W=4096 local; global KV cache sharded over data(seq)
        supports_long_context=True,
    )
