"""Phi-3-vision 4.2B — VLM: phi3-mini backbone + CLIP frontend (stubbed)
[hf:microsoft/Phi-3-vision-128k-instruct].

32 layers, d_model=3072, 32 heads (GQA kv=32), d_ff=8192, vocab=32064.
The CLIP vision encoder is a STUB: input_specs() supplies precomputed patch
embeddings (CLIP ViT-L/14 width=1024) which a learned projector maps to
d_model and prepends to the token stream.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, FrontendSpec, LayerSpec,
                                ModelConfig, register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        source="hf:microsoft/Phi-3-vision-128k-instruct",
        d_model=3072,
        vocab_size=32064,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=32,
        attn=AttentionSpec(num_heads=32, num_kv_heads=32, head_dim=96),
        ffn=FFNSpec(kind="dense", d_ff=8192),
        frontend=FrontendSpec(kind="vision", embed_dim=1024, num_prefix=576),
        supports_long_context=False,    # dense full-attention backbone
    )
