"""OLMo 2 7B Instruct — the model used in the paper's vLLM case study
[hf:allenai/OLMo-2-1124-7B-Instruct].

32 layers, d_model=4096, 32 heads (MHA), d_ff=11008, vocab=100352.
Not part of the assigned pool; included because the paper's Table 2 serves it.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="olmo2-7b",
        family="dense",
        source="hf:allenai/OLMo-2-1124-7B-Instruct",
        d_model=4096,
        vocab_size=100352,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=32,
        attn=AttentionSpec(num_heads=32, num_kv_heads=32, head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=11008),
        supports_long_context=False,
    )
