"""Configuration dataclasses for all supported architectures.

A model is described by a *layer pattern*: an optional unrolled ``prefix`` of
:class:`LayerSpec` entries followed by a ``period`` of LayerSpecs repeated
``repeats`` times.  The periodic part is compiled with ``jax.lax.scan`` over
stacked parameters, so HLO size (and compile time) is independent of depth.

Every assigned architecture from the public pool gets one module in this
package that builds a :class:`ModelConfig` with the exact published
dimensions, plus a ``reduced()`` variant (<=2 layers, d_model<=512,
<=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionSpec:
    """GQA / MLA attention family."""
    kind: str = "gqa"                 # "gqa" | "mla"
    num_heads: int = 32
    num_kv_heads: int = 32
    head_dim: int = 128
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None   # gemma2-style tanh cap on attn logits
    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0              # 0 => full-rank q projection
    kv_lora_rank: int = 0             # compressed KV dimension (cache stores this)
    rope_head_dim: int = 0            # decoupled RoPE key dim (shared across heads)
    nope_head_dim: int = 0            # per-head non-RoPE dim
    v_head_dim: int = 0


@dataclass(frozen=True)
class FFNSpec:
    kind: str = "dense"               # "dense" | "moe"
    d_ff: int = 0
    activation: str = "silu"          # "silu" (gated) | "gelu" (gated)
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0       # deepseek-v2 shared experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01     # load-balance loss coefficient


@dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class RWKVSpec:
    head_dim: int = 64
    decay_lora: int = 64              # rank of the data-dependent decay LoRA
    d_ffn: int = 0                    # channel-mix hidden size


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: a sequence mixer followed by an FFN."""
    mixer: str = "attn"               # "attn" | "mamba" | "rwkv6"
    ffn: str = "dense"                # "dense" | "moe" | "rwkv_cm" | "none"
    window: int = 0                   # 0 = full attention; >0 = sliding window size
    cross_attn: bool = False          # enc-dec decoder layers attend to encoder memory


@dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec models (seamless)."""
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    d_ff: int = 0


@dataclass(frozen=True)
class FrontendSpec:
    """Stubbed modality frontend: input_specs() supplies precomputed embeddings.

    ``embed_dim`` is the raw embedding size produced by the (stubbed) encoder;
    a learned linear projector maps it to d_model.  ``num_prefix`` is how many
    embedding positions are prepended to the text stream for decoder-only
    multimodal models (VLM patches); for enc-dec audio models the embeddings
    are the *encoder input* instead.
    """
    kind: str = "none"                # "none" | "vision" | "audio"
    embed_dim: int = 0
    num_prefix: int = 0               # decoder-only VLM: patches prepended


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    source: str                       # citation
    d_model: int
    vocab_size: int
    prefix: Tuple[LayerSpec, ...] = ()
    period: Tuple[LayerSpec, ...] = ()
    repeats: int = 0
    attn: AttentionSpec = field(default_factory=AttentionSpec)
    ffn: FFNSpec = field(default_factory=FFNSpec)
    moe: Optional[FFNSpec] = None     # MoE layers' FFN spec (if mixed with dense)
    mamba: Optional[MambaSpec] = None
    rwkv: Optional[RWKVSpec] = None
    encoder: Optional[EncoderSpec] = None
    frontend: FrontendSpec = field(default_factory=FrontendSpec)
    norm_eps: float = 1e-5
    final_logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    dtype: str = "bfloat16"
    # which input shapes this arch supports for decode-500k (sub-quadratic rule)
    supports_long_context: bool = False

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.period) * self.repeats

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        return tuple(self.prefix) + tuple(self.period) * self.repeats

    def ffn_spec_for(self, layer: LayerSpec) -> FFNSpec:
        if layer.ffn == "moe":
            return self.moe if self.moe is not None else self.ffn
        return self.ffn

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(fn):
    """Decorator: register a zero-arg config builder under its module name."""
    name = fn.__module__.rsplit(".", 1)[-1]
    _REGISTRY[name] = fn
    return fn


def get_config(arch: str) -> ModelConfig:
    # populate registry lazily
    import importlib
    key = arch.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]()


ARCH_IDS = (
    "jamba_v0_1_52b",
    "stablelm_3b",
    "phi_3_vision_4_2b",
    "mixtral_8x7b",
    "starcoder2_7b",
    "seamless_m4t_large_v2",
    "rwkv6_1_6b",
    "deepseek_v2_236b",
    "granite_3_8b",
    "gemma2_27b",
)


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    heads = 4
    kv = min(4, max(1, cfg.attn.num_kv_heads * heads // max(cfg.attn.num_heads, 1)))
    attn = dataclasses.replace(
        cfg.attn, num_heads=heads, num_kv_heads=max(1, kv), head_dim=64,
        q_lora_rank=min(cfg.attn.q_lora_rank, 64) if cfg.attn.q_lora_rank else 0,
        kv_lora_rank=min(cfg.attn.kv_lora_rank, 32) if cfg.attn.kv_lora_rank else 0,
        rope_head_dim=min(cfg.attn.rope_head_dim, 16) if cfg.attn.rope_head_dim else 0,
        nope_head_dim=32 if cfg.attn.nope_head_dim else 0,
        v_head_dim=32 if cfg.attn.v_head_dim else 0,
    )

    def shrink_ffn(f: FFNSpec) -> FFNSpec:
        if f is None:
            return None
        return dataclasses.replace(
            f, d_ff=min(f.d_ff, 512),
            num_experts=min(f.num_experts, 4) if f.num_experts else 0,
            top_k=min(f.top_k, 2) if f.top_k else 0,
            num_shared_experts=min(f.num_shared_experts, 1)
            if f.num_shared_experts else 0,
        )

    mamba = dataclasses.replace(cfg.mamba, d_state=8) if cfg.mamba else None
    rwkv = (dataclasses.replace(cfg.rwkv, head_dim=32, decay_lora=16,
                                d_ffn=min(cfg.rwkv.d_ffn, 512))
            if cfg.rwkv else None)
    enc = (dataclasses.replace(cfg.encoder, num_layers=2, d_model=d_model,
                               num_heads=heads, d_ff=512)
           if cfg.encoder else None)
    fe = cfg.frontend
    if fe.kind != "none":
        fe = dataclasses.replace(fe, embed_dim=min(fe.embed_dim, 128),
                                 num_prefix=min(fe.num_prefix, 8))
    # keep the *pattern* (one period) but cap total depth at ~2 layers
    period = cfg.period if cfg.period else ()
    prefix = cfg.prefix
    if period:
        # keep at most 2 sub-layers of the period to preserve heterogeneity
        period = period[: max(1, min(2, len(period)))]
        repeats, prefix = 1, ()
    else:
        prefix, repeats = prefix[:2], 0
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", d_model=d_model,
        vocab_size=min(cfg.vocab_size, 1024),
        prefix=prefix, period=period, repeats=repeats,
        attn=attn, ffn=shrink_ffn(cfg.ffn), moe=shrink_ffn(cfg.moe),
        mamba=mamba, rwkv=rwkv, encoder=enc, frontend=fe,
    )
