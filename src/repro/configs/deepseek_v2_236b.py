"""DeepSeek-V2 236B — MLA + fine-grained MoE [arXiv:2405.04434].

60 layers, d_model=5120, 128 heads, MLA kv_lora=512 (decoupled RoPE dim 64,
nope head dim 128, v head dim 128, q_lora 1536), per-expert d_ff=1536,
vocab=102400, 2 shared + 160 routed experts, top-6.  First layer uses a dense
FFN (d_ff=12288), as in the released model.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        source="arXiv:2405.04434",
        d_model=5120,
        vocab_size=102400,
        prefix=(LayerSpec(mixer="attn", ffn="dense"),),
        period=(LayerSpec(mixer="attn", ffn="moe"),),
        repeats=59,
        attn=AttentionSpec(
            kind="mla", num_heads=128, num_kv_heads=128, head_dim=128,
            q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
            nope_head_dim=128, v_head_dim=128,
        ),
        ffn=FFNSpec(kind="dense", d_ff=12288),
        moe=FFNSpec(kind="moe", d_ff=1536, num_experts=160, top_k=6,
                    num_shared_experts=2),
        supports_long_context=True,     # MLA compressed cache: 576 floats/token/layer
    )
