"""StarCoder2 7B — dense code model, GQA + RoPE + sliding window
[arXiv:2402.19173].

32 layers (the 7B model card lists 32), d_model=4608, 36 heads (GQA kv=4),
d_ff=18432, vocab=49152, SWA window=4096.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        source="arXiv:2402.19173",
        d_model=4608,
        vocab_size=49152,
        period=(LayerSpec(mixer="attn", ffn="dense", window=4096),),
        repeats=32,
        attn=AttentionSpec(num_heads=36, num_kv_heads=4, head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=18432, activation="gelu"),
        supports_long_context=True,     # SWA caps the KV cache at window size
    )
