"""RWKV-6 (Finch) 1.6B — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

24 layers, d_model=2048, d_ffn=7168, vocab=65536, head_dim=64 (32 heads).
"""
from repro.configs.base import (FFNSpec, LayerSpec, ModelConfig, RWKVSpec,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        source="arXiv:2404.05892",
        d_model=2048,
        vocab_size=65536,
        period=(LayerSpec(mixer="rwkv6", ffn="rwkv_cm"),),
        repeats=24,
        ffn=FFNSpec(kind="dense", d_ff=7168),   # channel-mix hidden size
        rwkv=RWKVSpec(head_dim=64, decay_lora=64, d_ffn=7168),
        supports_long_context=True,     # O(1) recurrent state
    )
