"""Granite 3.0 8B — dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base family].

40 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=12800, vocab=49155.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        source="hf:ibm-granite/granite-3.0-2b-base",
        d_model=4096,
        vocab_size=49155,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=40,
        attn=AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=12800),
        tie_embeddings=True,
        supports_long_context=False,    # pure full attention (skip long_500k)
    )
