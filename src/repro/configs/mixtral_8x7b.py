"""Mixtral 8x7B — sparse MoE with sliding-window attention [arXiv:2401.04088].

32 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000,
8 experts top-2 on every layer, SWA window=4096.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        source="arXiv:2401.04088",
        d_model=4096,
        vocab_size=32000,
        period=(LayerSpec(mixer="attn", ffn="moe", window=4096),),
        repeats=32,
        attn=AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=14336),
        moe=FFNSpec(kind="moe", d_ff=14336, num_experts=8, top_k=2),
        supports_long_context=True,     # SWA caps the KV cache at window size
    )
