"""Jamba v0.1 52B — hybrid Mamba + attention with MoE [arXiv:2403.19887].

32 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=65536.
Attention : Mamba interleave 1:7 (one attention layer per 8-layer period),
MoE (16 experts, top-2) applied every other layer.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, MambaSpec,
                                ModelConfig, register)


@register
def config() -> ModelConfig:
    # 8-layer period: attention at position 4 (as in the released model);
    # MoE on odd positions (every other layer).
    period = tuple(
        LayerSpec(
            mixer="attn" if i == 4 else "mamba",
            ffn="moe" if i % 2 == 1 else "dense",
        )
        for i in range(8)
    )
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="arXiv:2403.19887",
        d_model=4096,
        vocab_size=65536,
        period=period,
        repeats=4,                      # 32 layers total
        attn=AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=14336),
        moe=FFNSpec(kind="moe", d_ff=14336, num_experts=16, top_k=2),
        mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
        supports_long_context=True,     # only 4/32 layers attend; Mamba state O(1)
    )
