"""StableLM 3B — dense decoder [hf:stabilityai/stablelm-2-1_6b family].

32 layers, d_model=2560, 32 heads (GQA kv=32 => MHA), d_ff=6912, vocab=50304.
"""
from repro.configs.base import (AttentionSpec, FFNSpec, LayerSpec, ModelConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        d_model=2560,
        vocab_size=50304,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=32,
        attn=AttentionSpec(num_heads=32, num_kv_heads=32, head_dim=80),
        ffn=FFNSpec(kind="dense", d_ff=6912),
        supports_long_context=False,    # pure full attention (skip long_500k)
    )
