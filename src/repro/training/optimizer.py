"""AdamW optimizer (pure JAX, no optax dependency) with sharding-aware
state: first/second moments are float32 and inherit each parameter's
PartitionSpec, so under pjit the optimizer state is FSDP-sharded exactly
like the parameters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.params import P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def state_plan(param_plan) -> AdamWState:
    """Plan-of-P for the optimizer state (for dry-run specs/shardings)."""
    def f32(p: P) -> P:
        return P(p.shape, dtype="float32", init="zeros", pspec=p.pspec,
                 alt=p.alt)
    moments = jax.tree.map(f32, param_plan, is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P((), dtype="int32", init="zeros", pspec=()),
                      mu=moments,
                      nu=jax.tree.map(lambda p: p, moments,
                                      is_leaf=lambda x: isinstance(x, P)))


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, diagnostics)."""
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:            # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
