"""Training step assembly and a small driver loop."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import NO_POLICY, ShardPolicy
from repro.models.model import Model, train_loss
from repro.training import optimizer as opt


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig,
                    policy: ShardPolicy = NO_POLICY, remat: bool = True
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, state, aux)."""

    def step(params, opt_state, batch):
        def loss_fn(p):
            return train_loss(p, cfg, batch, policy, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt_state2, diag = opt.apply_updates(ocfg, params, grads,
                                                      opt_state)
        return params2, opt_state2, {"loss": loss, **diag}

    return step


@dataclass
class TrainResult:
    losses: list
    final_params: Any
    final_state: Any


def train(cfg: ModelConfig, data_iter, steps: int,
          ocfg: Optional[opt.AdamWConfig] = None, seed: int = 0,
          policy: ShardPolicy = NO_POLICY, remat: bool = False,
          log_every: int = 10, log_fn=None) -> TrainResult:
    """CPU-scale driver used by tests/examples (reduced configs)."""
    ocfg = ocfg or opt.AdamWConfig(warmup_steps=10, total_steps=steps)
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    state = opt.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, ocfg, policy, remat))
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, state, aux = step_fn(params, state, batch)
        losses.append(float(aux["loss"]))
        if log_fn and (i % log_every == 0 or i == steps - 1):
            log_fn(i, losses[-1], float(aux["grad_norm"]))
    return TrainResult(losses=losses, final_params=params, final_state=state)
