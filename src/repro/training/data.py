"""Synthetic data pipeline with a host-side byte-rate throttle.

The throttle is the framework's analogue of the paper's cgroup ``io.max``
guardrail: a bandwidth-heavy data-loading tenant (the T2 "ETL" class) can
be capped to N bytes/s, which the controller applies for bounded windows
(paper §2.4: "I/O throttles use cgroup io.max with bounded windows").
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class PipelineStats:
    batches: int = 0
    bytes_read: int = 0
    throttle_sleeps: float = 0.0


class SyntheticTokenPipeline:
    """Deterministic synthetic LM batches (tokens + next-token labels)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, bytes_per_s_cap: Optional[float] = None,
                 frontend: Optional[dict] = None):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        self.bytes_per_s_cap = bytes_per_s_cap
        self.frontend = frontend or {}
        self.stats = PipelineStats()
        self._window_start = time.perf_counter()
        self._window_bytes = 0.0

    def set_throttle(self, bytes_per_s: Optional[float]) -> None:
        """Controller guardrail hook (cgroup io.max analogue)."""
        self.bytes_per_s_cap = bytes_per_s

    def _account(self, nbytes: int) -> None:
        self.stats.bytes_read += nbytes
        if self.bytes_per_s_cap is None:
            return
        self._window_bytes += nbytes
        elapsed = time.perf_counter() - self._window_start
        required = self._window_bytes / self.bytes_per_s_cap
        if required > elapsed:
            sleep = required - elapsed
            self.stats.throttle_sleeps += sleep
            time.sleep(min(sleep, 0.25))
        if elapsed > 1.0:
            self._window_start = time.perf_counter()
            self._window_bytes = 0.0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        toks = self.rng.integers(0, self.vocab_size,
                                 (self.batch, self.seq_len + 1),
                                 dtype=np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        nbytes = toks.nbytes
        kind = self.frontend.get("kind")
        if kind == "vision":
            p, e = self.frontend["num_prefix"], self.frontend["embed_dim"]
            emb = self.rng.standard_normal((self.batch, p, e)).astype(np.float32)
            # text region shrinks so total positions == seq_len
            batch["tokens"] = batch["tokens"][:, : self.seq_len - p]
            batch["labels"] = batch["labels"][:, : self.seq_len - p]
            batch["embeds"] = emb
            nbytes += emb.nbytes
        elif kind == "audio":
            e = self.frontend["embed_dim"]
            frames = self.rng.standard_normal(
                (self.batch, self.seq_len, e)).astype(np.float32)
            batch["frames"] = frames
            nbytes += frames.nbytes
        self._account(nbytes)
        self.stats.batches += 1
        return batch
