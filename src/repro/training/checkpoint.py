"""Checkpointing: msgpack + zstd container for parameter/optimizer pytrees.

No orbax dependency — a flat path->array mapping with a JSON-ish manifest,
good enough for single-host saves and the last-known-good rollback the
controller's audit log requires.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:                       # container images without zstd
    zstandard = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    flat = {}
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in paths_leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, metadata: Dict[str, Any] | None = None) -> int:
    flat = _flatten(tree)
    payload = {
        "metadata": metadata or {},
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(raw)
    else:
        comp = zlib.compress(raw, 6)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)
    return len(comp)


def load(path: str, like: Any | None = None) -> Tuple[Any, Dict[str, Any]]:
    """Returns (tree, metadata).  If ``like`` is given, restores its pytree
    structure; otherwise returns the flat dict."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError("checkpoint is zstd-compressed but the "
                              "zstandard module is unavailable")
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:
        raw = zlib.decompress(blob)
    payload = msgpack.unpackb(raw, raw=False)
    arrays = {
        k: np.frombuffer(v["data"],
                         dtype=np.dtype(v["dtype"])).reshape(v["shape"])
        for k, v in payload["arrays"].items()
    }
    if like is None:
        return arrays, payload["metadata"]
    flat_like = _flatten(like)
    assert set(flat_like) == set(arrays), "checkpoint/pytree key mismatch"
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        restored.append(jnp.asarray(arrays[key]).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), payload["metadata"]
