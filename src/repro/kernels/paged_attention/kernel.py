"""Pallas TPU paged decode attention (the vLLM-style serving hot spot).

TPU adaptation notes:
  * page gathering is done through the BlockSpec index map driven by a
    *scalar-prefetched* block table (PrefetchScalarGridSpec) — the Pallas
    analogue of vLLM's gather from the page pool, but resolved by the DMA
    engine ahead of compute instead of per-warp pointer chasing;
  * one (batch, kv_head) pair per grid step keeps the whole per-head state
    (page tile + accumulator) in VMEM; pages stream over the innermost grid
    dimension with the online-softmax accumulator in VMEM scratch;
  * page_size is a multiple of 128 so the K^T q matmul hits the MXU.

Grid: (batch, kv_heads, pages_per_seq), pages innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0e38


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, page: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # [page, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)         # [page, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = pos < lengths_ref[bi]
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pi == np_ - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       (l_ref[...][:, None] + 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, interpret: bool = False):
    """q: [B,H,hd]; pages: [P,page,KV,hd]; tables: [B,PPS]; lengths: [B]."""
    b, h, hd = q.shape
    page = k_pages.shape[1]
    kv = k_pages.shape[2]
    g = h // kv
    pps = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    qr = q.reshape(b, kv, g, hd)

    grid = (b, kv, pps)
    kernel = functools.partial(_paged_kernel, scale=scale, page=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, ki, pi, tables, lens: (bi, ki, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda bi, ki, pi, tables, lens:
                         (tables[bi, pi], 0, ki, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda bi, ki, pi, tables, lens:
                         (tables[bi, pi], 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, pi, tables, lens: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qr, k_pages, v_pages)
    return out.reshape(b, h, hd)
