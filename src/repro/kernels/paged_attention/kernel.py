"""Pallas TPU paged attention (the vLLM-style serving hot spot), ragged.

One kernel serves the whole fused mixed prefill+decode step: every batch
lane carries a block of ``Q`` query rows (a decode lane uses one live row,
a prefill chunk uses ``chunk`` rows; pad rows are masked by position) and
causality is enforced *inside the page walk* — key slot ``t`` of the
gathered pages contributes to query row ``i`` only when
``t <= q_positions[lane, i]``.

TPU adaptation notes:
  * page gathering is done through the BlockSpec index map driven by a
    *scalar-prefetched* block table (PrefetchScalarGridSpec) — the Pallas
    analogue of vLLM's gather from the page pool, but resolved by the DMA
    engine ahead of compute instead of per-warp pointer chasing;
  * one (batch, kv_head) pair per grid step keeps the whole per-head state
    (page tile + [Q, G] accumulator) in VMEM; pages stream over the
    innermost grid dimension with the online-softmax accumulator in VMEM
    scratch;
  * page_size is a multiple of 128 so the K^T q matmul hits the MXU;
  * int8 page pools ride the same specs: per-page-row scales are streamed
    next to their pages and the dequant happens in-register, so HBM
    traffic stays at the int8 footprint.

Grid: (batch, kv_heads, pages_per_seq), pages innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0e38


def _mixed_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, page: int,
                  ks_ref=None, vs_ref=None):
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)            # [Q, G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # [page, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)         # [page, hd]
    if ks_ref is not None:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
    if vs_ref is not None:
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos_k = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    qpos = qpos_ref[0]                                # [Q]
    mask = pos_k <= qpos[:, None, None]               # causal page walk
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                               # [Q, G]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=2))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, :, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, :, None] + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pi == np_ - 1)
    def _finalize():
        o_ref[0, :, 0] = (acc_ref[...] /
                          (l_ref[...][..., None] + 1e-30)).astype(o_ref.dtype)


def paged_attention_mixed(q, k_pages, v_pages, block_tables, q_positions, *,
                          scale=None, interpret: bool = False,
                          k_scales=None, v_scales=None):
    """q: [B,Q,H,hd]; pages: [P,page,KV,hd]; tables: [B,PPS];
    q_positions: [B,Q] (per-row sequence position, causal bound);
    k_scales/v_scales: [P,page,KV] when the pages are int8."""
    b, qn, h, hd = q.shape
    page = k_pages.shape[1]
    kv = k_pages.shape[2]
    g = h // kv
    pps = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    qr = q.reshape(b, qn, kv, g, hd)

    grid = (b, kv, pps)
    kernel = functools.partial(_mixed_kernel, scale=scale, page=page)

    def at_lane(bi, ki, pi, tables):
        return (bi, 0)

    in_specs = [
        pl.BlockSpec((1, qn), at_lane),                       # q_positions
        pl.BlockSpec((1, qn, 1, g, hd),
                     lambda bi, ki, pi, tables: (bi, 0, ki, 0, 0)),
        pl.BlockSpec((1, page, 1, hd),
                     lambda bi, ki, pi, tables: (tables[bi, pi], 0, ki, 0)),
        pl.BlockSpec((1, page, 1, hd),
                     lambda bi, ki, pi, tables: (tables[bi, pi], 0, ki, 0)),
    ]
    inputs = [block_tables, q_positions, qr, k_pages, v_pages]
    if k_scales is not None:
        # scales stream next to their pages through the same gather
        spec = pl.BlockSpec((1, page, 1),
                            lambda bi, ki, pi, tables: (tables[bi, pi], 0, ki))
        in_specs += [spec, spec]
        inputs += [k_scales, v_scales]

        def kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, ks, vs, o_ref,
                   acc_ref, m_ref, l_ref):
            _mixed_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                          acc_ref, m_ref, l_ref, scale=scale, page=page,
                          ks_ref=ks, vs_ref=vs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, qn, 1, g, hd),
                               lambda bi, ki, pi, tables: (bi, 0, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qn, g, hd), jnp.float32),
            pltpu.VMEM((qn, g), jnp.float32),
            pltpu.VMEM((qn, g), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, qn, kv, g, hd), q.dtype),
        interpret=interpret,
    )(*inputs)
    return out.reshape(b, qn, h, hd)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, interpret: bool = False,
                    k_scales=None, v_scales=None):
    """Single-token decode: q [B,H,hd], lengths [B] — the q_len=1 case."""
    qpos = (lengths - 1)[:, None].astype(jnp.int32)
    out = paged_attention_mixed(q[:, None], k_pages, v_pages, block_tables,
                                qpos, scale=scale, interpret=interpret,
                                k_scales=k_scales, v_scales=v_scales)
    return out[:, 0]
