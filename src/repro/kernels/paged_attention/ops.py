"""Jit'd public wrapper for paged decode attention.

This is the entry point the paged serving runtime calls each decode step
with *real* per-sequence block tables and lengths (built from the
``PagedKVCache`` page tables).  ``impl`` selects the execution path:

  * ``"auto"``   — Pallas kernel on TPU, pure-jnp oracle elsewhere (the
                   oracle is the fast CPU fallback; the interpreted kernel
                   is ~100x slower than the oracle on CPU);
  * ``"kernel"`` — always the Pallas kernel (interpret mode off-TPU), used
                   by the parity tests and kernel benchmarks;
  * ``"ref"``    — always the pure-jnp oracle.

Contract expected by both paths: ``block_tables`` may be narrower than the
maximum pages-per-sequence (the runtime buckets the width to the longest
live sequence so decode cost tracks live tokens, not the seq cap), every
table entry must be a valid page index, and ``lengths`` must be >= 1
(masked-out padding lanes are clamped by the caller — a zero length would
NaN the online softmax).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "impl", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, impl: str = "auto", interpret: bool = False):
    """q: [B,H,hd]; pages: [P,page,KV,hd]; tables: [B,PPS]; lengths: [B]."""
    if impl not in ("auto", "kernel", "ref"):
        raise ValueError(f"unknown paged_attention impl {impl!r}")
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale)
    return _kernel(q, k_pages, v_pages, block_tables, lengths, scale=scale,
                   interpret=interpret or not _on_tpu())


__all__ = ["paged_attention", "paged_attention_ref"]
