"""Jit'd public wrappers for paged attention (decode and ragged mixed).

These are the entry points the paged serving runtime calls each step with
*real* per-sequence block tables built from the ``PagedKVCache`` page
tables.  ``paged_attention_mixed`` is the fused-step form: every lane
carries ``Q`` query rows with per-row sequence positions (decode lanes use
one live row, prefill chunks use ``chunk`` rows) and causality is enforced
inside the page walk.  ``paged_attention`` keeps the classic q_len=1
decode contract on top of it.

``impl`` selects the execution path:

  * ``"auto"``   — Pallas kernel on TPU, pure-jnp oracle elsewhere (the
                   oracle is the fast CPU fallback; the interpreted kernel
                   is ~100x slower than the oracle on CPU);
  * ``"kernel"`` — always the Pallas kernel (interpret mode off-TPU), used
                   by the parity tests and kernel benchmarks;
  * ``"ref"``    — always the pure-jnp oracle.

Contract expected by both paths: ``block_tables`` may be narrower than the
maximum pages-per-sequence (the runtime buckets the width to the longest
live sequence so attention cost tracks live tokens, not the seq cap),
every table entry must be a valid page index, and every query row's
position must map to a key slot whose page holds real data (pad rows are
given position 0, which reads the lane's first slot — written for any
live lane — and their output is discarded by the caller).  When the page
pools are int8, ``k_scales``/``v_scales`` carry the per-page-row
dequantization scales ``[P, page, KV]``.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention as _kernel,
    paged_attention_mixed as _kernel_mixed,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_mixed_ref,
    paged_attention_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "impl", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, impl: str = "auto", interpret: bool = False,
                    k_scales=None, v_scales=None):
    """q: [B,H,hd]; pages: [P,page,KV,hd]; tables: [B,PPS]; lengths: [B]."""
    if impl not in ("auto", "kernel", "ref"):
        raise ValueError(f"unknown paged_attention impl {impl!r}")
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale, k_scales=k_scales,
                                   v_scales=v_scales)
    return _kernel(q, k_pages, v_pages, block_tables, lengths, scale=scale,
                   interpret=interpret or not _on_tpu(),
                   k_scales=k_scales, v_scales=v_scales)


@functools.partial(jax.jit, static_argnames=("scale", "impl", "interpret"))
def paged_attention_mixed(q, k_pages, v_pages, block_tables, q_positions, *,
                          scale=None, impl: str = "auto",
                          interpret: bool = False,
                          k_scales=None, v_scales=None):
    """q: [B,Q,H,hd]; q_positions: [B,Q] per-row sequence positions."""
    if impl not in ("auto", "kernel", "ref"):
        raise ValueError(f"unknown paged_attention impl {impl!r}")
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return paged_attention_mixed_ref(
            q, k_pages, v_pages, block_tables, q_positions, scale=scale,
            k_scales=k_scales, v_scales=v_scales)
    return _kernel_mixed(q, k_pages, v_pages, block_tables, q_positions,
                         scale=scale, interpret=interpret or not _on_tpu(),
                         k_scales=k_scales, v_scales=v_scales)


__all__ = ["paged_attention", "paged_attention_mixed",
           "paged_attention_ref", "paged_attention_mixed_ref"]
